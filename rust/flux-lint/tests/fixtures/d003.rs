// flux-lint test fixture: D003 (wall clock).
use std::time::Instant;

fn wall() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_nanos() as f64
}
