// flux-lint test fixture: allow pragma, standalone-line form (covers
// the next code line) and same-line form.

fn lt(a: f64, b: f64) -> bool {
    // flux-lint: allow(D002) -- fixture: callers reject NaN upstream
    matches!(a.partial_cmp(&b), Some(std::cmp::Ordering::Less))
}

fn probe(a: f64, b: f64) -> bool {
    a.partial_cmp(&b).is_some() // flux-lint: allow(D002) -- same line
}
