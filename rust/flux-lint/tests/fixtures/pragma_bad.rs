// flux-lint test fixture: D000 (pragma hygiene). Unknown rule id,
// missing reason, and an allow that suppresses nothing.

// flux-lint: allow(D999) -- not a real rule
fn unknown_rule() {}

// flux-lint: allow(D001)
fn reasonless() {}

// flux-lint: allow(D001) -- suppresses nothing below
fn clean() -> u32 {
    7
}
