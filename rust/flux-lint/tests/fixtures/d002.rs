// flux-lint test fixture: D002 (partial_cmp on floats). The use on
// line 5 is a violation; the `fn partial_cmp` PartialOrd impl below is
// a definition and must NOT be flagged.

fn smallest(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

struct T(f64);

impl PartialOrd for T {
    fn partial_cmp(&self, _other: &Self) -> Option<std::cmp::Ordering> {
        Some(std::cmp::Ordering::Equal)
    }
}
