// flux-lint test fixture: D004 (OS entropy).

fn roll() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
