//! flux-lint behaviour tests: each rule against a seeded fixture
//! (`tests/fixtures/` — not cargo targets, so the fixtures are free to
//! be intentionally broken), pragma handling, the cfg(test) exclusion,
//! the D005 budget ratchet, and byte-stability of the JSON document.

use std::collections::BTreeMap;
use std::path::Path;

use flux_lint::{
    apply_budget, scan_source, scan_tree, Budget, PanicCounts, Report,
};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// (line, rule) pairs of the findings for one fixture.
fn hits(rel: &str, text: &str) -> Vec<(usize, &'static str)> {
    scan_source(rel, text)
        .findings
        .iter()
        .map(|f| (f.line, f.rule))
        .collect()
}

#[test]
fn d001_flags_hash_collections() {
    let scan = scan_source("fixtures/d001.rs", &fixture("d001.rs"));
    assert_eq!(
        hits("fixtures/d001.rs", &fixture("d001.rs")),
        vec![(2, "D001"), (4, "D001"), (5, "D001")]
    );
    assert_eq!(scan.findings[0].path, "rust/src/fixtures/d001.rs");
    assert!(scan.findings[0].message.contains("BTreeMap"));
}

#[test]
fn d002_flags_use_but_not_definition() {
    // Line 6 uses partial_cmp inside sort_by; line 12 is the
    // `fn partial_cmp` of a PartialOrd impl and stays legal.
    assert_eq!(
        hits("fixtures/d002.rs", &fixture("d002.rs")),
        vec![(6, "D002")]
    );
    // The `.unwrap()` on line 6 also lands in the panic counts.
    let scan = scan_source("fixtures/d002.rs", &fixture("d002.rs"));
    assert_eq!(scan.counts.unwrap, 1);
    assert_eq!(scan.counts.expect, 0);
}

#[test]
fn d003_flags_wall_clock_outside_bench() {
    assert_eq!(
        hits("fixtures/d003.rs", &fixture("d003.rs")),
        vec![(2, "D003"), (5, "D003")]
    );
    // The same source as util/bench.rs (the sanctioned wall-clock
    // module) is clean.
    assert_eq!(hits("util/bench.rs", &fixture("d003.rs")), vec![]);
}

#[test]
fn d004_flags_os_entropy() {
    assert_eq!(
        hits("fixtures/d004.rs", &fixture("d004.rs")),
        vec![(4, "D004")]
    );
}

#[test]
fn allow_pragma_suppresses_and_records() {
    let scan = scan_source("fixtures/allow.rs", &fixture("allow.rs"));
    assert_eq!(scan.findings, vec![], "both hits are pragma-allowed");
    let allowed: Vec<(usize, &str, &str)> = scan
        .allowed
        .iter()
        .map(|a| (a.line, a.rule, a.reason.as_str()))
        .collect();
    assert_eq!(
        allowed,
        vec![
            (6, "D002", "fixture: callers reject NaN upstream"),
            (10, "D002", "same line"),
        ]
    );
}

#[test]
fn d000_flags_malformed_and_unused_pragmas() {
    assert_eq!(
        hits("fixtures/pragma_bad.rs", &fixture("pragma_bad.rs")),
        vec![(4, "D000"), (7, "D000"), (10, "D000")]
    );
    let scan =
        scan_source("fixtures/pragma_bad.rs", &fixture("pragma_bad.rs"));
    assert!(scan.findings[0].message.contains("malformed"));
    assert!(scan.findings[2].message.contains("unused"));
}

#[test]
fn prose_mention_of_flux_lint_is_not_a_pragma() {
    let src = "// flux-lint rule D003 bans Instant outside bench\n\
               fn f() {}\n";
    assert_eq!(hits("a.rs", src), vec![]);
}

#[test]
fn cfg_test_region_excluded_from_panic_counts() {
    let src = "\
fn live() {
    do_it().unwrap();
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        do_it().unwrap();
        other().expect(\"boom\");
        panic!(\"never\");
    }
}
";
    let scan = scan_source("a.rs", src);
    assert_eq!(scan.counts.unwrap, 1, "only the non-test unwrap");
    assert_eq!(scan.counts.expect, 0);
    assert_eq!(scan.counts.panic, 0);
}

#[test]
fn lexer_ignores_strings_comments_and_raw_strings() {
    // Every rule trigger below lives in a string, comment, raw string
    // or char literal — none of it is code.
    let src = "\
// HashMap in a comment
/* Instant::now() in /* a nested */ block comment */
fn f() -> &'static str {
    let _lifetime: &'static u8 = &0;
    let _c = 'H'; // char literal, not a HashMap
    let _s = \"HashMap<partial_cmp> thread_rng\";
    let _r = r#\"Instant::now() \"quoted\" SystemTime\"#;
    let _cont = \"a\\
        HashMap continuation line\";
    \"done\"
}
fn line_check() -> std::time::Instant {
    std::time::Instant::now()
}
";
    // Only the two real Instant tokens fire, and the `\<newline>`
    // string continuation must not desync the line numbers.
    assert_eq!(
        hits("a.rs", src),
        vec![(12, "D003"), (13, "D003")]
    );
}

#[test]
fn budget_parses_and_ratchets() {
    let budget = Budget::parse(
        "{\"schema\":\"flux-lint-budget-v1\",\"modules\":{\
         \"a.rs\":{\"unwrap\":1,\"expect\":2},\
         \"b.rs\":{\"panic\":1}}}",
    )
    .unwrap();
    assert_eq!(budget.modules["a.rs"].unwrap, 1);
    assert_eq!(budget.modules["a.rs"].expect, 2);
    assert_eq!(budget.modules["b.rs"].panic, 1);

    // a.rs within budget (slack 1 expect), c.rs over (no allowance),
    // b.rs has zero sites now (slack 1 panic to ratchet away).
    let mut report = Report::default();
    report.panic_sites.insert(
        "a.rs".into(),
        PanicCounts { unwrap: 1, expect: 1, panic: 0 },
    );
    report.panic_sites.insert(
        "c.rs".into(),
        PanicCounts { unwrap: 1, expect: 0, panic: 0 },
    );
    apply_budget(&mut report, &budget);
    let d005: Vec<(&str, usize)> = report
        .findings
        .iter()
        .filter(|f| f.rule == "D005")
        .map(|f| (f.path.as_str(), f.line))
        .collect();
    assert_eq!(d005, vec![("rust/src/c.rs", 0)]);
    assert!(report.findings[0].message.contains("unwrap 1 > 0"));
    assert_eq!(report.budget_slack["a.rs"].expect, 1);
    assert_eq!(report.budget_slack["b.rs"].panic, 1);
    assert!(!report.budget_slack.contains_key("c.rs"));
}

#[test]
fn budget_rejects_bad_schema_and_kinds() {
    assert!(Budget::parse("{\"schema\":\"nope\",\"modules\":{}}")
        .is_err());
    assert!(Budget::parse(
        "{\"schema\":\"flux-lint-budget-v1\",\"modules\":{\
         \"a.rs\":{\"frob\":1}}}"
    )
    .is_err());
}

#[test]
fn fixture_tree_scan_is_sorted_and_byte_stable() {
    let dir =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let a = scan_tree(&dir).unwrap();
    let b = scan_tree(&dir).unwrap();
    assert_eq!(a.files_scanned, 6);
    assert_eq!(a.to_json(), b.to_json(), "repeat scans byte-identical");
    // Findings arrive sorted by (path, line, rule).
    let keys: Vec<(String, usize)> = a
        .findings
        .iter()
        .map(|f| (f.path.clone(), f.line))
        .collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
    // One finding per seeded violation: 3x D001, 1x D002, 2x D003,
    // 1x D004, 3x D000; the allow fixture contributes only `allowed`.
    assert_eq!(a.findings.len(), 10);
    assert_eq!(a.allowed.len(), 2);
}

/// Pseudo-property test: serialization is a pure function of the scan
/// result — for a spread of deterministically generated token soups,
/// scanning and serializing twice yields identical bytes.
#[test]
fn json_serialization_is_byte_stable_under_generated_inputs() {
    let atoms = [
        "HashMap", "partial_cmp", "Instant", "thread_rng", "unwrap",
        "fn", ".", "(", ")", "\n", "// flux-lint: allow(D001) -- x\n",
        "\"str HashMap\"", "let x = 1;", "#[cfg(test)] mod t { ",
        "}", "panic", "!",
    ];
    let mut state = 0x9e3779b97f4a7c15u64;
    let mut next = move || {
        // xorshift64* — deterministic, no OS entropy (D004 practices
        // what it preaches).
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state = state.wrapping_mul(0x2545f4914f6cdd1d);
        state
    };
    for _case in 0..64 {
        let len = (next() % 40 + 5) as usize;
        let src: String = (0..len)
            .map(|_| {
                let a = atoms[(next() % atoms.len() as u64) as usize];
                format!("{a} ")
            })
            .collect();
        let mut r1 = Report::default();
        let mut r2 = Report::default();
        for (r, sink) in
            [(&src, &mut r1), (&src, &mut r2)]
        {
            let scan = scan_source("gen.rs", r);
            sink.findings.extend(scan.findings);
            sink.allowed.extend(scan.allowed);
            if scan.counts.total() > 0 {
                sink.panic_sites.insert("gen.rs".into(), scan.counts);
            }
            sink.files_scanned = 1;
            apply_budget(sink, &Budget::default());
        }
        assert_eq!(r1.to_json(), r2.to_json());
    }
}

/// The CI gate end-to-end: inject a violation into a scratch tree and
/// the binary exits nonzero naming rule/path/line; pragma the line and
/// it exits clean. (This is what "CI fails on an injected D001-D004
/// violation" means mechanically — the lint step exits 1.)
#[test]
fn binary_exits_nonzero_on_injected_violation() {
    let root = std::env::temp_dir().join("flux_lint_inject");
    let src = root.join("rust").join("src");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(
        src.join("bad.rs"),
        "use std::collections::HashMap;\nfn f() {}\n",
    )
    .unwrap();
    let run = || {
        std::process::Command::new(env!("CARGO_BIN_EXE_flux-lint"))
            .arg("--root")
            .arg(&root)
            .output()
            .unwrap()
    };
    let out = run();
    assert_eq!(out.status.code(), Some(1), "findings must exit 1");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("D001 rust/src/bad.rs:1:"),
        "rule, path and line in the output: {text}"
    );

    // The documented escape hatch turns the same tree green.
    std::fs::write(
        src.join("bad.rs"),
        "// flux-lint: allow(D001) -- injected fixture\n\
         use std::collections::HashMap;\nfn f() {}\n",
    )
    .unwrap();
    let out = run();
    assert_eq!(
        out.status.code(),
        Some(0),
        "pragma-allowed tree exits clean: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn json_document_shape() {
    let dir =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut report = scan_tree(&dir).unwrap();
    let budget = Budget {
        modules: BTreeMap::from([(
            "fixtures/d002.rs".to_string(),
            PanicCounts { unwrap: 1, expect: 0, panic: 0 },
        )]),
    };
    apply_budget(&mut report, &budget);
    let json = report.to_json();
    assert!(json.starts_with("{\"allowed\":["));
    assert!(json.ends_with(",\"schema\":\"flux-lint-v1\"}"));
    assert!(json.contains("\"files_scanned\":6"));
    assert!(json.contains(
        "\"panic_sites\":{\"fixtures/d002.rs\":{\"unwrap\":1}}"
    ));
    assert!(!json.contains("D005"), "d002.rs is exactly on budget");
}
