//! `flux-lint` binary: scan `rust/src/**` for determinism-rule
//! violations (D001-D005) and report them human-readably or as the
//! byte-stable `flux-lint-v1` JSON document.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use flux_lint::{find_root, run, Budget, BUDGET_PATH, RULES};

const USAGE: &str = "\
flux-lint — determinism & byte-stability lint for the FLUX tree

USAGE:
    flux-lint [--json] [--root DIR] [--budget FILE] [--list]

OPTIONS:
    --json         emit the byte-stable flux-lint-v1 JSON document
    --root DIR     repo root (default: walk up from cwd to rust/src)
    --budget FILE  D005 panic-budget file
                   (default: <root>/artifacts/lint_budget.json)
    --list         print the rule table and exit
";

fn main() -> ExitCode {
    match real_main() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("flux-lint: error: {e:#}");
            ExitCode::from(2)
        }
    }
}

fn real_main() -> anyhow::Result<ExitCode> {
    let mut json = false;
    let mut list = false;
    let mut root: Option<PathBuf> = None;
    let mut budget_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--list" => list = true,
            "--root" => {
                root = Some(PathBuf::from(
                    args.next()
                        .ok_or_else(|| anyhow::anyhow!("--root needs DIR"))?,
                ));
            }
            "--budget" => {
                budget_path = Some(PathBuf::from(args.next().ok_or_else(
                    || anyhow::anyhow!("--budget needs FILE"),
                )?));
            }
            "--help" | "-h" => {
                print!("{USAGE}");
                return Ok(ExitCode::SUCCESS);
            }
            other => {
                eprintln!("flux-lint: unknown argument {other:?}\n");
                eprint!("{USAGE}");
                return Ok(ExitCode::from(2));
            }
        }
    }
    if list {
        for r in RULES {
            println!("{}  {:<22} {}", r.id, r.title, r.protects);
        }
        return Ok(ExitCode::SUCCESS);
    }
    let root = match root {
        Some(r) => r,
        None => find_root(&std::env::current_dir()?)?,
    };
    let budget_path =
        budget_path.unwrap_or_else(|| root.join(BUDGET_PATH));
    let budget = if budget_path.exists() {
        Some(Budget::load(&budget_path)?)
    } else {
        // No ratchet file: D005 is skipped (fixture trees, bring-up).
        None
    };
    let report = run(&root, budget.as_ref())?;
    if json {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_human());
    }
    Ok(if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    })
}
