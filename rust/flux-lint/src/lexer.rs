//! Lexical front end: blank comments/strings/char literals out of Rust
//! source (preserving the char-for-char line layout) and cut the
//! remainder into identifier/number/punct tokens.
//!
//! This is deliberately a lexer, not a parser: every rule flux-lint
//! enforces is decidable from the token stream plus a little lookback/
//! lookahead, and a lexer cannot be wedged by code that does not parse
//! yet. A bit-exact Python mirror lives in `scripts/lint_budget.py`
//! (it generates `artifacts/lint_budget.json`); keep the two in sync.

/// `strip()` output: the source with every comment, string literal and
/// char literal replaced by spaces (newlines preserved, so line/column
/// positions survive), plus each `//` comment's text for pragma
/// parsing.
pub struct Stripped {
    pub blanked: String,
    /// `(line, text)` per line comment, text after the `//`.
    pub comments: Vec<(usize, String)>,
}

pub fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

pub fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Blank non-code out of `text`. Handles nested block comments, string
/// escapes incl. `\<newline>` continuations, raw (and byte) strings
/// with any `#` count, byte chars, and the char-literal/lifetime
/// ambiguity (`'a'` vs `'a`).
pub fn strip(text: &str) -> Stripped {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out: Vec<char> = vec![' '; n];
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            out[i] = '\n';
            line += 1;
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let body: String = chars[i + 2..j].iter().collect();
            comments.push((line, body));
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    out[i] = '\n';
                    line += 1;
                    i += 1;
                } else if chars[i] == '/'
                    && i + 1 < n
                    && chars[i + 1] == '*'
                {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*'
                    && i + 1 < n
                    && chars[i + 1] == '/'
                {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        if c == '"' {
            let (ni, nl) = skip_string(&chars, i + 1, line, &mut out);
            i = ni;
            line = nl;
            continue;
        }
        // Raw/byte strings — but not raw identifiers (`r#foo`) and not
        // an `r`/`b` that is the tail of a longer identifier.
        if (c == 'r' || c == 'b')
            && (i == 0 || !is_ident_char(chars[i - 1]))
        {
            let mut j = i + 1;
            if c == 'b' && j < n && chars[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                let (ni, nl) =
                    skip_raw_string(&chars, j + 1, hashes, line, &mut out);
                i = ni;
                line = nl;
                continue;
            }
            if c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
                i = skip_char_literal(&chars, i + 2);
                continue;
            }
        }
        if c == '\'' {
            let nxt = if i + 1 < n { chars[i + 1] } else { ' ' };
            let nxt2 = if i + 2 < n { chars[i + 2] } else { ' ' };
            if nxt == '\\' {
                i = skip_char_literal(&chars, i + 1);
                continue;
            }
            if is_ident_start(nxt) && nxt2 != '\'' {
                // Lifetime: blank the quote, keep the name as code.
                i += 1;
                continue;
            }
            if nxt2 == '\'' {
                i += 3; // 'x'
                continue;
            }
            i += 1;
            continue;
        }
        out[i] = c;
        i += 1;
    }
    Stripped { blanked: out.iter().collect(), comments }
}

fn skip_string(
    chars: &[char],
    mut i: usize,
    mut line: usize,
    out: &mut [char],
) -> (usize, usize) {
    let n = chars.len();
    while i < n {
        let c = chars[i];
        if c == '\n' {
            out[i] = '\n';
            line += 1;
            i += 1;
        } else if c == '\\' {
            // `\<newline>` is a line continuation: the newline is
            // still a source line boundary.
            if i + 1 < n && chars[i + 1] == '\n' {
                out[i + 1] = '\n';
                line += 1;
            }
            i += 2;
        } else if c == '"' {
            return (i + 1, line);
        } else {
            i += 1;
        }
    }
    (i, line)
}

fn skip_raw_string(
    chars: &[char],
    mut i: usize,
    hashes: usize,
    mut line: usize,
    out: &mut [char],
) -> (usize, usize) {
    let n = chars.len();
    while i < n {
        if chars[i] == '\n' {
            out[i] = '\n';
            line += 1;
            i += 1;
        } else if chars[i] == '"' && closes_raw(chars, i + 1, hashes) {
            return (i + 1 + hashes, line);
        } else {
            i += 1;
        }
    }
    (i, line)
}

fn closes_raw(chars: &[char], start: usize, hashes: usize) -> bool {
    start + hashes <= chars.len()
        && chars[start..start + hashes].iter().all(|&c| c == '#')
}

fn skip_char_literal(chars: &[char], mut i: usize) -> usize {
    // `i` points at the backslash (or first interior char); scan to
    // the closing quote. For `'\''` the escaped char is consumed
    // first so its quote does not terminate early; `'\u{..}'` ends at
    // the next quote either way.
    let n = chars.len();
    if i < n && chars[i] == '\\' {
        i += 2;
    }
    while i < n && chars[i] != '\'' {
        i += 1;
    }
    i + 1
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Id,
    Num,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub line: usize,
    pub kind: Kind,
    pub s: String,
}

impl Tok {
    pub fn is_id(&self, s: &str) -> bool {
        self.kind == Kind::Id && self.s == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        // Punct tokens are single-char by construction.
        self.kind == Kind::Punct && self.s.chars().next() == Some(c)
    }
}

/// Cut blanked source into tokens. Numbers are lexed as one
/// `[0-9][A-Za-z0-9_]*` run (enough to keep `0x1b3` from reading as a
/// byte-string start); every other non-space char is a 1-char punct.
pub fn tokenize(blanked: &str) -> Vec<Tok> {
    let chars: Vec<char> = blanked.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_char(chars[j]) {
                j += 1;
            }
            toks.push(Tok {
                line,
                kind: Kind::Id,
                s: chars[i..j].iter().collect(),
            });
            i = j;
        } else if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_ident_char(chars[j]) {
                j += 1;
            }
            toks.push(Tok {
                line,
                kind: Kind::Num,
                s: chars[i..j].iter().collect(),
            });
            i = j;
        } else {
            toks.push(Tok { line, kind: Kind::Punct, s: c.to_string() });
            i += 1;
        }
    }
    toks
}

/// Token-index spans `[start, end)` covered by `#[cfg(test)]` items
/// (the attribute tokens included). The guarded item ends at the
/// matching brace of its first block, or at a `;` if brace-less.
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        let is_attr = toks[i].is_punct('#')
            && i + 6 < n
            && toks[i + 1].is_punct('[')
            && toks[i + 2].is_id("cfg")
            && toks[i + 3].is_punct('(')
            && toks[i + 4].is_id("test")
            && toks[i + 5].is_punct(')')
            && toks[i + 6].is_punct(']');
        if !is_attr {
            i += 1;
            continue;
        }
        let mut j = i + 7;
        while j < n && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
            j += 1;
        }
        if j < n && toks[j].is_punct('{') {
            let mut depth = 1usize;
            j += 1;
            while j < n && depth > 0 {
                if toks[j].is_punct('{') {
                    depth += 1;
                } else if toks[j].is_punct('}') {
                    depth -= 1;
                }
                j += 1;
            }
        } else {
            j = (j + 1).min(n);
        }
        spans.push((i, j));
        i = j;
    }
    spans
}

pub fn in_spans(spans: &[(usize, usize)], idx: usize) -> bool {
    spans.iter().any(|&(s, e)| s <= idx && idx < e)
}
