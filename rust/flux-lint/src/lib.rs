//! flux-lint — determinism & byte-stability static analysis for the
//! FLUX tree.
//!
//! Every result the repo ships rests on byte-stable reports: the
//! flux-vs-decoupled speedup bands, the parallel runner's
//! byte-identical-at-any-thread-count guarantee, the CI trajectory
//! diffs. This pass encodes the rules that keep them stable as named
//! diagnostics over `rust/src/**`, so a determinism break is caught at
//! the source line instead of as an unexplained BENCH diff three jobs
//! later:
//!
//! * **D001** no `HashMap`/`HashSet` — hash iteration order is
//!   nondeterministic; use `BTreeMap`/`BTreeSet` or a `Vec`.
//! * **D002** no `partial_cmp` — not total on floats (NaN panics
//!   `sort`/`min_by` unwraps or poisons them); `f64::total_cmp` is the
//!   law. `fn partial_cmp` (a `PartialOrd` impl) is a definition, not
//!   a use, and is exempt.
//! * **D003** no `Instant`/`SystemTime` outside `util/bench.rs` — wall
//!   clock may only feed `--wall` report sections, via
//!   `util::bench::Stopwatch`.
//! * **D004** no OS-entropy RNG construction (`thread_rng`, `OsRng`,
//!   `RandomState`, ...) — randomness comes from the seeded
//!   `util::prng::Rng` entry points.
//! * **D005** panic-budget ratchet — `unwrap()`/`expect()`/`panic!`
//!   counts per module (non-test code) are pinned in
//!   `artifacts/lint_budget.json` and may only go down.
//! * **D000** pragma hygiene — a malformed or unused allow pragma is
//!   itself a finding.
//!
//! Justified exceptions carry an escape pragma naming the rule and the
//! reason, on the offending line or a standalone comment line directly
//! above it:
//!
//! ```text
//! // flux-lint: allow(D002) -- admit() rejects non-finite times
//! ```
//!
//! The scanner is a lexer, not a parser (`lexer` module); rules are
//! token matches with one token of context. `scripts/lint_budget.py`
//! is a bit-exact Python mirror used to (re)generate the budget file;
//! keep the two in sync.

pub mod lexer;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use lexer::{in_spans, strip, test_regions, tokenize, Kind, Tok};

/// Schema of the `flux-lint --json` document.
pub const SCHEMA: &str = "flux-lint-v1";
/// Schema of `artifacts/lint_budget.json` (the D005 ratchet).
pub const BUDGET_SCHEMA: &str = "flux-lint-budget-v1";
/// Where the budget lives, relative to the repo root.
pub const BUDGET_PATH: &str = "artifacts/lint_budget.json";

/// One named diagnostic, for `flux list` and the README.
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    pub id: &'static str,
    pub title: &'static str,
    /// What the rule protects, one line.
    pub protects: &'static str,
}

pub const RULES: [Rule; 6] = [
    Rule {
        id: "D000",
        title: "pragma hygiene",
        protects: "allow pragmas stay well-formed and load-bearing",
    },
    Rule {
        id: "D001",
        title: "hash-order collections",
        protects: "report iteration order (BTreeMap/Vec, never Hash*)",
    },
    Rule {
        id: "D002",
        title: "float ordering",
        protects: "NaN-safe total order (f64::total_cmp everywhere)",
    },
    Rule {
        id: "D003",
        title: "wall clock",
        protects: "deterministic sections never read Instant/SystemTime",
    },
    Rule {
        id: "D004",
        title: "OS entropy",
        protects: "all randomness flows from seeded util::prng",
    },
    Rule {
        id: "D005",
        title: "panic budget",
        protects: "unwrap/expect/panic! sites only ratchet down",
    },
];

/// Rules an allow pragma may name (D000/D005 are not line-scoped).
const PRAGMA_RULES: [&str; 4] = ["D001", "D002", "D003", "D004"];

/// File-scope allowlist: D003 is legal in the bench harness, the one
/// sanctioned wall-clock source.
const D003_FILE_ALLOW: [&str; 1] = ["util/bench.rs"];

const D004_IDENTS: [&str; 7] = [
    "thread_rng",
    "ThreadRng",
    "OsRng",
    "StdRng",
    "from_entropy",
    "getrandom",
    "RandomState",
];

#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// A finding suppressed by a pragma — kept for the audit trail.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Allowed {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub reason: String,
}

/// Non-test `unwrap()`/`expect()`/`panic!` sites in one module.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanicCounts {
    pub unwrap: usize,
    pub expect: usize,
    pub panic: usize,
}

impl PanicCounts {
    pub fn total(&self) -> usize {
        self.unwrap + self.expect + self.panic
    }
}

/// The D005 ratchet: pinned per-module panic counts.
#[derive(Clone, Debug, Default)]
pub struct Budget {
    pub modules: BTreeMap<String, PanicCounts>,
}

pub struct FileScan {
    pub findings: Vec<Finding>,
    pub allowed: Vec<Allowed>,
    pub counts: PanicCounts,
}

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub allowed: Vec<Allowed>,
    /// Per-module panic sites (modules with at least one site).
    pub panic_sites: BTreeMap<String, PanicCounts>,
    /// Budget headroom per module (budget minus count, where
    /// positive) — the slack `lint_budget.json` should ratchet away.
    pub budget_slack: BTreeMap<String, PanicCounts>,
    pub files_scanned: usize,
}

struct Pragma {
    line: usize,
    /// The code line the pragma covers (`None`: nothing to cover).
    target: Option<usize>,
    rules: Vec<String>,
    reason: String,
}

fn parse_pragmas(
    comments: &[(usize, String)],
    blanked_lines: &[&str],
) -> (Vec<Pragma>, Vec<(usize, String)>) {
    let mut pragmas = Vec::new();
    let mut malformed = Vec::new();
    for (line, text) in comments {
        // Only `// flux-lint: ...` is a pragma attempt; prose mentions
        // ("flux-lint rule D003 bans ...") are ordinary comments.
        let t = text.trim();
        let Some(rest) = t.strip_prefix("flux-lint:") else {
            continue;
        };
        let parsed = parse_allow(rest.trim());
        let Some((rules, reason)) = parsed else {
            malformed.push((
                *line,
                "malformed flux-lint pragma: expected `// flux-lint: \
                 allow(D001[,D002...]) -- reason` (rules D001-D004)"
                    .to_string(),
            ));
            continue;
        };
        let code = blanked_lines.get(line - 1).copied().unwrap_or("");
        let target = if code.trim().is_empty() {
            // Standalone comment line: covers the next code line.
            blanked_lines
                .iter()
                .enumerate()
                .skip(*line)
                .find(|(_, l)| !l.trim().is_empty())
                .map(|(idx, _)| idx + 1)
        } else {
            Some(*line)
        };
        pragmas.push(Pragma { line: *line, target, rules, reason });
    }
    (pragmas, malformed)
}

fn parse_allow(rest: &str) -> Option<(Vec<String>, String)> {
    let inner_tail = rest.strip_prefix("allow(")?;
    let (inner, tail) = inner_tail.split_once(')')?;
    let rules: Vec<String> =
        inner.split(',').map(|r| r.trim().to_string()).collect();
    if rules.is_empty()
        || !rules.iter().all(|r| PRAGMA_RULES.contains(&r.as_str()))
    {
        return None;
    }
    let reason = tail.trim().strip_prefix("--")?.trim();
    if reason.is_empty() {
        return None;
    }
    Some((rules, reason.to_string()))
}

/// Scan one file. `rel` is the path relative to `rust/src` with `/`
/// separators (it selects file-scope allowlists and becomes the budget
/// module key); reported paths are prefixed `rust/src/`.
pub fn scan_source(rel: &str, text: &str) -> FileScan {
    let stripped = strip(text);
    let blanked_lines: Vec<&str> = stripped.blanked.split('\n').collect();
    let toks = tokenize(&stripped.blanked);
    let spans = test_regions(&toks);
    let (pragmas, malformed) =
        parse_pragmas(&stripped.comments, &blanked_lines);
    let path = format!("rust/src/{rel}");

    // Raw rule hits, before pragma suppression.
    let mut raw: Vec<(usize, &'static str, String)> = Vec::new();
    let mut counts = PanicCounts::default();
    for (idx, tok) in toks.iter().enumerate() {
        if tok.kind != Kind::Id {
            continue;
        }
        let prev: Option<&Tok> =
            if idx > 0 { Some(&toks[idx - 1]) } else { None };
        let next: Option<&Tok> = toks.get(idx + 1);
        let id = tok.s.as_str();
        if id == "HashMap" || id == "HashSet" {
            raw.push((
                tok.line,
                "D001",
                format!(
                    "{id} iterates in hash order; use BTreeMap/BTreeSet \
                     or a Vec so report bytes stay stable"
                ),
            ));
        } else if id == "partial_cmp"
            && !prev.is_some_and(|p| p.is_id("fn"))
        {
            raw.push((
                tok.line,
                "D002",
                "partial_cmp is not total on floats (NaN); use \
                 f64::total_cmp"
                    .to_string(),
            ));
        } else if (id == "Instant" || id == "SystemTime")
            && !D003_FILE_ALLOW.contains(&rel)
        {
            raw.push((
                tok.line,
                "D003",
                format!(
                    "std::time::{id} is wall clock; deterministic paths \
                     must route timing through util::bench (Stopwatch)"
                ),
            ));
        } else if D004_IDENTS.contains(&id) {
            raw.push((
                tok.line,
                "D004",
                format!(
                    "{id} draws OS entropy; construct RNGs via the \
                     seeded util::prng::Rng entry points"
                ),
            ));
        } else if (id == "unwrap" || id == "expect")
            && prev.is_some_and(|p| p.is_punct('.'))
            && next.is_some_and(|x| x.is_punct('('))
            && !in_spans(&spans, idx)
        {
            if id == "unwrap" {
                counts.unwrap += 1;
            } else {
                counts.expect += 1;
            }
        } else if id == "panic"
            && next.is_some_and(|x| x.is_punct('!'))
            && !in_spans(&spans, idx)
        {
            counts.panic += 1;
        }
    }

    let mut findings: Vec<Finding> = malformed
        .into_iter()
        .map(|(line, message)| Finding {
            path: path.clone(),
            line,
            rule: "D000",
            message,
        })
        .collect();
    let mut allowed = Vec::new();
    let mut used = vec![false; pragmas.len()];
    for (line, rule, message) in raw {
        let hit = pragmas.iter().position(|p| {
            p.target == Some(line) && p.rules.iter().any(|r| r == rule)
        });
        match hit {
            Some(pi) => {
                used[pi] = true;
                allowed.push(Allowed {
                    path: path.clone(),
                    line,
                    rule,
                    reason: pragmas[pi].reason.clone(),
                });
            }
            None => {
                findings.push(Finding {
                    path: path.clone(),
                    line,
                    rule,
                    message,
                });
            }
        }
    }
    for (pi, p) in pragmas.iter().enumerate() {
        if !used[pi] {
            findings.push(Finding {
                path: path.clone(),
                line: p.line,
                rule: "D000",
                message: "unused flux-lint allow pragma (suppresses \
                          nothing on its target line)"
                    .to_string(),
            });
        }
    }
    FileScan { findings, allowed, counts }
}

/// Walk `src_root` (normally `<repo>/rust/src`) and scan every `.rs`
/// file, in sorted relative-path order.
pub fn scan_tree(src_root: &Path) -> Result<Report> {
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    files.sort();
    let mut report = Report { files_scanned: files.len(), ..Default::default() };
    for (rel, path) in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let scan = scan_source(rel, &text);
        report.findings.extend(scan.findings);
        report.allowed.extend(scan.allowed);
        if scan.counts.total() > 0 {
            report.panic_sites.insert(rel.clone(), scan.counts);
        }
    }
    report.findings.sort();
    report.allowed.sort();
    Ok(report)
}

fn collect_rs(
    dir: &Path,
    base: &Path,
    out: &mut Vec<(String, PathBuf)>,
) -> Result<()> {
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("read dir {}", dir.display()))?;
    let mut entries: Vec<PathBuf> =
        rd.map(|e| Ok(e?.path())).collect::<Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, base, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(base)
                .expect("walk stays under base")
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

/// Check the D005 ratchet: every module's non-test panic count must
/// stay within `budget`; headroom is reported as slack to ratchet away.
pub fn apply_budget(report: &mut Report, budget: &Budget) {
    let mut modules: Vec<&String> = report.panic_sites.keys().collect();
    for m in budget.modules.keys() {
        if !report.panic_sites.contains_key(m) {
            modules.push(m);
        }
    }
    let mut findings = Vec::new();
    for module in modules {
        let count = report
            .panic_sites
            .get(module)
            .copied()
            .unwrap_or_default();
        let cap = budget
            .modules
            .get(module)
            .copied()
            .unwrap_or_default();
        let mut over = Vec::new();
        for (kind, have, allow) in [
            ("unwrap", count.unwrap, cap.unwrap),
            ("expect", count.expect, cap.expect),
            ("panic!", count.panic, cap.panic),
        ] {
            if have > allow {
                over.push(format!("{kind} {have} > {allow}"));
            }
        }
        let slack = PanicCounts {
            unwrap: cap.unwrap.saturating_sub(count.unwrap),
            expect: cap.expect.saturating_sub(count.expect),
            panic: cap.panic.saturating_sub(count.panic),
        };
        if !over.is_empty() {
            findings.push(Finding {
                path: format!("rust/src/{module}"),
                line: 0,
                rule: "D005",
                message: format!(
                    "panic budget exceeded: {} — remove sites; {} only \
                     ratchets down",
                    over.join(", "),
                    BUDGET_PATH
                ),
            });
        }
        if slack.total() > 0 {
            report.budget_slack.insert(module.clone(), slack);
        }
    }
    report.findings.extend(findings);
    report.findings.sort();
}

impl Budget {
    pub fn load(path: &Path) -> Result<Budget> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!(
                "read {} (the D005 panic-budget ratchet; regenerate \
                 with scripts/lint_budget.py)",
                path.display()
            )
        })?;
        Budget::parse(&text)
            .with_context(|| format!("parse {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Budget> {
        let doc = json::parse(text)?;
        let schema = doc
            .get("schema")
            .and_then(json::Value::as_str)
            .ok_or_else(|| anyhow!("budget missing \"schema\""))?;
        if schema != BUDGET_SCHEMA {
            bail!("budget schema {schema:?}, expected {BUDGET_SCHEMA:?}");
        }
        let mods = doc
            .get("modules")
            .and_then(json::Value::as_obj)
            .ok_or_else(|| anyhow!("budget missing \"modules\""))?;
        let mut modules = BTreeMap::new();
        for (module, v) in mods {
            let counts = v
                .as_obj()
                .ok_or_else(|| anyhow!("budget[{module:?}] not an object"))?;
            let mut c = PanicCounts::default();
            for (kind, n) in counts {
                let n = n.as_usize().ok_or_else(|| {
                    anyhow!("budget[{module:?}][{kind:?}] not a count")
                })?;
                match kind.as_str() {
                    "unwrap" => c.unwrap = n,
                    "expect" => c.expect = n,
                    "panic" => c.panic = n,
                    other => {
                        bail!("budget[{module:?}]: unknown kind {other:?}")
                    }
                }
            }
            modules.insert(module.clone(), c);
        }
        Ok(Budget { modules })
    }
}

/// Walk upward from `start` to the first directory containing
/// `rust/src` — the repo root, from wherever the binary is invoked.
pub fn find_root(start: &Path) -> Result<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            bail!(
                "no rust/src above {} (pass --root <repo>)",
                start.display()
            );
        }
    }
}

/// Scan the tree under `root` and, when a budget is given, check the
/// D005 ratchet against it.
pub fn run(root: &Path, budget: Option<&Budget>) -> Result<Report> {
    let mut report = scan_tree(&root.join("rust").join("src"))?;
    if let Some(b) = budget {
        apply_budget(&mut report, b);
    }
    Ok(report)
}

impl Report {
    /// The `flux-lint-v1` document: one line, keys in fixed
    /// (alphabetical) order, byte-identical across runs.
    pub fn to_json(&self) -> String {
        let mut o = String::from("{\"allowed\":[");
        for (i, a) in self.allowed.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(
                o,
                "{{\"line\":{},\"path\":{},\"reason\":{},\"rule\":{}}}",
                a.line,
                json::esc(&a.path),
                json::esc(&a.reason),
                json::esc(a.rule)
            );
        }
        o.push_str("],\"budget_slack\":");
        push_counts_map(&mut o, &self.budget_slack);
        let _ = write!(o, ",\"files_scanned\":{}", self.files_scanned);
        o.push_str(",\"findings\":[");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(
                o,
                "{{\"line\":{},\"message\":{},\"path\":{},\"rule\":{}}}",
                f.line,
                json::esc(&f.message),
                json::esc(&f.path),
                json::esc(f.rule)
            );
        }
        o.push_str("],\"panic_sites\":");
        push_counts_map(&mut o, &self.panic_sites);
        let _ = write!(o, ",\"schema\":{}}}", json::esc(SCHEMA));
        o
    }

    /// Human-readable rendering: findings (file:line, clickable),
    /// the pragma audit trail, and the ratchet state.
    pub fn render_human(&self) -> String {
        let mut o = String::new();
        for f in &self.findings {
            let _ = writeln!(
                o,
                "{} {}:{}: {}",
                f.rule, f.path, f.line, f.message
            );
        }
        for a in &self.allowed {
            let _ = writeln!(
                o,
                "allowed {} {}:{} -- {}",
                a.rule, a.path, a.line, a.reason
            );
        }
        let mut sites = PanicCounts::default();
        for c in self.panic_sites.values() {
            sites.unwrap += c.unwrap;
            sites.expect += c.expect;
            sites.panic += c.panic;
        }
        let _ = writeln!(
            o,
            "panic sites (non-test): {} across {} modules (unwrap {}, \
             expect {}, panic! {})",
            sites.total(),
            self.panic_sites.len(),
            sites.unwrap,
            sites.expect,
            sites.panic
        );
        for (module, s) in &self.budget_slack {
            let _ = writeln!(
                o,
                "budget slack: {module} (unwrap {}, expect {}, panic! \
                 {}) — ratchet {} down",
                s.unwrap, s.expect, s.panic, BUDGET_PATH
            );
        }
        if self.findings.is_empty() {
            let _ = writeln!(
                o,
                "flux-lint: clean ({} files, {} pragma-allowed)",
                self.files_scanned,
                self.allowed.len()
            );
        } else {
            let _ = writeln!(
                o,
                "flux-lint: {} finding(s) in {} files",
                self.findings.len(),
                self.files_scanned
            );
        }
        o
    }
}

fn push_counts_map(o: &mut String, map: &BTreeMap<String, PanicCounts>) {
    o.push('{');
    for (i, (module, c)) in map.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        o.push_str(&json::esc(module));
        o.push_str(":{");
        let mut first = true;
        for (kind, n) in
            [("expect", c.expect), ("panic", c.panic), ("unwrap", c.unwrap)]
        {
            if n > 0 {
                if !first {
                    o.push(',');
                }
                first = false;
                let _ = write!(o, "\"{kind}\":{n}");
            }
        }
        o.push('}');
    }
    o.push('}');
}

/// Minimal JSON reader/escaper for the budget file and the report
/// writer. flux-lint stays dependency-free (the main crate's
/// `util::json` lives on the other side of the `flux -> flux-lint`
/// edge), so it carries this ~100-line subset: objects, strings,
/// non-negative integers — everything `lint_budget.json` contains.
mod json {
    use std::collections::BTreeMap;

    use anyhow::{anyhow, bail, Result};

    #[derive(Clone, Debug)]
    pub enum Value {
        Str(String),
        Num(f64),
        Obj(BTreeMap<String, Value>),
    }

    impl Value {
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(m) => m.get(key),
                _ => None,
            }
        }

        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
            match self {
                Value::Obj(m) => Some(m),
                _ => None,
            }
        }

        pub fn as_usize(&self) -> Option<usize> {
            match self {
                Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => {
                    Some(*x as usize)
                }
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value> {
        let b = text.as_bytes();
        let mut i = 0usize;
        let v = value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            bail!("trailing JSON at byte {i}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], i: &mut usize) {
        while *i < b.len()
            && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r')
        {
            *i += 1;
        }
    }

    fn value(b: &[u8], i: &mut usize) -> Result<Value> {
        skip_ws(b, i);
        match b.get(*i) {
            Some(b'{') => {
                *i += 1;
                let mut m = BTreeMap::new();
                skip_ws(b, i);
                if b.get(*i) == Some(&b'}') {
                    *i += 1;
                    return Ok(Value::Obj(m));
                }
                loop {
                    skip_ws(b, i);
                    let k = string(b, i)?;
                    skip_ws(b, i);
                    if b.get(*i) != Some(&b':') {
                        bail!("expected ':' at byte {i}");
                    }
                    *i += 1;
                    m.insert(k, value(b, i)?);
                    skip_ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b'}') => {
                            *i += 1;
                            return Ok(Value::Obj(m));
                        }
                        _ => bail!("expected ',' or '}}' at byte {i}"),
                    }
                }
            }
            Some(b'"') => Ok(Value::Str(string(b, i)?)),
            Some(c) if c.is_ascii_digit() || *c == b'-' => {
                let start = *i;
                while *i < b.len()
                    && matches!(b[*i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    *i += 1;
                }
                let s = std::str::from_utf8(&b[start..*i])?;
                Ok(Value::Num(s.parse()?))
            }
            _ => bail!("unsupported JSON value at byte {i}"),
        }
    }

    fn string(b: &[u8], i: &mut usize) -> Result<String> {
        if b.get(*i) != Some(&b'"') {
            bail!("expected string at byte {i}");
        }
        *i += 1;
        let mut s = String::new();
        loop {
            let c = *b
                .get(*i)
                .ok_or_else(|| anyhow!("unterminated string"))?;
            *i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *b
                        .get(*i)
                        .ok_or_else(|| anyhow!("truncated escape"))?;
                    *i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        other => {
                            bail!("unsupported escape \\{}", other as char)
                        }
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the raw slice.
                    let start = *i - 1;
                    let mut end = *i;
                    while end < b.len() && (b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&b[start..end])?);
                    *i = end;
                }
            }
        }
    }

    /// JSON-escape a string, with quotes.
    pub fn esc(s: &str) -> String {
        let mut o = String::with_capacity(s.len() + 2);
        o.push('"');
        for c in s.chars() {
            match c {
                '"' => o.push_str("\\\""),
                '\\' => o.push_str("\\\\"),
                '\n' => o.push_str("\\n"),
                '\r' => o.push_str("\\r"),
                '\t' => o.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    o.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => o.push(c),
            }
        }
        o.push('"');
        o
    }
}
