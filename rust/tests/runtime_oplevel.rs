//! Op-level integration: the Pallas fused kernels (AOT → HLO text → PJRT
//! CPU) agree with the Rust numeric twins and the host-buffer reference.
//! This closes the three-layer loop: L1 kernel == L3 twin == oracle.

use flux::collectives::host::{matmul, Mat};
use flux::overlap::numeric;
use flux::runtime::{literal_f32, to_f32_vec, Runtime};
use flux::util::prng::Rng;

/// Load the runtime, or `None` when this build cannot execute PJRT
/// artifacts (in-tree xla stub / missing `make artifacts` output): the
/// kernel-vs-twin cross-checks then skip, leaving the hermetic suite to
/// the goldens + numeric-twin property tests.
fn runtime() -> Option<Runtime> {
    if !Runtime::pjrt_available() {
        eprintln!("skipping op-level PJRT test: stub xla build");
        return None;
    }
    Some(Runtime::load_default().expect("run `make artifacts` first"))
}

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, rng.normal_vec(rows * cols))
}

#[test]
fn plain_gemm_artifact_matches_host_matmul() {
    let Some(mut rt) = runtime() else { return };
    let (m, k, n) = (rt.manifest.op_m, rt.manifest.op_k, rt.manifest.op_n);
    let mut rng = Rng::new(11);
    let a = rand_mat(&mut rng, m, k);
    let b = rand_mat(&mut rng, k, n);
    let a_lit = literal_f32(&[m, k], &a.data).unwrap();
    let b_lit = literal_f32(&[k, n], &b.data).unwrap();
    let name = format!("gemm_m{m}k{k}n{n}");
    let out = rt.run(&name, &[&a_lit, &b_lit]).unwrap();
    let got = to_f32_vec(&out[0]).unwrap();
    let want = matmul(&a, &b);
    let max_diff = got
        .iter()
        .zip(&want.data)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "max diff {max_diff}");
}

#[test]
fn pallas_gemm_rs_artifacts_match_rust_twin_and_reference() {
    let Some(mut rt) = runtime() else { return };
    let man = rt.manifest.clone();
    let (n_tp, m, n) = (man.op_n_tp, man.op_m, man.op_n);
    let kl = man.op_k / n_tp;
    let block = 32;
    let mut rng = Rng::new(22);
    let a: Vec<Mat> = (0..n_tp).map(|_| rand_mat(&mut rng, m, kl)).collect();
    let b: Vec<Mat> = (0..n_tp).map(|_| rand_mat(&mut rng, kl, n)).collect();

    // Run each rank's fused Pallas kernel on PJRT: scattered outputs.
    let mut scattered_pjrt: Vec<Vec<Mat>> = Vec::new();
    for r in 0..n_tp {
        let a_lit = literal_f32(&[m, kl], &a[r].data).unwrap();
        let b_lit = literal_f32(&[kl, n], &b[r].data).unwrap();
        let out = rt
            .run(&format!("flux_gemm_rs_r{r}"), &[&a_lit, &b_lit])
            .unwrap();
        let flat = to_f32_vec(&out[0]).unwrap(); // [n_tp, m/n_tp, n]
        let per = m / n_tp;
        scattered_pjrt.push(
            (0..n_tp)
                .map(|d| {
                    Mat::from_vec(
                        per,
                        n,
                        flat[d * per * n..(d + 1) * per * n].to_vec(),
                    )
                })
                .collect(),
        );
    }

    // Rust numeric twin (same tile size, same swizzle).
    for r in 0..n_tp {
        let twin = numeric::gemm_rs_scattered(&a[r], &b[r], r, n_tp,
                                              block, true)
            .unwrap();
        for d in 0..n_tp {
            let diff = twin[d].max_abs_diff(&scattered_pjrt[r][d]);
            assert!(diff < 1e-2, "rank {r} dest {d}: twin vs pjrt {diff}");
        }
    }

    // Full pipeline: AlltoAll + local reduce == direct RS reference.
    let received = flux::collectives::host::all_to_all(&scattered_pjrt)
        .unwrap();
    let got: Vec<Mat> = received
        .iter()
        .map(|rx| flux::collectives::host::local_reduce(rx))
        .collect();
    let want = numeric::gemm_rs_reference(&a, &b).unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!(g.max_abs_diff(w) < 1e-2);
    }
}

#[test]
fn pallas_ag_gemm_artifacts_match_reference() {
    let Some(mut rt) = runtime() else { return };
    let man = rt.manifest.clone();
    let (n_tp, m, k) = (man.op_n_tp, man.op_m, man.op_k);
    let nl = man.op_n / n_tp;
    let mut rng = Rng::new(33);
    let x: Vec<Mat> = (0..n_tp)
        .map(|_| rand_mat(&mut rng, m / n_tp, k))
        .collect();
    let w: Vec<Mat> = (0..n_tp).map(|_| rand_mat(&mut rng, k, nl)).collect();

    // Host assembles the gathered buffer (the Alg. 3 loop's result).
    let gathered = flux::collectives::host::all_gather(&x).unwrap();
    for r in 0..n_tp {
        let a_lit = literal_f32(&[m, k], &gathered[r].data).unwrap();
        let w_lit = literal_f32(&[k, nl], &w[r].data).unwrap();
        let out = rt
            .run(&format!("flux_ag_gemm_r{r}"), &[&a_lit, &w_lit])
            .unwrap();
        let got = Mat::from_vec(m, nl, to_f32_vec(&out[0]).unwrap());
        let want = matmul(&gathered[r], &w[r]);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-2, "rank {r}: {diff}");
    }
}

#[test]
fn artifacts_compile_once_and_are_cached() {
    let Some(mut rt) = runtime() else { return };
    rt.ensure_compiled("gemm_m128k256n128").unwrap();
    let c1 = rt.compiled_count();
    rt.ensure_compiled("gemm_m128k256n128").unwrap();
    assert_eq!(rt.compiled_count(), c1, "second compile is a no-op");
}
