//! DES-core hardening regressions (PR 2 + PR 8 satellites) that are
//! NOT covered by the in-module unit tests: release-mode tile routing
//! (tile_dest used to guard divisibility with `debug_assert!` only),
//! the batcher fairness / mid-tick-rollback contracts, and the
//! fault-drain KV-block conservation flushed out by replica churn.
//! The NaN-ordering, Summary-convention and backpressure cases live
//! next to their code in `sim/engine.rs`, `util/stats.rs` and
//! `serving/batcher.rs`.

use flux::overlap::tiles::tile_dest;
use flux::serving::batcher::{Batcher, BatcherConfig, Work};
use flux::serving::kvcache::KvCacheManager;
use flux::serving::{Request, RequestState};

// -- overlap/tiles.rs: release-mode tile routing --------------------------

#[test]
fn tile_dest_routes_evenly_divided_grids() {
    // 32 row-tiles over 8 ranks: 4 per rank, block layout.
    for t in 0..32 {
        assert_eq!(tile_dest(t, 32, 8), t / 4);
    }
}

#[test]
#[should_panic(expected = "not divisible")]
fn tile_dest_rejects_indivisible_grids() {
    // 10 tiles over 4 ranks used to silently mis-route tiles in release
    // builds (debug_assert only); now it is a hard error everywhere.
    tile_dest(9, 10, 4);
}

#[test]
#[should_panic(expected = ">= grid")]
fn tile_dest_rejects_out_of_range_tiles() {
    tile_dest(32, 32, 8);
}

// -- serving/batcher.rs: fairness + mid-tick admission failure ------------

fn req(id: u64, prompt_len: usize, new_tokens: usize) -> Request {
    Request::new(id, 0.0, vec![1; prompt_len], new_tokens)
}

#[test]
fn decode_round_robin_never_starves_past_the_cap() {
    // 5 running requests, decode cap 2: every request must be served
    // within a bounded number of steps of every other (spread <= 1
    // among still-running requests at all times).
    let mut b = Batcher::new(BatcherConfig {
        max_prefill_batch: 8,
        max_decode_batch: 2,
        max_prompt: 64,
        max_seq: 128,
        ..Default::default()
    });
    let mut kv = KvCacheManager::new(64, 16);
    let n = 5u64;
    let gen = 6usize;
    for i in 0..n {
        b.submit(req(i, 4, gen));
    }
    match b.next_work(&mut kv).unwrap() {
        Work::Prefill(ids) => assert_eq!(ids.len(), n as usize),
        w => panic!("expected prefill, got {w:?}"),
    }
    let mut served = vec![0usize; n as usize];
    let mut steps = 0;
    loop {
        match b.next_work(&mut kv).unwrap() {
            Work::Decode(ids) => {
                assert!(ids.len() <= 2, "cap respected");
                for &id in &ids {
                    served[id as usize] += 1;
                }
                let toks: Vec<i32> = ids.iter().map(|_| 1).collect();
                b.complete_decode(&ids, &toks, &mut kv, steps as f64)
                    .unwrap();
                // Fairness invariant among still-running requests.
                let live: Vec<usize> = (0..n as usize)
                    .filter(|&i| served[i] < gen)
                    .map(|i| served[i])
                    .collect();
                if let (Some(&mx), Some(&mn)) =
                    (live.iter().max(), live.iter().min())
                {
                    assert!(
                        mx - mn <= 1,
                        "starvation: served={served:?} at step {steps}"
                    );
                }
            }
            Work::Idle => break,
            w => panic!("unexpected work {w:?}"),
        }
        steps += 1;
        assert!(steps < 1000, "did not converge");
    }
    assert!(b.all_done());
    assert!(served.iter().all(|&s| s == gen), "served={served:?}");
}

#[test]
fn mid_tick_admission_failure_leaks_nothing() {
    // An out-of-band KV resident under a queued request's id makes
    // `kv.admit` fail AFTER `can_admit` passed — mid-tick. The batcher
    // must roll the whole tick back: the error is surfaced, every
    // request admitted earlier in the tick returns to the queue in its
    // original position, and no queue slot or KV block is stranded.
    let mut b = Batcher::new(BatcherConfig::default());
    let mut kv = KvCacheManager::new(32, 16);
    b.submit(req(0, 16, 2));
    b.submit(req(1, 16, 2));
    b.submit(req(2, 16, 2));
    // Simulate the foreign resident (e.g. a stale sequence never
    // released by a crashed engine).
    kv.admit(1, 16).unwrap();
    let foreign_blocks = kv.used_blocks();

    let err = b.next_work(&mut kv).unwrap_err();
    assert!(
        format!("{err:#}").contains("admitting request 1"),
        "error names the request: {err:#}"
    );
    // The tick rolled back: nothing running, all three still queued,
    // only the foreign resident holds blocks.
    assert_eq!(b.running(), 0);
    assert_eq!(b.queued(), 3);
    assert_eq!(kv.used_blocks(), foreign_blocks);
    kv.check_invariants().unwrap();

    // Recovery: drop the foreign resident; the next tick admits all
    // three in order and the batcher drains normally — nothing lost.
    kv.release(1).unwrap();
    assert_eq!(
        b.next_work(&mut kv).unwrap(),
        Work::Prefill(vec![0, 1, 2])
    );
    assert_eq!(b.running(), 3);
    let fin = b
        .complete_decode(&[0, 1, 2], &[9, 9, 9], &mut kv, 1.0)
        .unwrap();
    assert!(fin.is_empty());
    kv.check_invariants().unwrap();
}

// -- serving: fault-drain KV conservation (PR 8) --------------------------

#[test]
fn drain_releases_every_kv_block_and_fails_the_requests() {
    // A replica kill drains queue + running. Running requests hold KV
    // blocks; a drain that forgot to release them leaked the pool, so
    // a restarted replica ran out of blocks after a few churn cycles.
    // Every block must return to the free list and the same
    // batcher+pool must serve fresh work afterwards.
    let mut b = Batcher::new(BatcherConfig::default());
    let mut kv = KvCacheManager::new(32, 16);
    b.submit(req(0, 16, 4));
    b.submit(req(1, 16, 4));
    b.submit(req(2, 16, 4));
    match b.next_work(&mut kv).unwrap() {
        Work::Prefill(ids) => assert_eq!(ids.len(), 3),
        w => panic!("expected prefill, got {w:?}"),
    }
    assert!(kv.used_blocks() > 0, "running requests hold blocks");

    let drained = b.drain(&mut kv).unwrap();
    assert_eq!(drained, vec![0, 1, 2]);
    assert_eq!(kv.used_blocks(), 0, "kv blocks leaked on drain");
    kv.check_invariants().unwrap();
    assert!(b.all_done());
    for &id in &drained {
        assert_eq!(
            b.requests[id as usize].state,
            RequestState::Failed,
            "drained request {id} not marked failed"
        );
    }

    // Restart reuse: the replica rejoins with the same pool and the
    // next request admits, decodes and retires cleanly.
    b.submit(req(3, 16, 1));
    assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![3]));
    let fin = b.complete_decode(&[3], &[9], &mut kv, 2.0).unwrap();
    assert_eq!(fin, vec![3]);
    assert_eq!(kv.used_blocks(), 0);
    kv.check_invariants().unwrap();
}

#[test]
fn replica_churn_conserves_requests_end_to_end() {
    // Full-intensity replica churn on the 4-node H800 cluster: every
    // request must end either completed or failed — none lost in a
    // drained batcher, none double-counted after restart — and the
    // SLO report must have observed all of them.
    use flux::cost::arch::SCALE_H800_TP8_DP4;
    use flux::faults::FaultSpec;
    use flux::overlap::Method;
    use flux::serving::scale::{run_scale_faulted, ScaleScenario};

    let sc = ScaleScenario::quick(&SCALE_H800_TP8_DP4);
    let n = sc.workload.requests_per_replica * sc.topo.dp;
    let tl = FaultSpec::resolve("replica-churn")
        .unwrap()
        .expand(sc.topo.dp, 1.0);
    for m in [Method::NonOverlap, Method::Flux] {
        let rep = run_scale_faulted(&sc, m, &tl).unwrap();
        assert_eq!(
            rep.completed + rep.failed,
            n,
            "{m:?}: requests lost or duplicated"
        );
        assert!(rep.failed > 0, "{m:?}: full-intensity churn is lossy");
        let slo = rep.slo.as_ref().expect("preset carries an SLO");
        assert_eq!(slo.requests, n, "{m:?}: SLO missed requests");
    }
}
