//! Cross-language goldens: the Rust tile bookkeeping must agree exactly
//! with the Python kernels' (artifacts/golden_swizzle.json, emitted by
//! aot.py from the same functions the Pallas kernels use for their
//! BlockSpec index maps).

use flux::overlap::tiles;
use flux::runtime::Runtime;
use flux::util::json::Json;

fn golden() -> Json {
    let path = Runtime::artifacts_dir().join("golden_swizzle.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{}: {e}\nthe golden file ships with the repo; if it is \
             missing, regenerate it with `make artifacts` (prefers the \
             JAX exporter, falls back to the hermetic Rust generator) \
             or directly with `cargo run --bin flux -- gen-goldens`",
            path.display()
        )
    });
    Json::parse(&text).expect("golden json parses")
}

#[test]
fn swizzle_order_matches_python() {
    let g = golden();
    let cases = g.get("swizzle").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for c in cases {
        let num = c.get("num_tiles").unwrap().as_usize().unwrap();
        let rank = c.get("rank").unwrap().as_usize().unwrap();
        let n_tp = c.get("n_tp").unwrap().as_usize().unwrap();
        let want = c.get("order").unwrap().usize_vec().unwrap();
        assert_eq!(
            tiles::swizzle_order(num, rank, n_tp),
            want,
            "swizzle({num}, {rank}, {n_tp})"
        );
    }
}

#[test]
fn ring_order_matches_python() {
    let g = golden();
    for c in g.get("ring").unwrap().as_arr().unwrap() {
        let rank = c.get("rank").unwrap().as_usize().unwrap();
        let n_tp = c.get("n_tp").unwrap().as_usize().unwrap();
        let want = c.get("order").unwrap().usize_vec().unwrap();
        assert_eq!(tiles::ring_comm_order(rank, n_tp), want);
    }
}

#[test]
fn comm_schedule_matches_python() {
    let g = golden();
    let cases = g.get("comm_sched").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for c in cases {
        let m = c.get("m").unwrap().as_usize().unwrap();
        let rank = c.get("rank").unwrap().as_usize().unwrap();
        let n_tp = c.get("n_tp").unwrap().as_usize().unwrap();
        let rows = c.get("rows").unwrap().as_usize().unwrap();
        let want = c.get("schedule").unwrap().as_arr().unwrap();
        let got = tiles::comm_schedule(m, rank, n_tp, rows, true);
        assert_eq!(got.len(), want.len());
        for (g_t, w) in got.iter().zip(want) {
            assert_eq!(g_t.src, w.get("src").unwrap().as_usize().unwrap());
            assert_eq!(g_t.dst, w.get("dst").unwrap().as_usize().unwrap());
            assert_eq!(
                g_t.row0,
                w.get("row0").unwrap().as_usize().unwrap()
            );
            assert_eq!(g_t.rows, w.get("rows").unwrap().as_usize().unwrap());
            assert_eq!(
                g_t.signal,
                w.get("signal").unwrap().as_usize().unwrap()
            );
        }
    }
}
