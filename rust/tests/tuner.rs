//! Tuner coverage: search-space determinism, tune seed-stability and
//! the TunerCache hit/miss contract (previously untested).

use flux::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};
use flux::overlap::flux::simulate as flux_sim;
use flux::overlap::Problem;
use flux::tuner::{search_space, tune, TunerCache};

fn probe_problems() -> Vec<Problem> {
    vec![
        Problem::ag(2048, 49152, 12288, 8),
        Problem::rs(2048, 12288, 49152, 8),
        Problem::ag(512, 49152, 12288, 4),
    ]
}

#[test]
fn search_space_is_deterministic_across_calls() {
    // The §4.4 space must enumerate identically on every call: the
    // tuner's reproducibility (and the byte-stable reports downstream)
    // depend on candidate order never changing.
    for p in probe_problems() {
        for cl in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
            let a = search_space(cl, &p);
            let b = search_space(cl, &p);
            assert!(!a.is_empty(), "{} {}", cl.name, p.op.name());
            assert_eq!(a, b, "{} {}: space drifted", cl.name, p.op.name());
        }
    }
}

#[test]
fn rs_space_pins_comm_rows_ag_space_ladders_them() {
    // RS communication granularity IS the GEMM tile (comm_rows == 0);
    // AG searches the halving ladder.
    let rs = search_space(&A100_NVLINK, &Problem::rs(2048, 12288, 49152, 8));
    assert!(rs.iter().all(|c| c.comm_rows == 0));
    let ag = search_space(&A100_NVLINK, &Problem::ag(2048, 49152, 12288, 8));
    let sizes: std::collections::BTreeSet<usize> =
        ag.iter().map(|c| c.comm_rows).collect();
    assert!(sizes.len() > 1, "AG ladder collapsed: {sizes:?}");
}

#[test]
fn tune_is_seed_stable() {
    // Same seed: identical winning config and timing. The winner must
    // also reproduce when re-simulated with its own config — i.e. the
    // reported timing is an evaluation, not a stale copy.
    for p in probe_problems() {
        for cl in [&A100_PCIE, &A100_NVLINK] {
            let a = tune(cl, &p, 7);
            let b = tune(cl, &p, 7);
            assert_eq!(a.config, b.config, "{} {}", cl.name, p.op.name());
            assert_eq!(a.timing.overall_ns, b.timing.overall_ns);
            assert_eq!(a.candidates_tried, search_space(cl, &p).len());
            let replay = flux_sim(cl, &p, &a.config, 7);
            assert_eq!(a.timing.overall_ns, replay.overall_ns);
        }
    }
}

#[test]
fn cache_is_keyed_by_shape_not_seed() {
    // The cache key is (cluster, op, shape): a lookup with a different
    // seed must HIT — the same semantics as a GEMM library's algorithm
    // cache, and what keeps serving loops from re-tuning per request.
    let mut c = TunerCache::new();
    assert!(c.is_empty());
    let p = Problem::ag(1024, 49152, 12288, 8);
    let first = c.get(&A100_NVLINK, &p, 7);
    assert_eq!((c.misses, c.hits, c.len()), (1, 0, 1));
    let again = c.get(&A100_NVLINK, &p, 999);
    assert_eq!((c.misses, c.hits), (1, 1), "seed must not key the cache");
    assert_eq!(first.config, again.config);
    assert!(!c.is_empty());
}

#[test]
fn cache_misses_on_every_key_dimension() {
    let mut c = TunerCache::new();
    let p = Problem::ag(1024, 49152, 12288, 8);
    c.get(&A100_NVLINK, &p, 7);
    // Different cluster.
    c.get(&A100_PCIE, &p, 7);
    assert_eq!(c.misses, 2);
    // Different op (same m/n_tp, n and k swapped as in the dgrad pair).
    c.get(&A100_NVLINK, &Problem::rs(1024, 12288, 49152, 8), 7);
    assert_eq!(c.misses, 3);
    // Different TP degree.
    c.get(&A100_NVLINK, &Problem::ag(1024, 49152, 12288, 4), 7);
    assert_eq!(c.misses, 4);
    assert_eq!(c.len(), 4);
    assert_eq!(c.hits, 0);
    // Every prior key still hits.
    c.get(&A100_PCIE, &p, 7);
    assert_eq!((c.misses, c.hits), (4, 1));
}
