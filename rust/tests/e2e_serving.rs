//! End-to-end serving integration: the Rust coordinator running the
//! tiny TP transformer's per-rank PJRT artifacts must reproduce the
//! full (un-sharded) JAX model bit-for-tolerance — prefill against the
//! Python golden, decode against prefill-extension consistency, and the
//! whole thing driven through the batcher like a real request loop.

use flux::runtime::Runtime;
use flux::serving::engine::{argmax, Engine};
use flux::serving::kvcache::KvCacheManager;
use flux::serving::{Batcher, BatcherConfig, Request};
use flux::util::json::Json;

/// Build the engine, or `None` when this build cannot execute PJRT
/// artifacts: the hermetic checkout links the in-tree xla API stub (no
/// backend) and only ships the golden file, not the AOT artifacts.
/// The tests then skip — they cover the real-numerics path, which needs
/// `make artifacts` plus the real xla bindings.
fn engine() -> Option<Engine> {
    if !Runtime::pjrt_available() {
        eprintln!("skipping e2e serving test: stub xla build, no PJRT");
        return None;
    }
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    Some(Engine::new(rt).expect("engine init"))
}

fn golden_prefill() -> (Vec<Vec<i32>>, Vec<usize>, Vec<Vec<f32>>) {
    let path = Runtime::artifacts_dir().join("golden_swizzle.json");
    let g = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let p = g.get("prefill").unwrap();
    let ids: Vec<Vec<i32>> = p
        .get("ids").unwrap().as_arr().unwrap()
        .iter()
        .map(|row| {
            row.as_arr().unwrap().iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect()
        })
        .collect();
    let lens: Vec<usize> = p
        .get("lens").unwrap().usize_vec().unwrap();
    let logits: Vec<Vec<f32>> = p
        .get("last_logits").unwrap().as_arr().unwrap()
        .iter()
        .map(|row| {
            row.as_arr().unwrap().iter()
                .map(|v| v.as_f64().unwrap() as f32)
                .collect()
        })
        .collect();
    (ids, lens, logits)
}

#[test]
fn prefill_matches_python_full_model_golden() {
    let Some(mut eng) = engine() else { return };
    let (ids, lens, want) = golden_prefill();
    let prompts: Vec<Vec<i32>> = ids
        .iter()
        .zip(&lens)
        .map(|(row, &l)| row[..l].to_vec())
        .collect();
    let got = eng.prefill(&prompts).unwrap();
    for (b, (g, w)) in got.iter().zip(&want).enumerate() {
        let max_diff = g
            .iter()
            .zip(w.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "seq {b}: max logit diff {max_diff}");
        // Greedy tokens agree exactly.
        assert_eq!(
            argmax(g),
            argmax(w),
            "seq {b}: greedy token mismatch"
        );
    }
}

#[test]
fn decode_equals_prefill_extension() {
    // Prefill s tokens then decode token s+1 must equal prefilling all
    // s+1 tokens — the KV-cache correctness invariant, now across the
    // full Rust+PJRT path.
    let Some(mut eng) = engine() else { return };
    let s = 12usize;
    let vocab = eng.vocab as i32;
    let prompts: Vec<Vec<i32>> = (0..eng.b)
        .map(|i| {
            (0..=s).map(|t| ((7 + i * 31 + t * 13) as i32) % vocab).collect()
        })
        .collect();
    // Reference: prefill all s+1 tokens.
    let full = eng.prefill(&prompts).unwrap();
    // Candidate: prefill s tokens, then decode the last one.
    let shorter: Vec<Vec<i32>> =
        prompts.iter().map(|p| p[..s].to_vec()).collect();
    eng.prefill(&shorter).unwrap();
    let last_tokens: Vec<i32> = prompts.iter().map(|p| p[s]).collect();
    let stepped = eng.decode_step(&last_tokens).unwrap();
    for b in 0..eng.b {
        let max_diff = full[b]
            .iter()
            .zip(&stepped[b])
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "seq {b}: diff {max_diff}");
    }
}

#[test]
fn greedy_generation_is_deterministic() {
    let Some(mut eng) = engine() else { return };
    let prompts = vec![vec![3, 1, 4, 1, 5], vec![2, 7, 1, 8]];
    let gen = |eng: &mut Engine| -> Vec<Vec<i32>> {
        let logits = eng.prefill(&prompts).unwrap();
        let mut toks: Vec<i32> =
            logits.iter().map(|l| argmax(l)).collect();
        let mut out: Vec<Vec<i32>> = toks.iter().map(|&t| vec![t]).collect();
        for _ in 0..4 {
            let l = eng.decode_step(&toks).unwrap();
            toks = l.iter().map(|x| argmax(x)).collect();
            for (o, &t) in out.iter_mut().zip(&toks) {
                o.push(t);
            }
        }
        out
    };
    let a = gen(&mut eng);
    let b = gen(&mut eng);
    assert_eq!(a, b, "same prompts, same tokens");
    assert!(a[0].iter().all(|&t| t >= 0 && (t as usize) < eng.vocab));
}

#[test]
fn batcher_driven_serving_loop_completes() {
    // The full coordinator shape: requests -> batcher -> engine ->
    // tokens, with KV accounting. This is the integration the
    // examples/serve_e2e.rs driver packages up.
    let Some(mut eng) = engine() else { return };
    let mut batcher = Batcher::new(BatcherConfig {
        max_prefill_batch: eng.b,
        max_decode_batch: eng.b,
        max_prompt: eng.s,
        max_seq: eng.smax,
        ..Default::default()
    });
    let mut kv = KvCacheManager::new(64, 16);
    for i in 0..3u64 {
        batcher.submit(Request::new(
            i,
            0.0,
            vec![(i as i32) * 3 + 1, 5, 9],
            3,
        ));
    }
    let mut last_tok: Vec<i32> = vec![0; eng.b];
    let mut slot_of: std::collections::BTreeMap<u64, usize> =
        Default::default();
    loop {
        match batcher.next_work(&mut kv).unwrap() {
            flux::serving::batcher::Work::Prefill(ids) => {
                let prompts: Vec<Vec<i32>> = ids
                    .iter()
                    .map(|&id| batcher.get(id).prompt.clone())
                    .collect();
                let logits = eng.prefill(&prompts).unwrap();
                for (slot, &id) in ids.iter().enumerate() {
                    slot_of.insert(id, slot);
                    last_tok[slot] = argmax(&logits[slot]);
                }
                let toks: Vec<i32> =
                    ids.iter().map(|&id| last_tok[slot_of[&id]]).collect();
                batcher
                    .complete_decode(&ids, &toks, &mut kv, 1.0)
                    .unwrap();
            }
            flux::serving::batcher::Work::Decode(ids) => {
                let logits = eng.decode_step(&last_tok).unwrap();
                let mut toks = Vec::new();
                for &id in &ids {
                    let slot = slot_of[&id];
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                }
                batcher
                    .complete_decode(&ids, &toks, &mut kv, 2.0)
                    .unwrap();
            }
            flux::serving::batcher::Work::Idle => break,
        }
    }
    assert!(batcher.all_done());
    for i in 0..3u64 {
        let r = batcher.get(i);
        assert_eq!(r.generated.len(), 3, "request {i} finished");
    }
    kv.check_invariants().unwrap();
}
