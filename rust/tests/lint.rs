//! flux-lint at the repo surface: the real tree is clean under the
//! checked-in panic budget, the pragma audit trail matches the
//! documented exceptions, and the `flux lint` subcommand is byte-stable
//! across runs.

use std::path::Path;
use std::process::Command;

fn repo_root() -> std::path::PathBuf {
    flux_lint::find_root(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap()
}

fn lint_report() -> flux_lint::Report {
    let root = repo_root();
    let budget =
        flux_lint::Budget::load(&root.join(flux_lint::BUDGET_PATH))
            .expect("the panic budget is checked in");
    flux_lint::run(&root, Some(&budget)).unwrap()
}

#[test]
fn the_tree_is_clean_under_the_checked_in_budget() {
    let report = lint_report();
    assert!(
        report.findings.is_empty(),
        "determinism findings in rust/src:\n{}",
        report.render_human()
    );
    assert!(report.files_scanned > 30, "the walk saw the whole tree");
}

#[test]
fn every_allowed_exception_carries_its_documented_reason() {
    // The pragma audit trail is part of the lint contract: exceptions
    // are enumerable, not scattered. Today there is exactly one — the
    // DES queue comparator, whose inputs admit() has already vetted.
    let report = lint_report();
    let allowed: Vec<(&str, &str)> = report
        .allowed
        .iter()
        .map(|a| (a.path.as_str(), a.rule))
        .collect();
    assert_eq!(allowed, vec![("rust/src/sim/engine.rs", "D002")]);
    assert!(report.allowed[0].reason.contains("admit()"));
}

#[test]
fn budget_has_no_slack_at_head() {
    // The ratchet invariant: the checked-in budget is exactly the
    // current count, never looser. Slack appears when panic sites are
    // removed without regenerating artifacts/lint_budget.json
    // (scripts/lint_budget.py).
    let report = lint_report();
    assert!(
        report.budget_slack.is_empty(),
        "ratchet {} down: {:?}",
        flux_lint::BUDGET_PATH,
        report.budget_slack.keys().collect::<Vec<_>>()
    );
}

#[test]
fn flux_lint_cli_is_byte_stable_and_clean() {
    let run = || {
        Command::new(env!("CARGO_BIN_EXE_flux"))
            .args(["lint", "--json"])
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert!(
        a.status.success(),
        "flux lint found violations:\n{}{}",
        String::from_utf8_lossy(&a.stdout),
        String::from_utf8_lossy(&a.stderr)
    );
    assert_eq!(a.stdout, b.stdout, "lint --json must be byte-stable");
    let text = String::from_utf8_lossy(&a.stdout);
    assert!(text.contains("\"schema\":\"flux-lint-v1\""));
    assert!(text.contains("\"findings\":[]"));

    // Human mode exits zero and reports the clean state.
    let out = Command::new(env!("CARGO_BIN_EXE_flux"))
        .arg("lint")
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout)
        .contains("flux-lint: clean"));
}

#[test]
fn cli_json_matches_the_library_report() {
    // The subcommand is a thin veneer: its bytes are the library's.
    let out = Command::new(env!("CARGO_BIN_EXE_flux"))
        .args(["lint", "--json"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success());
    let cli = String::from_utf8(out.stdout).unwrap();
    assert_eq!(cli.trim_end(), lint_report().to_json());
}
