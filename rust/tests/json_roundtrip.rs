//! util::json round-trip coverage on the real document schemas this
//! repo ships (the cross-language golden file and the bench report),
//! plus escape/number edge cases. The writer/parser pair is the only
//! JSON implementation in the tree — goldens, manifests and the perf
//! trajectory all ride on it, so parse → write → parse must be lossless.

use flux::util::json::Json;

fn round_trip(doc: &Json) -> Json {
    Json::parse(&doc.to_string()).unwrap()
}

#[test]
fn golden_schema_round_trips() {
    let doc = flux::goldens::golden_doc();
    let rt = round_trip(&doc);
    assert_eq!(rt, doc);
    // And the writer is stable: writing the re-parsed doc is identical.
    assert_eq!(rt.to_string(), doc.to_string());
}

#[test]
fn checked_in_golden_file_round_trips() {
    let path = flux::runtime::Runtime::artifacts_dir()
        .join("golden_swizzle.json");
    let text = std::fs::read_to_string(&path)
        .expect("golden_swizzle.json ships with the repo");
    let doc = Json::parse(&text).unwrap();
    let rt = round_trip(&doc);
    assert_eq!(rt, doc);
}

#[test]
fn bench_schema_round_trips() {
    let doc = flux::report::bench_doc(true);
    let rt = round_trip(&doc);
    assert_eq!(rt, doc);
    assert_eq!(rt.to_string(), doc.to_string());
}

#[test]
fn string_escape_edge_cases() {
    for s in [
        "plain",
        "quote\"inside",
        "back\\slash",
        "new\nline and \t tab and \r cr",
        "control\u{1}\u{1f}chars",
        "null byte \u{0} embedded",
        "unicode: héllo wörld — ≤96% ✓",
        "emoji 🚀 (outside the BMP, raw UTF-8)",
        "",
    ] {
        let doc = Json::Str(s.to_string());
        let text = doc.to_string();
        assert_eq!(
            Json::parse(&text).unwrap(),
            doc,
            "string {s:?} via {text:?}"
        );
        // Escaped controls must not appear raw in the output.
        assert!(!text.contains('\n') && !text.contains('\u{1}'));
    }
}

#[test]
fn unicode_escape_parsing() {
    assert_eq!(
        Json::parse(r#""\u0041\u00e9""#).unwrap(),
        Json::Str("Aé".to_string())
    );
}

#[test]
fn number_edge_cases() {
    for (text, want) in [
        ("0", 0.0),
        ("-0", 0.0),
        ("9007199254740992", 9007199254740992.0), // 2^53
        ("-12.5", -12.5),
        ("1e300", 1e300),
        ("2.5e-10", 2.5e-10),
        ("0.1", 0.1),
    ] {
        let v = Json::parse(text).unwrap();
        assert_eq!(v.as_f64().unwrap(), want, "parse {text}");
        // Write → parse is exact for every representable f64.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v2, v, "round trip {text}");
    }
    // Integer-valued floats print without a fractional part (schema
    // stability for ids/counts), big magnitudes keep full precision.
    assert_eq!(Json::Num(42.0).to_string(), "42");
    assert_eq!(Json::Num(-3.0).to_string(), "-3");
    let big = Json::Num(1.23456789e120);
    assert_eq!(Json::parse(&big.to_string()).unwrap(), big);
}

#[test]
fn nested_mixed_document_round_trips() {
    use flux::util::json::obj;
    let doc = obj(vec![
        ("empty_arr", Json::Arr(vec![])),
        ("empty_obj", Json::Obj(Default::default())),
        ("null", Json::Null),
        ("bools", Json::from(vec![true, false])),
        (
            "mixed",
            Json::Arr(vec![
                Json::from(1usize),
                Json::from("two"),
                Json::Null,
                Json::from(3.5),
            ]),
        ),
        ("weird key \" \\ \n", Json::from("value")),
    ]);
    assert_eq!(round_trip(&doc), doc);
}

#[test]
fn rejects_malformed_documents() {
    for s in [
        "",
        "{",
        "[1,",
        "{\"a\" 1}",
        "tru",
        "1 2",
        "\"unterminated",
        "{\"dup\": }",
        "[01x]",
        "\"bad escape \\q\"",
    ] {
        assert!(Json::parse(s).is_err(), "should reject {s:?}");
    }
}
