//! Differential property tests pinning the calendar [`EventQueue`] to
//! the reference [`HeapEventQueue`] (the pre-calendar `BinaryHeap`
//! semantics): identical streams must drain pop-for-pop identically —
//! exact ties, `-0.0`, bucket-boundary times, far-future jumps and
//! interleaved schedule/pop included — plus the streaming-vs-collecting
//! [`Summary`] equivalence on pinned seeds. Together these are the
//! proof obligation of the engine rewrite: same results, only faster.

use flux::sim::engine::{
    hold_workload, hold_workload_heap, EventQueue, HeapEventQueue,
};
use flux::util::propcheck::{
    f64_in, forall_gen, map, one_of, usize_in, vec_of, zip,
};
use flux::util::stats::{Streaming, Summary};

/// Pop both queues to exhaustion, requiring identical `(time, payload)`
/// sequences and identical clock positions at every step.
fn drain_compare(cal: &mut EventQueue<usize>, heap: &mut HeapEventQueue<usize>) {
    loop {
        let a = cal.next();
        let b = heap.next();
        assert_eq!(a, b, "pop diverged (calendar vs heap)");
        if a.is_none() {
            break;
        }
        assert_eq!(cal.now(), heap.now(), "clock diverged");
    }
}

/// Event times mixing exact-tie lattices at several magnitudes, zeros of
/// both signs, continuous draws and far-future outliers (which push the
/// calendar through its overflow/rebuild path).
fn adversarial_times() -> impl Fn(&mut flux::util::prng::Rng) -> Vec<f64> {
    vec_of(
        usize_in(1, 120),
        map(
            zip(
                zip(usize_in(0, 10), one_of(vec![1.0, 1.0e3, 1.0e9])),
                f64_in(0.0, 100.0),
            ),
            |((kind, scale), x)| match kind {
                0 | 1 | 2 => (x / 10.0).floor() * 10.0 * scale,
                3 => 0.0,
                4 => -0.0,
                5 => x * scale * 1.0e6,
                _ => x * scale,
            },
        ),
    )
}

#[test]
fn batch_drain_is_identical_to_heap() {
    forall_gen(96, 0xD1F_0001, adversarial_times(), |times| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            cal.schedule(t, i);
            heap.schedule(t, i);
        }
        assert_eq!(cal.len(), heap.len());
        drain_compare(&mut cal, &mut heap);
    });
}

#[test]
fn interleaved_schedule_and_pop_is_identical_to_heap() {
    // Open-loop usage: delays relative to the moving clock, including
    // zero delays (exact ties at `now`), tie lattices and huge jumps,
    // with pops mixed in — the access pattern of the serving/training
    // sims.
    let gen = vec_of(
        usize_in(1, 150),
        zip(usize_in(0, 5), f64_in(0.0, 50.0)),
    );
    forall_gen(96, 0xD1F_0002, gen, |ops| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        let mut i = 0usize;
        for &(kind, x) in ops {
            match kind {
                0 | 1 => {
                    let a = cal.next();
                    let b = heap.next();
                    assert_eq!(a, b, "interleaved pop diverged");
                }
                2 => {
                    let d = (x / 10.0).floor() * 10.0;
                    cal.schedule_in(d, i);
                    heap.schedule_in(d, i);
                    i += 1;
                }
                3 => {
                    cal.schedule_in(x * 1.0e7, i);
                    heap.schedule_in(x * 1.0e7, i);
                    i += 1;
                }
                _ => {
                    cal.schedule_in(x, i);
                    heap.schedule_in(x, i);
                    i += 1;
                }
            }
        }
        drain_compare(&mut cal, &mut heap);
    });
}

#[test]
fn bucket_boundary_times_are_identical_to_heap() {
    // Aim events at the *exact* edges of the calendar's live buckets
    // (where a `(at - start) / width` rounding slip would misfile an
    // event), after enough random traffic to force grow rebuilds.
    let gen = vec_of(usize_in(40, 200), f64_in(0.0, 1000.0));
    forall_gen(48, 0xD1F_0003, gen, |times| {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            cal.schedule(t, i);
            heap.schedule(t, i);
        }
        let (start, width, nb) = cal.bucket_params();
        let mut i = times.len();
        for k in 0..(2 * nb + 3) {
            let t = start + width * k as f64;
            if t.is_finite() && t >= cal.now() {
                cal.schedule(t, i);
                heap.schedule(t, i);
                i += 1;
            }
            if k % 7 == 0 {
                assert_eq!(cal.next(), heap.next(), "boundary pop");
            }
        }
        drain_compare(&mut cal, &mut heap);
    });
}

#[test]
fn hold_workload_counters_and_checksums_match_heap() {
    // The bench workload itself, across sizes: the pop-sequence
    // checksum certifies identical order without storing the sequence.
    let gen = zip(usize_in(1, 400), usize_in(0, 3000));
    forall_gen(12, 0xD1F_0004, gen, |&(resident, ops)| {
        let seed = (resident * 31 + ops) as u64;
        let a = hold_workload(resident, ops, seed);
        let b = hold_workload_heap(resident, ops, seed);
        assert_eq!(a.checksum, b.checksum, "pop sequences diverged");
        assert_eq!(a.pops, b.pops);
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.pops, (resident + ops) as u64, "hold conservation");
    });
}

#[test]
fn past_float_sliver_clamps_identically() {
    // The admission bugfix, differentially: an event in the 1e-9 float
    // noise sliver below `now` fires *at* `now` in both queues (it used
    // to rewind the clock), and the clamped event still ties FIFO
    // against one scheduled exactly at `now`.
    let mut cal = EventQueue::new();
    let mut heap = HeapEventQueue::new();
    cal.schedule(10.0, 0);
    heap.schedule(10.0, 0);
    assert_eq!(cal.next(), heap.next());
    cal.schedule(10.0 - 1e-10, 1);
    heap.schedule(10.0 - 1e-10, 1);
    cal.schedule(10.0, 2);
    heap.schedule(10.0, 2);
    drain_compare(&mut cal, &mut heap);
    assert_eq!(cal.now(), 10.0, "clock must not rewind");
}

#[test]
fn streaming_summary_equals_collecting_on_pinned_seeds() {
    // Push-at-a-time must reproduce collect-then-summarize *bit for
    // bit* — the guarantee that lets the serving report switch to
    // streaming accumulators without moving a single pinned f64.
    let gen = vec_of(usize_in(1, 300), f64_in(-1.0e12, 1.0e12));
    forall_gen(128, 0xD1F_0005, gen, |xs| {
        let mut acc = Streaming::with_capacity(xs.len());
        for &x in xs {
            acc.push(x);
        }
        let a = acc.finalize();
        let b = Summary::of(xs);
        assert_eq!(a, b);
        assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "mean drifted");
        assert_eq!(a.std.to_bits(), b.std.to_bits(), "std drifted");
        assert_eq!(a.p99.to_bits(), b.p99.to_bits(), "p99 drifted");
    });
}
