//! CLI smoke tests: the `flux` binary's subcommands and every example
//! run to completion in debug mode. These guard the user-facing entry
//! points the README quickstart advertises.

use std::path::PathBuf;
use std::process::Command;

fn flux_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_flux"))
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("flux_cli_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_exits_zero_and_lists_subcommands() {
    let out = flux_bin().arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in
        ["figures", "simulate", "tune", "gen-goldens", "bench", "lint"]
    {
        assert!(text.contains(cmd), "--help must mention {cmd}");
    }
    // `--help` after a subcommand also prints usage (not a parse error).
    let out = flux_bin().args(["bench", "--help"]).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_command_fails() {
    let out = flux_bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn list_prints_every_registry() {
    // CLI discoverability: topologies, workload presets, overlap
    // methods and report schemas, sourced from the registries the
    // scenario runner resolves against.
    let out = flux_bin().arg("list").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for t in flux::cost::arch::ALL_SCALE_TOPOLOGIES {
        assert!(text.contains(t.name), "missing topology {}", t.name);
    }
    for t in flux::cost::arch::ALL_TRAIN_TOPOLOGIES {
        assert!(text.contains(t.name), "missing topology {}", t.name);
    }
    for name in flux::workload::PRESET_NAMES {
        assert!(text.contains(name), "missing preset {name}");
    }
    for m in flux::overlap::Method::ALL {
        assert!(text.contains(m.key()), "missing method {}", m.key());
    }
    for s in flux::report::SCHEMAS {
        assert!(text.contains(s.name), "missing schema {}", s.name);
    }
    for r in flux_lint::RULES {
        assert!(text.contains(r.id), "missing lint rule {}", r.id);
    }
}

#[test]
fn scenario_subcommand_runs_the_checked_in_file() {
    let dir = tmp_dir("scenario");
    let file = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../artifacts/scenario_h800_bursty.json"
    );
    let run = |name: &str, threads: &str| -> String {
        let path = dir.join(name);
        let out = flux_bin()
            .args(["scenario", file, "--json", "--threads", threads])
            .arg("--out")
            .arg(&path)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read_to_string(&path).unwrap()
    };
    // Parallel and sequential scenario runs are byte-identical (the
    // run_matrix determinism contract, at the CLI surface).
    let a = run("seq.json", "1");
    let b = run("par.json", "3");
    assert_eq!(a, b, "scenario runs must not depend on --threads");
    let doc = flux::util::json::Json::parse(&a).unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        flux::report::SCALE_SCHEMA
    );
    assert_eq!(
        doc.get("scenario").unwrap().as_str().unwrap(),
        "h800-bursty"
    );
    let t = &doc.get("topologies").unwrap().as_arr().unwrap()[0];
    for key in ["decoupled", "medium", "flux"] {
        assert!(t.opt(key).is_some(), "missing method block {key}");
    }

    // Missing files and broken scenarios fail with the path named.
    let out = flux_bin()
        .args(["scenario", "no-such-scenario.json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr)
        .contains("no-such-scenario.json"));

    let bad = dir.join("bad.json");
    std::fs::write(
        &bad,
        r#"{"name": "bad", "mode": "serve", "methods": ["flux"]}"#,
    )
    .unwrap();
    let out = flux_bin()
        .arg("scenario")
        .arg(&bad)
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("baseline"), "pointed error expected: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_out_and_trace_paths_fail_with_the_path_named() {
    // Regression (satellite): --out/--trace under a non-directory
    // parent must produce an error naming the path, not a bare io
    // error.
    let dir = tmp_dir("badpaths");
    let blocker = dir.join("blocker");
    std::fs::write(&blocker, "x").unwrap();
    let out = flux_bin()
        .args([
            "simulate", "--scale", "--quick", "--json",
            "--topo", "1-node-tp8", "--out",
        ])
        .arg(blocker.join("sub/report.json"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("blocker"), "must name the path: {err}");

    let out = flux_bin()
        .args([
            "simulate", "--scale", "--quick",
            "--topo", "1-node-tp8", "--trace",
        ])
        .arg(blocker.join("sub/trace.json"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("blocker"), "must name the path: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_goldens_writes_the_golden_document() {
    let dir = tmp_dir("goldens");
    let path = dir.join("golden_swizzle.json");
    let out = flux_bin()
        .args(["gen-goldens", "--out", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).unwrap();
    // Byte-exact match with the library generator (determinism), and a
    // parseable document with all three sections.
    assert_eq!(text, flux::goldens::golden_doc().to_string());
    let doc = flux::util::json::Json::parse(&text).unwrap();
    for key in ["swizzle", "ring", "comm_sched"] {
        assert!(doc.opt(key).is_some(), "golden missing {key}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checked_in_golden_matches_the_rust_generator() {
    // The hermetic fallback contract: a clean checkout's golden file is
    // exactly what `flux gen-goldens` would emit — unless `make
    // artifacts` ran with JAX, which adds a "prefill" section; then we
    // only require the shared sections to parse (golden.rs checks their
    // values case by case).
    let path = flux::runtime::Runtime::artifacts_dir()
        .join("golden_swizzle.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("{}: {e} — the golden file must be checked in", path.display())
    });
    let doc = flux::util::json::Json::parse(&text).unwrap();
    if doc.opt("prefill").is_none() {
        assert_eq!(text, flux::goldens::golden_doc().to_string());
    }
}

#[test]
fn bench_json_is_reproducible_byte_for_byte() {
    // Acceptance: two consecutive runs produce byte-identical reports.
    let dir = tmp_dir("bench");
    let run = |name: &str| -> String {
        let path = dir.join(name);
        let out = flux_bin()
            .args(["bench", "--json", "--quick", "--out"])
            .arg(&path)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read_to_string(&path).unwrap()
    };
    let a = run("BENCH_a.json");
    let b = run("BENCH_b.json");
    assert_eq!(a, b, "bench --json must be deterministic");
    let doc = flux::util::json::Json::parse(&a).unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        flux::report::SCHEMA
    );
    assert!(!doc.get("suite").unwrap().as_arr().unwrap().is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn simulate_scale_json_is_reproducible_byte_for_byte() {
    // Acceptance: the serving-at-scale report is deterministic, covers
    // every topology, and Flux is never slower than the decoupled
    // execution on the NVLink-intra configurations.
    let dir = tmp_dir("scale");
    let run = |name: &str| -> String {
        let path = dir.join(name);
        let out = flux_bin()
            .args(["simulate", "--scale", "--json", "--quick", "--out"])
            .arg(&path)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read_to_string(&path).unwrap()
    };
    let a = run("BENCH_scale_a.json");
    let b = run("BENCH_scale_b.json");
    assert_eq!(a, b, "simulate --scale --json must be deterministic");
    let doc = flux::util::json::Json::parse(&a).unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        flux::report::SCALE_SCHEMA
    );
    let topos = doc.get("topologies").unwrap().as_arr().unwrap();
    assert!(topos.len() >= 3, "at least 3 topologies");
    for t in topos {
        let nvlink_intra = t
            .get("cluster")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("NVLink");
        let speedup = t.get("speedup").unwrap().as_f64().unwrap();
        if nvlink_intra {
            assert!(
                speedup >= 1.0,
                "{}: flux slower than decoupled ({speedup})",
                t.get("topology").unwrap().as_str().unwrap()
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn simulate_scale_prints_a_table() {
    let out = flux_bin()
        .args(["simulate", "--scale", "--quick"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("serving at scale"), "got: {text}");
    assert!(text.contains("speedup"), "got: {text}");
}

#[test]
fn simulate_scale_topo_filter() {
    // --topo restricts the sweep to one named topology; unknown names
    // and op-level flags are rejected, not silently ignored.
    let out = flux_bin()
        .args(["simulate", "--scale", "--quick", "--topo", "1-node-tp8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("1-node tp8"), "got: {text}");
    assert!(!text.contains("pcie"), "filtered out: {text}");

    let out = flux_bin()
        .args(["simulate", "--scale", "--topo", "warp-drive"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown topology"));

    let out = flux_bin()
        .args(["simulate", "--scale", "--m", "512"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("not supported"));
}

#[test]
fn simulate_scale_workload_flag_swaps_the_request_source() {
    // A preset by name: the report is marked with workload_filter and
    // carries the spec + SLO accounting.
    let dir = tmp_dir("workload_flag");
    let path = dir.join("preset.json");
    let out = flux_bin()
        .args([
            "simulate", "--scale", "--quick", "--json",
            "--workload", "bursty-decode",
            "--topo", "1-node-tp8",
            "--out",
        ])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = flux::util::json::Json::parse(
        &std::fs::read_to_string(&path).unwrap(),
    )
    .unwrap();
    assert_eq!(
        doc.get("workload_filter").unwrap().as_str().unwrap(),
        "bursty-decode"
    );
    let t = &doc.get("topologies").unwrap().as_arr().unwrap()[0];
    let wl = t.get("workload").unwrap();
    assert_eq!(
        wl.get("arrival").unwrap().get("kind").unwrap().as_str().unwrap(),
        "mmpp"
    );
    assert!(t.get("flux").unwrap().get("slo").unwrap().opt("goodput").is_some());

    // The checked-in example scenario file drives the same path.
    let file = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../artifacts/workload_bursty_chat.json"
    );
    let path2 = dir.join("file.json");
    let out = flux_bin()
        .args([
            "simulate", "--scale", "--quick", "--json",
            "--workload", file,
            "--topo", "1-node-tp8",
            "--out",
        ])
        .arg(&path2)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = flux::util::json::Json::parse(
        &std::fs::read_to_string(&path2).unwrap(),
    )
    .unwrap();
    assert_eq!(
        doc.get("workload_filter").unwrap().as_str().unwrap(),
        "bursty-chat-example"
    );

    // Unknown names are rejected with the preset list.
    let out = flux_bin()
        .args(["simulate", "--scale", "--workload", "mystery-traffic"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr)
        .contains("poisson-balanced"));

    // A file with a non-positive rate is rejected at parse time with a
    // pointed error, not a mid-simulation panic.
    let bad = dir.join("bad.json");
    std::fs::write(
        &bad,
        r#"{"name": "bad", "arrival": {"kind": "poisson",
            "mean_ns": -5}, "mix": {"kind": "fixed", "prompt": 8,
            "gen": 2}, "requests_per_replica": 2}"#,
    )
    .unwrap();
    let out = flux_bin()
        .args(["simulate", "--scale", "--workload"])
        .arg(&bad)
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("mean_ns") && err.contains("finite"),
        "pointed parse error expected, got: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_workloads_json_covers_the_preset_matrix() {
    // Acceptance: every preset on every topology, Flux never losing to
    // the decoupled execution on NVLink clusters. Byte-stability
    // across reruns is covered by the in-crate report test and CI's
    // release-mode `cmp` of BENCH_3.json, so one (debug-mode) run
    // suffices here.
    let dir = tmp_dir("sweep");
    let path = dir.join("BENCH_sweep.json");
    let out = flux_bin()
        .args(["sweep-workloads", "--json", "--quick", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let a = std::fs::read_to_string(&path).unwrap();
    let doc = flux::util::json::Json::parse(&a).unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        flux::report::SWEEP_SCHEMA
    );
    let presets = doc.get("presets").unwrap().as_arr().unwrap();
    assert_eq!(presets.len(), flux::workload::PRESET_NAMES.len());
    for p in presets {
        for t in p.get("topologies").unwrap().as_arr().unwrap() {
            let nvlink = t
                .get("cluster")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("NVLink");
            let speedup = t.get("speedup").unwrap().as_f64().unwrap();
            if nvlink {
                assert!(
                    speedup >= 1.0,
                    "{} on {}: {speedup}",
                    p.get("name").unwrap().as_str().unwrap(),
                    t.get("topology").unwrap().as_str().unwrap()
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_flag_writes_byte_stable_chrome_traces() {
    let dir = tmp_dir("trace");
    let run = |cmd: &str, name: &str| -> String {
        let path = dir.join(name);
        let out = flux_bin()
            .args([
                "simulate", cmd, "--quick",
                "--topo",
                if cmd == "--scale" { "1-node-tp8" } else {
                    "nvlink-dp2-pp8-tp8"
                },
                "--trace",
            ])
            .arg(&path)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{cmd}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read_to_string(&path).unwrap()
    };
    for cmd in ["--scale", "--train"] {
        let a = run(cmd, "a.json");
        let b = run(cmd, "b.json");
        assert_eq!(a, b, "{cmd} trace must be byte-stable");
        let doc = flux::util::json::Json::parse(&a).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty(), "{cmd} trace has events");
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(["X", "i", "M"].contains(&ph), "{cmd}: ph {ph}");
        }
    }
    // A whole-sweep trace would interleave topologies: rejected.
    let out = flux_bin()
        .args(["simulate", "--scale", "--quick", "--trace"])
        .arg(dir.join("no.json"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--topo"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn schema_subcommand_dumps_every_registered_schema() {
    // Discoverability: every name `flux list` advertises has a typed
    // field dump, human and --json.
    for s in flux::report::SCHEMAS {
        let out = flux_bin().args(["schema", s.name]).output().unwrap();
        assert!(
            out.status.success(),
            "schema {}: {}",
            s.name,
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains(s.name), "{}: dump names the schema", s.name);

        let out = flux_bin()
            .args(["schema", s.name, "--json"])
            .output()
            .unwrap();
        assert!(out.status.success());
        let doc = flux::util::json::Json::parse(
            &String::from_utf8_lossy(&out.stdout),
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), s.name);
        assert_eq!(doc.get("command").unwrap().as_str().unwrap(), s.command);
        assert!(
            !doc.get("fields").unwrap().as_arr().unwrap().is_empty(),
            "{}: dump has fields",
            s.name
        );
    }
    // Unknown names fail with the registry listed; a bare `schema`
    // prints usage and fails.
    let out = flux_bin().args(["schema", "flux-nope-v9"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains(flux::report::METRICS_SCHEMA),
        "error must list known schemas: {err}"
    );
    let out = flux_bin().arg("schema").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn metrics_flag_writes_byte_stable_telemetry() {
    // Tentpole acceptance at the CLI surface: `--faults replica-churn
    // --metrics` is byte-stable across reruns AND thread counts, and
    // the document carries the fault markers.
    let dir = tmp_dir("metrics");
    let run = |name: &str, threads: &str| -> String {
        let mpath = dir.join(name);
        let out = flux_bin()
            .args([
                "simulate", "--scale", "--quick",
                "--topo", "1-node-tp8",
                "--faults", "replica-churn",
                "--json", "--threads", threads,
            ])
            .arg("--out")
            .arg(dir.join(format!("report_{name}")))
            .arg("--metrics")
            .arg(&mpath)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read_to_string(&mpath).unwrap()
    };
    let a = run("m_a.json", "1");
    let b = run("m_b.json", "3");
    assert_eq!(a, b, "--metrics must not depend on --threads");
    let doc = flux::util::json::Json::parse(&a).unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        flux::report::METRICS_SCHEMA
    );
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert!(!cells.is_empty(), "metrics doc has cells");
    for c in cells {
        for key in [
            "counters", "gauges", "histograms", "markers", "method",
            "series", "topology",
        ] {
            assert!(c.opt(key).is_some(), "cell missing {key}");
        }
    }
    assert!(a.contains("fault.kill"), "churn kill markers recorded");
    assert!(a.contains("serve.queue_depth"), "sampled series recorded");

    // Combined --trace --metrics: one capture serves both files, so
    // the sampled gauges additionally land in the trace as chrome
    // counter ("C") events.
    let tpath = dir.join("trace.json");
    let mpath = dir.join("m_trace.json");
    let out = flux_bin()
        .args([
            "simulate", "--scale", "--quick", "--topo", "1-node-tp8",
        ])
        .arg("--trace")
        .arg(&tpath)
        .arg("--metrics")
        .arg(&mpath)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let trace = flux::util::json::Json::parse(
        &std::fs::read_to_string(&tpath).unwrap(),
    )
    .unwrap();
    let evs = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        evs.iter().any(|e| {
            matches!(e.opt("ph").and_then(|p| p.as_str().ok()), Some("C"))
        }),
        "combined capture must emit counter events"
    );
    let metrics = flux::util::json::Json::parse(
        &std::fs::read_to_string(&mpath).unwrap(),
    )
    .unwrap();
    assert_eq!(
        metrics.get("schema").unwrap().as_str().unwrap(),
        flux::report::METRICS_SCHEMA
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn simulate_train_json_is_reproducible_byte_for_byte() {
    // Acceptance: the event-driven training report is deterministic,
    // covers every topology, and the 128-GPU PCIe speedup lands in the
    // paper's ~1.2x band.
    let dir = tmp_dir("train");
    let run = |name: &str| -> String {
        let path = dir.join(name);
        let out = flux_bin()
            .args(["simulate", "--train", "--json", "--quick", "--out"])
            .arg(&path)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read_to_string(&path).unwrap()
    };
    let a = run("BENCH_train_a.json");
    let b = run("BENCH_train_b.json");
    assert_eq!(a, b, "simulate --train --json must be deterministic");
    let doc = flux::util::json::Json::parse(&a).unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        flux::report::TRAIN_SCHEMA
    );
    let topos = doc.get("topologies").unwrap().as_arr().unwrap();
    assert_eq!(topos.len(), 3, "all three paper clusters");
    for t in topos {
        let name = t.get("topology").unwrap().as_str().unwrap();
        let speedup = t.get("speedup").unwrap().as_f64().unwrap();
        assert!(speedup >= 1.0, "{name}: flux slower ({speedup})");
        if name.contains("pcie") {
            assert!(
                speedup > 1.10 && speedup < 1.60,
                "{name}: PCIe speedup {speedup} outside the Fig. 16 band"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn simulate_train_prints_a_table_and_filters_topologies() {
    let out = flux_bin()
        .args(["simulate", "--train", "--quick", "--topo",
               "nvlink-dp2-pp8-tp8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("training at scale"), "got: {text}");
    assert!(text.contains("nvlink dp2 pp8 tp8"), "got: {text}");
    assert!(!text.contains("pcie"), "filtered out: {text}");

    let out = flux_bin()
        .args(["simulate", "--train", "--topo", "warp-drive"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown topology"));

    // Op-level flags are rejected, and so is mixing the two sweeps.
    let out = flux_bin()
        .args(["simulate", "--train", "--m", "512"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("not supported"));

    let out = flux_bin()
        .args(["simulate", "--train", "--scale"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("pick one"));
}

#[test]
fn simulate_subcommand_prints_a_comparison() {
    let out = flux_bin()
        .args(["simulate", "--m", "512", "--tp", "4", "--op", "rs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Flux (tuned)"), "got: {text}");
}

#[test]
fn examples_run_to_completion_in_debug() {
    // Each example must exit 0. FLUX_SMOKE trims the heavy sweeps; the
    // PJRT-dependent examples (quickstart part 1, serve_e2e) detect the
    // stub backend themselves and degrade gracefully. Examples run
    // sequentially through one `cargo run` at a time to avoid build-dir
    // lock contention.
    let Some(cargo) = std::env::var_os("CARGO") else {
        eprintln!("skipping: CARGO env var not set");
        return;
    };
    for ex in [
        "quickstart",
        "autotune",
        "repro_figures",
        "serve_e2e",
        "train_cluster",
    ] {
        let out = Command::new(&cargo)
            .args(["run", "--quiet", "--example", ex])
            .env("FLUX_SMOKE", "1")
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap_or_else(|e| panic!("spawning cargo for {ex}: {e}"));
        assert!(
            out.status.success(),
            "example {ex} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
}
