//! Workload-subsystem properties and calibrated differential bands.
//!
//! The quantitative assertions were validated against the bit-exact
//! Python port of the coordinator (the same methodology as the PR-2/3
//! bands): every band below holds with margin on the port, so a
//! failure here means the Rust drifted from the calibrated behavior,
//! not that the band was guessed.

use flux::cost::arch::{
    ALL_SCALE_TOPOLOGIES, SCALE_H800_TP8_DP4, SCALE_TP8_DP2,
};
use flux::parallel::Method;
use flux::serving::scale::{compare_scale, run_scale, ScaleScenario};
use flux::util::propcheck::{f64_in, forall_gen, usize_in, zip};
use flux::util::prng::Rng;
use flux::workload::{
    preset, ArrivalSpec, LenClass, MixSpec, Routing, WorkloadSpec,
};

// ---------------------------------------------------------- properties

#[test]
fn prop_interarrivals_finite_nonnegative_for_every_process() {
    // Any open-loop process with valid parameters yields a finite,
    // non-decreasing arrival sequence; think gaps likewise.
    let gen = zip(
        zip(usize_in(1, 5), f64_in(1e4, 1e8)),
        zip(f64_in(0.0, 0.999), usize_in(1, 12)),
    );
    forall_gen(48, 0xF7, gen, |&((kind, mean), (amp, burst))| {
        let spec = match kind {
            1 => ArrivalSpec::Poisson { mean_ns: mean },
            2 => ArrivalSpec::Mmpp {
                on_mean_ns: mean / 10.0,
                idle_mean_ns: mean * 10.0,
                avg_burst: burst,
            },
            3 => ArrivalSpec::Diurnal {
                base_mean_ns: mean,
                amplitude: amp,
                period_ns: mean * 50.0,
            },
            _ => ArrivalSpec::ClosedLoop {
                concurrency: burst,
                think_ns: mean,
            },
        };
        spec.validate().unwrap();
        let mut rng = Rng::new(mean.to_bits() ^ burst as u64);
        match spec.arrival_times(100, 2, &mut rng) {
            Some(times) => {
                let mut prev = 0.0;
                for &t in &times {
                    assert!(
                        t.is_finite() && t >= prev,
                        "{spec:?}: {t} after {prev}"
                    );
                    prev = t;
                }
            }
            None => {
                for g in spec.think_gaps(100, &mut rng) {
                    assert!(g.is_finite() && g >= 0.0, "{spec:?}: {g}");
                }
            }
        }
    });
}

#[test]
fn prop_length_sampler_stays_within_spec_bounds() {
    let gen = zip(
        zip(usize_in(1, 2049), usize_in(1, 129)),
        zip(usize_in(1, 8193), f64_in(0.0, 1.0)),
    );
    forall_gen(48, 0xF8, gen, |&((sp, sg), (lp, p_long))| {
        let short = LenClass { prompt: sp, gen: sg };
        let long = LenClass { prompt: lp, gen: sg * 2 };
        let mix = MixSpec::TwoPoint { p_long, short, long };
        mix.validate().unwrap();
        let lens = mix.lengths(64, &mut Rng::new(sp as u64));
        for c in &lens {
            assert!(*c == short || *c == long, "{c:?} escaped the mix");
            assert!(c.prompt <= mix.max_prompt());
            assert!(c.prompt + c.gen <= mix.max_total());
        }
    });
}

#[test]
fn prop_identical_seeds_reproduce_identical_runs() {
    // The replay contract end to end: same spec + same seed => the
    // whole simulated run (makespan, every percentile, SLO counters)
    // is identical. Random preset, topology and seed per case.
    let gen = zip(
        zip(usize_in(0, 7), usize_in(0, ALL_SCALE_TOPOLOGIES.len())),
        usize_in(1, 1 << 16),
    );
    forall_gen(6, 0xF9, gen, |&((pi, ti), seed)| {
        let wl = preset(flux::workload::PRESET_NAMES[pi], true).unwrap();
        let mut sc = ScaleScenario::with_workload(
            ALL_SCALE_TOPOLOGIES[ti],
            wl,
        );
        sc.seed = seed as u64;
        let a = run_scale(&sc, Method::Flux).unwrap();
        let b = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.ttft.p99, b.ttft.p99);
        assert_eq!(a.per_token.mean, b.per_token.mean);
        assert_eq!(a.latency.p95, b.latency.p95);
        assert_eq!(a.slo, b.slo);
        assert_eq!(a.completed, sc.n_requests());
    });
}

// ------------------------------------------- calibrated traffic bands

#[test]
fn bursty_arrivals_widen_the_flux_gap_on_h800() {
    // steady-decode and bursty-decode share one length mix and differ
    // only in arrivals. On H800 (where plain decode is flux-adverse —
    // the narrow-store cliff) burst backlog turns queueing into Flux
    // territory: port-calibrated speedups 1.026 (steady) vs 1.113
    // (bursty) quick.
    let steady = compare_scale(&ScaleScenario::with_workload(
        &SCALE_H800_TP8_DP4,
        preset("steady-decode", true).unwrap(),
    ))
    .unwrap();
    let bursty = compare_scale(&ScaleScenario::with_workload(
        &SCALE_H800_TP8_DP4,
        preset("bursty-decode", true).unwrap(),
    ))
    .unwrap();
    assert!(
        bursty.speedup() > steady.speedup() + 0.05,
        "bursty {} should widen steady {}",
        bursty.speedup(),
        steady.speedup()
    );
    // The widened gap shows in goodput too: flux clears the SLOs the
    // decoupled execution starts missing under backlog (port: 1.000
    // vs 0.8125).
    let gfx = bursty.flux.slo.unwrap().goodput();
    let gde = bursty.decoupled.slo.unwrap().goodput();
    assert!(
        gfx >= gde + 0.15,
        "bursty goodput must diverge: flux {gfx} decoupled {gde}"
    );
}

#[test]
fn closed_loop_compresses_the_flux_gap_everywhere() {
    // open-prefill and closed-prefill share one length mix and differ
    // only in loop closure: think pauses are method-independent dead
    // time, so they dilute the speedup on every topology
    // (port-calibrated, e.g. H800 1.580 -> 1.313 quick).
    for topo in ALL_SCALE_TOPOLOGIES {
        let open = compare_scale(&ScaleScenario::with_workload(
            topo,
            preset("open-prefill", true).unwrap(),
        ))
        .unwrap();
        let closed = compare_scale(&ScaleScenario::with_workload(
            topo,
            preset("closed-prefill", true).unwrap(),
        ))
        .unwrap();
        assert!(
            closed.speedup() < open.speedup(),
            "{}: closed {} must compress open {}",
            topo.name,
            closed.speedup(),
            open.speedup()
        );
    }
}

#[test]
fn long_context_diverges_goodput_and_abandonment_on_h800() {
    // The bimodal long-context mix under SLOs: Flux's prefill overlap
    // converts directly into met deadlines (port: goodput 0.625 vs
    // 0.208) and fewer abandoned requests cluster-wide.
    let cmp = compare_scale(&ScaleScenario::with_workload(
        &SCALE_H800_TP8_DP4,
        preset("long-context", true).unwrap(),
    ))
    .unwrap();
    let fx = cmp.flux.slo.unwrap();
    let de = cmp.decoupled.slo.unwrap();
    assert!(
        fx.goodput() >= de.goodput() + 0.3,
        "flux {} decoupled {}",
        fx.goodput(),
        de.goodput()
    );
    assert!(fx.abandoned <= de.abandoned);
    assert!(fx.wasted_tokens <= de.wasted_tokens);
}

// ------------------------------------------------- routing regression

#[test]
fn least_outstanding_beats_round_robin_on_p99_ttft_under_bursts() {
    // Bursty arrivals + a skewed two-point mix near saturation: blind
    // rotation keeps feeding the replica stuck behind a 4096-token
    // prefill, least-outstanding steers around it. Port-calibrated:
    // p99 TTFT 5.82s (rr) vs 5.02s (lor), mean 1.58s vs 1.25s on the
    // 2-node NVLink DP2 topology under Flux.
    let scenario = |routing| WorkloadSpec {
        name: "lor-regression".to_string(),
        arrival: ArrivalSpec::Mmpp {
            on_mean_ns: 4.0e6,
            idle_mean_ns: 1.2e9,
            avg_burst: 4,
        },
        mix: MixSpec::TwoPoint {
            p_long: 0.3,
            short: LenClass { prompt: 256, gen: 8 },
            long: LenClass { prompt: 4096, gen: 32 },
        },
        requests_per_replica: 24,
        routing,
        slo: None,
        max_prefill_tokens: None,
    };
    let rr = run_scale(
        &ScaleScenario::with_workload(
            &SCALE_TP8_DP2,
            scenario(Routing::RoundRobin),
        ),
        Method::Flux,
    )
    .unwrap();
    let lor = run_scale(
        &ScaleScenario::with_workload(
            &SCALE_TP8_DP2,
            scenario(Routing::LeastOutstanding),
        ),
        Method::Flux,
    )
    .unwrap();
    assert_eq!(lor.completed, rr.completed, "same workload completes");
    assert!(
        lor.ttft.p99 < 0.95 * rr.ttft.p99,
        "lor p99 {} must beat rr p99 {} by >5%",
        lor.ttft.p99,
        rr.ttft.p99
    );
    assert!(
        lor.ttft.mean < rr.ttft.mean,
        "lor mean {} vs rr mean {}",
        lor.ttft.mean,
        rr.ttft.mean
    );
}
