//! Property tests for the DES/overlap core, driven by the in-house
//! seeded generator combinators (`util::propcheck`): every failure
//! message carries the reproducing seed and the exact generated input.

use flux::overlap::tiles::tile_dest;
use flux::sim::engine::EventQueue;
use flux::util::propcheck::{
    f64_in, forall_gen, map, one_of, usize_in, vec_of, zip,
};
use flux::util::stats::Summary;

/// Event times mixing a coarse lattice (forced exact ties) with
/// continuous draws (forced near-misses).
fn event_times() -> impl Fn(&mut flux::util::prng::Rng) -> Vec<f64> {
    vec_of(
        usize_in(1, 60),
        map(
            zip(one_of(vec![true, false]), f64_in(0.0, 100.0)),
            |(lattice, x)| if lattice { (x / 10.0).floor() * 10.0 } else { x },
        ),
    )
}

#[test]
fn random_schedules_drain_in_time_then_fifo_order() {
    // The DES total-order contract: popping sorts by time, and events
    // with numerically equal times come out in insertion order.
    forall_gen(128, 0xDE5_0001, event_times(), |times| {
        let mut q = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.schedule(t, i);
        }
        let drained: Vec<(f64, usize)> =
            std::iter::from_fn(|| q.next()).collect();
        assert_eq!(drained.len(), times.len(), "no event lost");
        for w in drained.windows(2) {
            let ((t0, i0), (t1, i1)) = (w[0], w[1]);
            assert!(t0 <= t1, "time order violated: {t0} > {t1}");
            if t0 == t1 {
                assert!(i0 < i1, "FIFO violated at t={t0}: {i0} vs {i1}");
            }
        }
        let mut seen: Vec<usize> =
            drained.iter().map(|&(_, i)| i).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..times.len()).collect::<Vec<_>>());
    });
}

#[test]
fn interleaved_schedule_and_pop_never_rewinds_the_clock() {
    // Open-loop usage: scheduling relative to a moving `now` (as the
    // serving/training sims do) keeps the popped sequence monotone.
    let gen = vec_of(usize_in(1, 80), zip(one_of(vec![true, false]),
                                          f64_in(0.0, 25.0)));
    forall_gen(128, 0xDE5_0002, gen, |ops| {
        let mut q = EventQueue::new();
        let mut popped = Vec::new();
        for &(push, delay) in ops {
            if push {
                q.schedule_in(delay, ());
            } else if let Some((t, ())) = q.next() {
                popped.push(t);
            }
        }
        while let Some((t, ())) = q.next() {
            popped.push(t);
        }
        for w in popped.windows(2) {
            assert!(w[0] <= w[1], "clock rewound: {} after {}", w[1], w[0]);
        }
    });
}

#[test]
fn tile_dest_is_a_balanced_bijection_onto_ranks() {
    // For every valid (tiles, ranks) shape: the row-tile -> rank map
    // covers every rank exactly tiles/ranks times, is monotone in the
    // tile index (block routing), and block starts map bijectively
    // onto 0..n_tp.
    let gen = zip(usize_in(1, 13), usize_in(1, 9));
    forall_gen(256, 0xDE5_0003, gen, |&(n_tp, per)| {
        let tiles_m = n_tp * per;
        let dests: Vec<usize> =
            (0..tiles_m).map(|t| tile_dest(t, tiles_m, n_tp)).collect();
        let mut counts = vec![0usize; n_tp];
        for &d in &dests {
            assert!(d < n_tp, "dest {d} out of range");
            counts[d] += 1;
        }
        assert!(
            counts.iter().all(|&c| c == per),
            "unbalanced routing: {counts:?}"
        );
        assert!(dests.windows(2).all(|w| w[0] <= w[1]), "non-monotone");
        let block_starts: Vec<usize> =
            (0..n_tp).map(|r| dests[r * per]).collect();
        assert_eq!(
            block_starts,
            (0..n_tp).collect::<Vec<_>>(),
            "block starts must enumerate the ranks in order"
        );
    });
}

#[test]
fn summary_percentiles_are_monotone_on_random_samples() {
    // min <= p50 <= p95 <= p99 <= max on any non-empty finite sample,
    // mean inside [min, max], std never negative.
    let gen = vec_of(usize_in(1, 100), f64_in(-1.0e9, 1.0e9));
    forall_gen(256, 0xDE5_0004, gen, |xs| {
        let s = Summary::of(xs);
        assert!(s.min <= s.p50, "min {} > p50 {}", s.min, s.p50);
        assert!(s.p50 <= s.p95, "p50 {} > p95 {}", s.p50, s.p95);
        assert!(s.p95 <= s.p99, "p95 {} > p99 {}", s.p95, s.p99);
        assert!(s.p99 <= s.max, "p99 {} > max {}", s.p99, s.max);
        assert!(s.mean >= s.min && s.mean <= s.max, "mean {}", s.mean);
        assert!(s.std >= 0.0);
        assert_eq!(s.n, xs.len());
    });
}
