//! Property tests for the DES/overlap core, driven by the in-house
//! seeded generator combinators (`util::propcheck`): every failure
//! message carries the reproducing seed and the exact generated input.

use flux::overlap::tiles::tile_dest;
use flux::sim::engine::EventQueue;
use flux::util::propcheck::{
    f64_in, forall_gen, map, one_of, usize_in, vec_of, zip,
};
use flux::util::stats::Summary;

/// Event times mixing a coarse lattice (forced exact ties) with
/// continuous draws (forced near-misses).
fn event_times() -> impl Fn(&mut flux::util::prng::Rng) -> Vec<f64> {
    vec_of(
        usize_in(1, 60),
        map(
            zip(one_of(vec![true, false]), f64_in(0.0, 100.0)),
            |(lattice, x)| if lattice { (x / 10.0).floor() * 10.0 } else { x },
        ),
    )
}

#[test]
fn random_schedules_drain_in_time_then_fifo_order() {
    // The DES total-order contract: popping sorts by time, and events
    // with numerically equal times come out in insertion order.
    forall_gen(128, 0xDE5_0001, event_times(), |times| {
        let mut q = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.schedule(t, i);
        }
        let drained: Vec<(f64, usize)> =
            std::iter::from_fn(|| q.next()).collect();
        assert_eq!(drained.len(), times.len(), "no event lost");
        for w in drained.windows(2) {
            let ((t0, i0), (t1, i1)) = (w[0], w[1]);
            assert!(t0 <= t1, "time order violated: {t0} > {t1}");
            if t0 == t1 {
                assert!(i0 < i1, "FIFO violated at t={t0}: {i0} vs {i1}");
            }
        }
        let mut seen: Vec<usize> =
            drained.iter().map(|&(_, i)| i).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..times.len()).collect::<Vec<_>>());
    });
}

#[test]
fn interleaved_schedule_and_pop_never_rewinds_the_clock() {
    // Open-loop usage: scheduling relative to a moving `now` (as the
    // serving/training sims do) keeps the popped sequence monotone.
    let gen = vec_of(usize_in(1, 80), zip(one_of(vec![true, false]),
                                          f64_in(0.0, 25.0)));
    forall_gen(128, 0xDE5_0002, gen, |ops| {
        let mut q = EventQueue::new();
        let mut popped = Vec::new();
        for &(push, delay) in ops {
            if push {
                q.schedule_in(delay, ());
            } else if let Some((t, ())) = q.next() {
                popped.push(t);
            }
        }
        while let Some((t, ())) = q.next() {
            popped.push(t);
        }
        for w in popped.windows(2) {
            assert!(w[0] <= w[1], "clock rewound: {} after {}", w[1], w[0]);
        }
    });
}

#[test]
fn tile_dest_is_a_balanced_bijection_onto_ranks() {
    // For every valid (tiles, ranks) shape: the row-tile -> rank map
    // covers every rank exactly tiles/ranks times, is monotone in the
    // tile index (block routing), and block starts map bijectively
    // onto 0..n_tp.
    let gen = zip(usize_in(1, 13), usize_in(1, 9));
    forall_gen(256, 0xDE5_0003, gen, |&(n_tp, per)| {
        let tiles_m = n_tp * per;
        let dests: Vec<usize> =
            (0..tiles_m).map(|t| tile_dest(t, tiles_m, n_tp)).collect();
        let mut counts = vec![0usize; n_tp];
        for &d in &dests {
            assert!(d < n_tp, "dest {d} out of range");
            counts[d] += 1;
        }
        assert!(
            counts.iter().all(|&c| c == per),
            "unbalanced routing: {counts:?}"
        );
        assert!(dests.windows(2).all(|w| w[0] <= w[1]), "non-monotone");
        let block_starts: Vec<usize> =
            (0..n_tp).map(|r| dests[r * per]).collect();
        assert_eq!(
            block_starts,
            (0..n_tp).collect::<Vec<_>>(),
            "block starts must enumerate the ranks in order"
        );
    });
}

#[test]
fn sketch_percentiles_bracket_the_exact_ones_bucketwise() {
    // Differential contract of the opt-in sketch mode against the
    // exact sorted-sample percentiles, on latency-like draws:
    //  - n/min/max are exact (bit-equal) — only percentiles bucket;
    //  - the sketch estimate always lands inside the bucket of the
    //    exact percentile's floor order statistic;
    //  - when the floor and ceil order statistics share that bucket
    //    (so the exact interpolation cannot cross a boundary), sketch
    //    and exact differ by at most one bucket width.
    use flux::obs::LATENCY_BOUNDS_NS;
    use flux::util::stats::Sketch;
    let gen = vec_of(usize_in(1, 300), f64_in(0.0, 2.0e10));
    forall_gen(128, 0xDE5_0005, gen, |xs| {
        let mut sk = Sketch::new(&LATENCY_BOUNDS_NS);
        for &x in xs {
            sk.observe(x);
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        let ex = Summary::of(xs);
        let s = sk.summary();
        assert_eq!(s.n, ex.n);
        assert_eq!(s.min.to_bits(), ex.min.to_bits());
        assert_eq!(s.max.to_bits(), ex.max.to_bits());
        for (q, sp, ep) in [
            (0.50, s.p50, ex.p50),
            (0.95, s.p95, ex.p95),
            (0.99, s.p99, ex.p99),
        ] {
            let pos = q * (sorted.len() - 1) as f64;
            let x_floor = sorted[pos.floor() as usize];
            let x_ceil = sorted[pos.ceil() as usize];
            let (lo, hi) = sk.bucket_of(x_floor);
            let tol = 1e-9 * hi.abs().max(1.0);
            assert!(
                sp >= lo - tol && sp <= hi + tol,
                "p{q}: sketch {sp} outside bucket [{lo}, {hi}]"
            );
            if sk.bucket_of(x_ceil) == (lo, hi) {
                assert!(
                    (sp - ep).abs() <= (hi - lo) + tol,
                    "p{q}: |{sp} - {ep}| > bucket width {}",
                    hi - lo
                );
            }
        }
    });
}

#[test]
fn summary_percentiles_are_monotone_on_random_samples() {
    // min <= p50 <= p95 <= p99 <= max on any non-empty finite sample,
    // mean inside [min, max], std never negative.
    let gen = vec_of(usize_in(1, 100), f64_in(-1.0e9, 1.0e9));
    forall_gen(256, 0xDE5_0004, gen, |xs| {
        let s = Summary::of(xs);
        assert!(s.min <= s.p50, "min {} > p50 {}", s.min, s.p50);
        assert!(s.p50 <= s.p95, "p50 {} > p95 {}", s.p50, s.p95);
        assert!(s.p95 <= s.p99, "p95 {} > p99 {}", s.p95, s.p99);
        assert!(s.p99 <= s.max, "p99 {} > max {}", s.p99, s.max);
        assert!(s.mean >= s.min && s.mean <= s.max, "mean {}", s.mean);
        assert!(s.std >= 0.0);
        assert_eq!(s.n, xs.len());
    });
}
