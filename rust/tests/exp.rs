//! Experiment-layer integration: the parallel `Runner` must be
//! byte-identical to the sequential path across every preset x
//! topology cell, and scenario files must round-trip and reject
//! nonsense with pointed errors.

use flux::exp::{Mode, Runner, Scenario, WorkloadRef};
use flux::overlap::Method;
use flux::report;
use flux::util::json::Json;
use flux::util::propcheck::{forall_gen, usize_in};

#[test]
fn sweep_matrix_is_byte_identical_at_any_thread_count() {
    // THE determinism-under-parallelism contract (and the CI
    // BENCH_4 byte-compare): the full preset x topology x method
    // matrix, sequential vs drawn worker counts.
    let seq = report::sweep_doc_with(true, &Runner::with_threads(1))
        .unwrap()
        .to_string();
    assert!(seq.contains("flux-sweep-v1"));
    forall_gen(3, 0xF1A7, usize_in(2, 9), |&threads| {
        let par =
            report::sweep_doc_with(true, &Runner::with_threads(threads))
                .unwrap()
                .to_string();
        assert_eq!(par, seq, "{threads} threads diverged");
    });
}

#[test]
fn scale_and_train_docs_are_byte_identical_across_thread_counts() {
    // Acceptance: parallel == sequential across >= 2 thread counts,
    // for both DES document families.
    let serve = Scenario::serve(None, None, true);
    let train = Scenario::train(None, true);
    let seq_scale =
        report::scale_doc_scenario(&serve, &Runner::with_threads(1))
            .unwrap()
            .to_string();
    let seq_train =
        report::train_doc_scenario(&train, &Runner::with_threads(1))
            .unwrap()
            .to_string();
    for threads in [2, 5] {
        let runner = Runner::with_threads(threads);
        assert_eq!(
            report::scale_doc_scenario(&serve, &runner)
                .unwrap()
                .to_string(),
            seq_scale,
            "scale doc at {threads} threads"
        );
        assert_eq!(
            report::train_doc_scenario(&train, &runner)
                .unwrap()
                .to_string(),
            seq_train,
            "train doc at {threads} threads"
        );
    }
}

#[test]
fn checked_in_scenario_file_loads_and_runs() {
    let path = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../artifacts/scenario_h800_bursty.json"
    ));
    let sc = Scenario::load(path).unwrap();
    assert_eq!(sc.name, "h800-bursty");
    assert_eq!(sc.mode, Mode::Serve);
    assert_eq!(
        sc.workload,
        Some(WorkloadRef::Preset("bursty-decode".into()))
    );
    assert_eq!(sc.method_set().len(), 3);
    assert_eq!(sc.topo_count().unwrap(), 1);
    // It runs end to end and stamps the document.
    let doc =
        report::scale_doc_scenario(&sc, &Runner::with_threads(2))
            .unwrap();
    assert_eq!(
        doc.get("scenario").unwrap().as_str().unwrap(),
        "h800-bursty"
    );
    assert_eq!(
        doc.get("workload_filter").unwrap().as_str().unwrap(),
        "bursty-decode"
    );
    let topos = doc.get("topologies").unwrap().as_arr().unwrap();
    assert_eq!(topos.len(), 1);
    // All three registry methods emitted their blocks.
    for key in ["decoupled", "medium", "flux"] {
        assert!(topos[0].opt(key).is_some(), "missing method {key}");
    }
    // H800 + bursty traffic: the port-calibrated band says flux wins
    // end to end (burst backlog widens the gap, PR-4).
    assert!(
        topos[0].get("speedup").unwrap().as_f64().unwrap() >= 1.0
    );
}

#[test]
fn scenario_json_round_trips_through_the_cli_surface() {
    let sc = Scenario {
        name: "roundtrip".into(),
        mode: Mode::Serve,
        topos: Some(vec!["2-node tp8 dp2".into()]),
        workload: Some(WorkloadRef::Preset("diurnal-chat".into())),
        methods: Some(vec![Method::NonOverlap, Method::Flux]),
        quick: true,
    };
    let text = sc.to_json().to_string();
    let parsed = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(parsed, sc);
    assert_eq!(parsed.to_json().to_string(), text);
}

#[test]
fn runner_default_uses_every_core_and_flag_overrides() {
    assert!(Runner::new().threads() >= 1);
    assert_eq!(Runner::from_flag(Some(7)).threads(), 7);
    assert_eq!(Runner::with_threads(0).threads(), 1, "clamped");
}
