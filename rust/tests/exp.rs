//! Experiment-layer integration: the parallel `Runner` must be
//! byte-identical to the sequential path across every preset x
//! topology cell, and scenario files must round-trip and reject
//! nonsense with pointed errors.

use flux::cost::arch::{SCALE_H800_TP8_DP4, TRAIN_NVLINK_128};
use flux::exp::{Mode, Runner, Scenario, WorkloadRef};
use flux::faults::FaultsRef;
use flux::overlap::Method;
use flux::report;
use flux::util::json::Json;
use flux::util::propcheck::{forall_gen, usize_in};
use flux::util::stats::PercentileMode;

#[test]
fn sweep_matrix_is_byte_identical_at_any_thread_count() {
    // THE determinism-under-parallelism contract (and the CI
    // BENCH_4 byte-compare): the full preset x topology x method
    // matrix, sequential vs drawn worker counts.
    let seq = report::sweep_doc_with(true, &Runner::with_threads(1))
        .unwrap()
        .to_string();
    assert!(seq.contains("flux-sweep-v1"));
    forall_gen(3, 0xF1A7, usize_in(2, 9), |&threads| {
        let par =
            report::sweep_doc_with(true, &Runner::with_threads(threads))
                .unwrap()
                .to_string();
        assert_eq!(par, seq, "{threads} threads diverged");
    });
}

#[test]
fn scale_and_train_docs_are_byte_identical_across_thread_counts() {
    // Acceptance: parallel == sequential across >= 2 thread counts,
    // for both DES document families.
    let serve = Scenario::serve(None, None, true);
    let train = Scenario::train(None, true);
    let seq_scale =
        report::scale_doc_scenario(&serve, &Runner::with_threads(1))
            .unwrap()
            .to_string();
    let seq_train =
        report::train_doc_scenario(&train, &Runner::with_threads(1))
            .unwrap()
            .to_string();
    for threads in [2, 5] {
        let runner = Runner::with_threads(threads);
        assert_eq!(
            report::scale_doc_scenario(&serve, &runner)
                .unwrap()
                .to_string(),
            seq_scale,
            "scale doc at {threads} threads"
        );
        assert_eq!(
            report::train_doc_scenario(&train, &runner)
                .unwrap()
                .to_string(),
            seq_train,
            "train doc at {threads} threads"
        );
    }
}

#[test]
fn churn_docs_are_byte_identical_across_drawn_thread_counts() {
    // Fault-injection determinism contract: an identical FaultSpec
    // seed replays byte-stably at ANY worker count, for both modes of
    // the flux-churn-v1 document. Thread counts are drawn by
    // propcheck, not hand-picked.
    let mut serve =
        Scenario::serve(Some(&SCALE_H800_TP8_DP4), None, true);
    serve.faults = Some(FaultsRef::Preset("replica-churn".into()));
    let mut train = Scenario::train(Some(&TRAIN_NVLINK_128), true);
    train.faults = Some(FaultsRef::Preset("straggler-storm".into()));
    let churn_bytes = |sc: &Scenario, threads: usize| {
        let spec = sc.faults.as_ref().unwrap().resolved().unwrap();
        report::churn_doc_scenario(
            sc,
            &spec,
            &Runner::with_threads(threads),
        )
        .unwrap()
        .to_string()
    };
    let seq_serve = churn_bytes(&serve, 1);
    let seq_train = churn_bytes(&train, 1);
    assert!(seq_serve.contains("flux-churn-v1"));
    assert!(seq_train.contains("flux-churn-v1"));
    forall_gen(3, 0x0C8A, usize_in(2, 9), |&threads| {
        assert_eq!(
            churn_bytes(&serve, threads),
            seq_serve,
            "serve churn doc at {threads} threads diverged"
        );
        assert_eq!(
            churn_bytes(&train, threads),
            seq_train,
            "train churn doc at {threads} threads diverged"
        );
    });
}

#[test]
fn intensity_zero_matches_the_plain_train_doc_exactly() {
    // Fault-free replay: the k=0 point of every churn curve must be
    // bit-identical to the historical flux-train-v1 document — wiring
    // a fault timeline that never fires must not perturb one f64.
    // (The serve-mode twin against flux-scale-v2 lives next to the
    // churn document in `report/churn.rs`.)
    let runner = Runner::with_threads(2);
    let mut churny = Scenario::train(Some(&TRAIN_NVLINK_128), true);
    churny.faults = Some(FaultsRef::Preset("straggler-storm".into()));
    let spec = churny.faults.as_ref().unwrap().resolved().unwrap();
    let churn =
        report::churn_doc_scenario(&churny, &spec, &runner).unwrap();
    let plain = report::train_doc_scenario(
        &Scenario::train(Some(&TRAIN_NVLINK_128), true),
        &runner,
    )
    .unwrap();
    let churn_topo = &churn.get("topologies").unwrap().as_arr().unwrap()[0];
    let plain_topo = &plain.get("topologies").unwrap().as_arr().unwrap()[0];
    for key in ["megatron", "te", "flux"] {
        let curve = churn_topo
            .get(key)
            .unwrap()
            .get("curve")
            .unwrap()
            .as_arr()
            .unwrap();
        let k0 = &curve[0];
        assert_eq!(k0.get("intensity").unwrap().as_f64().unwrap(), 0.0);
        for field in ["step_ns", "pipe_ns"] {
            assert_eq!(
                k0.get(field).unwrap().as_f64().unwrap(),
                plain_topo
                    .get(key)
                    .unwrap()
                    .get(field)
                    .unwrap()
                    .as_f64()
                    .unwrap(),
                "{key}.{field} perturbed by a fault-free timeline"
            );
        }
    }
}

#[test]
fn checked_in_scenario_file_loads_and_runs() {
    let path = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../artifacts/scenario_h800_bursty.json"
    ));
    let sc = Scenario::load(path).unwrap();
    assert_eq!(sc.name, "h800-bursty");
    assert_eq!(sc.mode, Mode::Serve);
    assert_eq!(
        sc.workload,
        Some(WorkloadRef::Preset("bursty-decode".into()))
    );
    assert_eq!(sc.method_set().len(), 3);
    assert_eq!(sc.topo_count().unwrap(), 1);
    // It runs end to end and stamps the document.
    let doc =
        report::scale_doc_scenario(&sc, &Runner::with_threads(2))
            .unwrap();
    assert_eq!(
        doc.get("scenario").unwrap().as_str().unwrap(),
        "h800-bursty"
    );
    assert_eq!(
        doc.get("workload_filter").unwrap().as_str().unwrap(),
        "bursty-decode"
    );
    let topos = doc.get("topologies").unwrap().as_arr().unwrap();
    assert_eq!(topos.len(), 1);
    // All three registry methods emitted their blocks.
    for key in ["decoupled", "medium", "flux"] {
        assert!(topos[0].opt(key).is_some(), "missing method {key}");
    }
    // H800 + bursty traffic: the port-calibrated band says flux wins
    // end to end (burst backlog widens the gap, PR-4).
    assert!(
        topos[0].get("speedup").unwrap().as_f64().unwrap() >= 1.0
    );
}

#[test]
fn checked_in_churn_scenario_files_load_and_run() {
    // The two fault-scenario artifacts are the CI byte-compare
    // fixtures (BENCH_6): they must load, resolve their preset, run
    // end to end, and stamp the flux-churn-v1 document.
    let serve_path = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../artifacts/scenario_churn_h800.json"
    ));
    let sc = Scenario::load(serve_path).unwrap();
    assert_eq!(sc.name, "h800-replica-churn");
    assert_eq!(sc.mode, Mode::Serve);
    let spec = sc.faults.as_ref().unwrap().resolved().unwrap();
    assert_eq!(spec.name, "replica-churn");
    let doc =
        report::churn_doc_scenario(&sc, &spec, &Runner::with_threads(2))
            .unwrap();
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        "flux-churn-v1"
    );
    assert_eq!(
        doc.get("scenario").unwrap().as_str().unwrap(),
        "h800-replica-churn"
    );
    // Degradation acceptance: goodput falls as intensity rises, on
    // every method of the single H800 topology.
    let topo = &doc.get("topologies").unwrap().as_arr().unwrap()[0];
    for key in ["decoupled", "flux"] {
        let curve = topo
            .get(key)
            .unwrap()
            .get("curve")
            .unwrap()
            .as_arr()
            .unwrap();
        let g: Vec<f64> = curve
            .iter()
            .map(|p| p.get("goodput").unwrap().as_f64().unwrap())
            .collect();
        assert!(
            g[0] > g[1] && g[1] > g[2],
            "{key}: goodput not strictly decreasing: {g:?}"
        );
    }

    let train_path = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../artifacts/scenario_churn_train.json"
    ));
    let tr = Scenario::load(train_path).unwrap();
    assert_eq!(tr.name, "nvlink-straggler-storm");
    assert_eq!(tr.mode, Mode::Train);
    let spec = tr.faults.as_ref().unwrap().resolved().unwrap();
    assert_eq!(spec.name, "straggler-storm");
    let doc =
        report::churn_doc_scenario(&tr, &spec, &Runner::with_threads(2))
            .unwrap();
    assert_eq!(
        doc.get("scenario").unwrap().as_str().unwrap(),
        "nvlink-straggler-storm"
    );
    let topo = &doc.get("topologies").unwrap().as_arr().unwrap()[0];
    for key in ["megatron", "te", "flux"] {
        let slow = topo
            .get(key)
            .unwrap()
            .get("slowdown")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(slow > 1.0, "{key}: stragglers must slow the step");
    }
}

#[test]
fn scenario_json_round_trips_through_the_cli_surface() {
    let sc = Scenario {
        name: "roundtrip".into(),
        mode: Mode::Serve,
        topos: Some(vec!["2-node tp8 dp2".into()]),
        workload: Some(WorkloadRef::Preset("diurnal-chat".into())),
        methods: Some(vec![Method::NonOverlap, Method::Flux]),
        faults: None,
        metrics: Some("metrics.json".into()),
        percentiles: PercentileMode::Exact,
        quick: true,
    };
    let text = sc.to_json().to_string();
    let parsed = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(parsed, sc);
    assert_eq!(parsed.to_json().to_string(), text);

    // The sketch opt-in rides the same surface: emitted as a
    // "percentiles" key, parsed back, byte-stable.
    let mut sketchy = sc.clone();
    sketchy.percentiles = PercentileMode::Sketch;
    let text = sketchy.to_json().to_string();
    assert!(text.contains("\"percentiles\":\"sketch\""));
    let parsed = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(parsed, sketchy);
    assert_eq!(parsed.to_json().to_string(), text);
}

#[test]
fn fleet_scenario_docs_are_byte_identical_across_thread_counts() {
    // The parametric dpN pools run through the same scenario surface
    // as the named registry, under the same determinism contract:
    // byte-identical expansion and execution at propcheck-drawn
    // worker counts.
    let sc = Scenario {
        name: "fleet-hot-path".into(),
        mode: Mode::Serve,
        topos: Some(vec![
            "fleet nvlink tp8 dp8".into(),
            "fleet h800 tp8 dp16".into(),
        ]),
        workload: None,
        methods: Some(vec![Method::Flux]),
        faults: None,
        metrics: None,
        percentiles: PercentileMode::Sketch,
        quick: true,
    };
    let seq = report::scale_doc_scenario(&sc, &Runner::with_threads(1))
        .unwrap()
        .to_string();
    assert!(seq.contains("fleet nvlink tp8 dp8"));
    assert!(seq.contains("fleet h800 tp8 dp16"));
    assert!(seq.contains("ttft_ns_sketch"));
    forall_gen(3, 0xDE5_0006, usize_in(2, 9), |&threads| {
        let par =
            report::scale_doc_scenario(&sc, &Runner::with_threads(threads))
                .unwrap()
                .to_string();
        assert_eq!(par, seq, "fleet doc at {threads} threads diverged");
    });
}

#[test]
fn metrics_docs_are_byte_identical_across_drawn_thread_counts() {
    // Observability determinism contract: the flux-metrics-v1
    // document — counters, seeded-cadence gauge series, fault
    // markers — replays byte-stably at ANY worker count, for both
    // modes. Thread counts are drawn by propcheck.
    let mut serve =
        Scenario::serve(Some(&SCALE_H800_TP8_DP4), None, true);
    serve.faults = Some(FaultsRef::Preset("replica-churn".into()));
    let train = Scenario::train(Some(&TRAIN_NVLINK_128), true);
    let bytes = |sc: &Scenario, threads: usize| {
        flux::exp::metrics_doc(sc, &Runner::with_threads(threads))
            .unwrap()
            .to_string()
    };
    let seq_serve = bytes(&serve, 1);
    let seq_train = bytes(&train, 1);
    assert!(seq_serve.contains("flux-metrics-v1"));
    assert!(seq_serve.contains("serve.queue_depth"));
    assert!(seq_serve.contains("fault.kill"));
    assert!(seq_train.contains("flux-metrics-v1"));
    assert!(seq_train.contains("train.pipe_ns"));
    forall_gen(3, 0x0B57, usize_in(2, 9), |&threads| {
        assert_eq!(
            bytes(&serve, threads),
            seq_serve,
            "serve metrics doc at {threads} threads diverged"
        );
        assert_eq!(
            bytes(&train, threads),
            seq_train,
            "train metrics doc at {threads} threads diverged"
        );
    });
}

#[test]
fn metrics_observer_never_perturbs_the_reports() {
    // The zero-cost-when-disabled half of the contract, both ways:
    // attaching a registry must not move one bit of the simulation
    // result, and a metrics-off run of the benched documents
    // (BENCH_1/2/6 builders) must reproduce their bytes exactly even
    // when the scenario carries a `metrics` key.
    use flux::obs::Metrics;
    use flux::serving::scale::{
        run_scale, run_scale_observed, ScaleScenario,
    };
    use flux::training::{
        run_train, run_train_observed, TrainScenario,
    };

    let sc = ScaleScenario::quick(&SCALE_H800_TP8_DP4);
    for m in Method::SERVE_SET {
        let plain = run_scale(&sc, m).unwrap();
        let mut metrics = Metrics::new(sc.seed);
        let observed =
            run_scale_observed(&sc, m, None, None, Some(&mut metrics))
                .unwrap();
        assert_eq!(plain.completed, observed.completed);
        assert_eq!(plain.tokens, observed.tokens);
        assert_eq!(plain.makespan_ns.to_bits(), observed.makespan_ns.to_bits());
        assert_eq!(
            plain.tokens_per_sec.to_bits(),
            observed.tokens_per_sec.to_bits()
        );
        let doc = metrics.to_json().to_string();
        assert!(doc.contains("serve.admitted"), "observer recorded");
    }

    let tr = TrainScenario::quick(&TRAIN_NVLINK_128);
    for m in Method::TRAIN_SET {
        let plain = run_train(&tr, m).unwrap();
        let mut metrics = Metrics::new(tr.seed);
        let observed =
            run_train_observed(&tr, m, None, None, Some(&mut metrics))
                .unwrap();
        assert_eq!(plain.step_ns.to_bits(), observed.step_ns.to_bits());
        assert_eq!(plain.pipe_ns.to_bits(), observed.pipe_ns.to_bits());
        assert_eq!(plain.dp_exposed_ns.to_bits(), observed.dp_exposed_ns.to_bits());
        let doc = metrics.to_json().to_string();
        assert!(doc.contains("train.fwd_ns"), "observer recorded");
    }

    // Report builders ignore the scenario's `metrics` key entirely.
    let runner = Runner::with_threads(2);
    let scale_sc = Scenario::serve(Some(&SCALE_H800_TP8_DP4), None, true);
    let mut scale_keyed = scale_sc.clone();
    scale_keyed.metrics = Some("unused.json".into());
    assert_eq!(
        report::scale_doc_scenario(&scale_keyed, &runner)
            .unwrap()
            .to_string(),
        report::scale_doc_scenario(&scale_sc, &runner)
            .unwrap()
            .to_string(),
        "scale doc perturbed by the metrics key"
    );
    let train_sc = Scenario::train(Some(&TRAIN_NVLINK_128), true);
    let mut train_keyed = train_sc.clone();
    train_keyed.metrics = Some("unused.json".into());
    assert_eq!(
        report::train_doc_scenario(&train_keyed, &runner)
            .unwrap()
            .to_string(),
        report::train_doc_scenario(&train_sc, &runner)
            .unwrap()
            .to_string(),
        "train doc perturbed by the metrics key"
    );
    let mut churn_sc = Scenario::serve(Some(&SCALE_H800_TP8_DP4), None, true);
    churn_sc.faults = Some(FaultsRef::Preset("replica-churn".into()));
    let mut churn_keyed = churn_sc.clone();
    churn_keyed.metrics = Some("unused.json".into());
    let spec = churn_sc.faults.as_ref().unwrap().resolved().unwrap();
    assert_eq!(
        report::churn_doc_scenario(&churn_keyed, &spec, &runner)
            .unwrap()
            .to_string(),
        report::churn_doc_scenario(&churn_sc, &spec, &runner)
            .unwrap()
            .to_string(),
        "churn doc perturbed by the metrics key"
    );
}

#[test]
fn runner_default_uses_every_core_and_flag_overrides() {
    assert!(Runner::new().threads() >= 1);
    assert_eq!(Runner::from_flag(Some(7)).threads(), 7);
    assert_eq!(Runner::with_threads(0).threads(), 1, "clamped");
}
