//! Cross-module property tests and failure injection: invariants that
//! must hold across the whole search space, not just the figure points.

use flux::cost::arch::{ALL_CLUSTERS, A100_NVLINK, A100_PCIE};
use flux::cost::gemm::{gemm_time_ns, GemmShape};
use flux::overlap::flux::{simulate as flux_sim, FluxConfig};
use flux::overlap::{baseline, medium, Op, Problem};
use flux::tuner;
use flux::util::check::forall;

fn random_problem(rng: &mut flux::util::prng::Rng) -> Problem {
    let m = [64usize, 256, 1024, 4096][rng.below(4) as usize];
    let n_tp = [2usize, 4, 8][rng.below(3) as usize];
    if rng.below(2) == 0 {
        Problem::ag(m, 49152, 12288, n_tp)
    } else {
        Problem::rs(m, 12288, 49152, n_tp)
    }
}

#[test]
fn overall_time_never_below_nonsplit_gemm() {
    // No strategy can beat the bare (launch-inclusive) GEMM: overlap
    // hides communication, it cannot create compute. (Flux can get
    // within launch overhead of it; never below.)
    forall(40, 0xF1, |rng| {
        let p = random_problem(rng);
        let cl = ALL_CLUSTERS[rng.below(3) as usize];
        let seed = rng.next_u64();
        let floor = p.gemm_nonsplit_ns(cl) * 0.999;
        assert!(baseline::simulate(cl, &p).overall_ns >= floor);
        assert!(medium::simulate(cl, &p, seed).overall_ns >= floor);
        let cfg = FluxConfig::for_cluster(cl);
        assert!(flux_sim(cl, &p, &cfg, seed).overall_ns >= floor);
    });
}

#[test]
fn baseline_ect_is_exactly_the_collective() {
    // §2.3: non-overlap ECT == pure NCCL time, always positive.
    forall(40, 0xF2, |rng| {
        let p = random_problem(rng);
        let cl = ALL_CLUSTERS[rng.below(3) as usize];
        let ect = baseline::simulate(cl, &p).ect_ns();
        assert!(ect > 0.0, "{p:?} on {}", cl.name);
    });
}

#[test]
fn gemm_time_is_monotone_in_every_dim() {
    forall(60, 0xF3, |rng| {
        let m = rng.range(8, 8192) as usize;
        let n = rng.range(32, 49152) as usize;
        let k = rng.range(32, 49152) as usize;
        let arch = &ALL_CLUSTERS[rng.below(3) as usize].arch;
        let t = gemm_time_ns(arch, &GemmShape::new(m, n, k));
        let t_m = gemm_time_ns(arch, &GemmShape::new(m * 2, n, k));
        let t_n = gemm_time_ns(arch, &GemmShape::new(m, n * 2, k));
        let t_k = gemm_time_ns(arch, &GemmShape::new(m, n, k * 2));
        assert!(t_m >= t && t_n >= t && t_k >= t * 1.2,
                "m={m} n={n} k={k}: {t} {t_m} {t_n} {t_k}");
    });
}

#[test]
fn tuned_flux_never_loses_to_any_searched_config() {
    forall(8, 0xF4, |rng| {
        let p = random_problem(rng);
        let cl = ALL_CLUSTERS[rng.below(3) as usize];
        let best = tuner::tune(cl, &p, 7);
        for cfg in tuner::search_space(cl, &p) {
            let t = flux_sim(cl, &p, &cfg, 7);
            assert!(
                best.timing.overall_ns <= t.overall_ns + 1e-6,
                "tuner missed: {cfg:?} beats {:?}", best.config
            );
        }
    });
}

#[test]
fn tp1_has_zero_communication() {
    // Degenerate 1-way TP: the collective is free; every method reduces
    // to the bare GEMM (+ launch effects).
    for op in [Op::AgGemm, Op::GemmRs] {
        let p = Problem { op, m: 1024, n: 12288, k: 12288, n_tp: 1 };
        let base = baseline::simulate(&A100_NVLINK, &p);
        assert!(base.ect_ns().abs() < 1e-6, "{op:?}: {}", base.ect_ns());
    }
}

#[test]
fn flux_scales_sanely_with_tp_degree() {
    // More ranks => smaller local GEMM => shorter overall op.
    let t = |n_tp: usize| {
        let p = Problem::ag(4096, 49152, 12288, n_tp);
        flux_sim(&A100_NVLINK, &p,
                 &FluxConfig::for_cluster(&A100_NVLINK), 7)
            .overall_ns
    };
    let (t2, t4, t8) = (t(2), t(4), t(8));
    assert!(t2 > t4 && t4 > t8, "t2={t2} t4={t4} t8={t8}");
}

#[test]
fn medium_jitter_bounded() {
    // Stream jitter perturbs but must not explode the medium-grained
    // time: across seeds the spread stays under 25%.
    let p = Problem::ag(2048, 49152, 12288, 8);
    let times: Vec<f64> = (0..12)
        .map(|s| medium::simulate(&A100_NVLINK, &p, s).overall_ns)
        .collect();
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    assert!(max / min < 1.25, "jitter spread {}", max / min);
}

#[test]
fn comm_volume_conservation_in_flux_rs() {
    // Every remote byte of the RS output must cross some ingress secure
    // in the simulator: overall >= bytes/aggregate-bandwidth bound.
    forall(20, 0xF5, |rng| {
        let m = [1024usize, 4096][rng.below(2) as usize];
        let p = Problem::rs(m, 12288, 49152, 8);
        let cl = &A100_PCIE;
        let t = flux_sim(cl, &p, &FluxConfig::for_cluster(cl), 7);
        // (N-1)/N of output crosses links; per-rank ingress share:
        let remote = p.comm_bytes() * 7.0 / 8.0 / 8.0;
        let floor = remote / cl.p2p_gbps();
        assert!(
            t.overall_ns > floor,
            "m={m}: overall {} < wire floor {floor}", t.overall_ns
        );
    });
}

#[test]
fn fuse_reduction_ablation_helps_or_ties() {
    // DESIGN.md ablation: the Alg.-1 Reduce branch (fused reduction)
    // never hurts, and strictly helps somewhere.
    let mut helped = false;
    for m in [1024usize, 4096, 8192] {
        let p = Problem::rs(m, 12288, 49152, 8);
        for cl in ALL_CLUSTERS {
            let fused = flux_sim(cl, &p,
                &FluxConfig { fuse_reduction: true,
                              ..FluxConfig::for_cluster(cl) }, 7);
            let discrete = flux_sim(cl, &p,
                &FluxConfig { fuse_reduction: false,
                              ..FluxConfig::for_cluster(cl) }, 7);
            assert!(fused.overall_ns <= discrete.overall_ns + 1e-6);
            if fused.overall_ns < discrete.overall_ns * 0.999 {
                helped = true;
            }
        }
    }
    assert!(helped, "fused reduction should matter somewhere");
}

#[test]
fn overlap_efficiency_upper_bound() {
    // Eq. 2: efficiency can approach but never exceed 100%.
    forall(30, 0xF6, |rng| {
        let p = random_problem(rng);
        let cl = ALL_CLUSTERS[rng.below(3) as usize];
        let base = baseline::simulate(cl, &p);
        let fx = flux_sim(cl, &p, &FluxConfig::for_cluster(cl), 7);
        let eff = fx.overlap_efficiency(&base);
        assert!(eff <= 1.0 + 1e-9, "{p:?} on {}: eff {eff}", cl.name);
    });
}

#[test]
fn runtime_errors_are_reported_not_panicked() {
    // Failure injection on the runtime: unknown artifacts and missing
    // manifests produce errors, not panics.
    let err = flux::runtime::Runtime::load(std::path::Path::new(
        "/nonexistent/artifacts",
    ));
    assert!(err.is_err());
    // The manifest half needs `make artifacts` (any backend); hermetic
    // checkouts only carry the golden file. Skip ONLY when the manifest
    // is genuinely absent — if it exists, a load failure is a real
    // regression this test must surface.
    let dir = flux::runtime::Runtime::artifacts_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = flux::runtime::Runtime::load_default()
            .expect("manifest.json exists, so the runtime must load");
        assert!(rt.run("no_such_artifact", &[]).is_err());
        assert!(rt.weight("no_such_weight").is_err());
    } else {
        eprintln!(
            "skipping manifest half: {} has no manifest.json \
             (run `make artifacts` to cover it)",
            dir.display()
        );
    }
}

#[test]
fn literal_shape_mismatch_rejected() {
    assert!(flux::runtime::literal_f32(&[2, 3], &[0.0; 5]).is_err());
    assert!(flux::runtime::literal_i32(&[4], &[1, 2, 3]).is_err());
}
