//! Auto-tuning demo (§4.4): search the FLUX knob space per
//! (cluster, op, shape), print what wins where, and show the cache
//! behaviour a serving/training loop relies on.
//!
//! Run: `cargo run --release --example autotune`

use flux::cost::arch::ALL_CLUSTERS;
use flux::figures::{ag_problem, rs_problem};
use flux::overlap::baseline;
use flux::tuner::{search_space, tune, TunerCache};
use flux::util::bench::table;

fn main() {
    // FLUX_SMOKE=1: one shape per cluster, for the CI example-smoke run.
    let ms: &[usize] = if std::env::var("FLUX_SMOKE").is_ok() {
        &[512]
    } else {
        &[512, 2048, 8192]
    };
    let mut rows = Vec::new();
    for cl in ALL_CLUSTERS {
        for &m in ms {
            for (tag, p) in
                [("AG", ag_problem(m, 8)), ("RS", rs_problem(m, 8))]
            {
                let space = search_space(cl, &p).len();
                let t = tune(cl, &p, 7);
                let base = baseline::simulate(cl, &p);
                rows.push(vec![
                    cl.name.to_string(),
                    tag.to_string(),
                    m.to_string(),
                    space.to_string(),
                    format!("swizzle={}", t.config.swizzle),
                    if t.config.pull { "pull" } else { "push" }.to_string(),
                    if tag == "AG" {
                        t.config.comm_rows.to_string()
                    } else {
                        "-".into()
                    },
                    format!("{:.3}", t.timing.overall_ns / 1e6),
                    format!(
                        "{:.0}%",
                        t.timing.overlap_efficiency(&base) * 100.0
                    ),
                ]);
            }
        }
    }
    table(
        "auto-tuner winners per (cluster, op, m)",
        &["cluster", "op", "m", "space", "swizzle", "dir", "comm rows",
          "overall ms", "eff"],
        &rows,
    );

    // Cache behaviour: a serving loop tunes once per shape.
    let mut cache = TunerCache::new();
    let p = ag_problem(4096, 8);
    for _ in 0..5 {
        cache.get(ALL_CLUSTERS[1], &p, 7);
    }
    println!(
        "\ntuner cache: {} entries, {} misses, {} hits \
         (tune once, reuse forever)",
        cache.len(), cache.misses, cache.hits
    );
}
