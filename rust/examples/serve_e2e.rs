//! END-TO-END serving driver (the EXPERIMENTS.md §E2E run).
//!
//! Loads the real tiny TP-4 transformer (AOT artifacts), serves a
//! batched open-loop request workload through the full coordinator
//! stack — router/batcher, paged KV-cache manager, per-rank PJRT
//! execution with host collectives between TP partials — and reports
//! latency (TTFT + end-to-end) and throughput. Correctness is asserted
//! en route: the first prefill batch is checked against the Python
//! full-model golden.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use flux::runtime::Runtime;
use flux::util::bench::Stopwatch;
use flux::serving::batcher::Work;
use flux::serving::engine::{argmax, Engine};
use flux::serving::kvcache::KvCacheManager;
use flux::serving::{Batcher, BatcherConfig, Request};
use flux::util::json::Json;
use flux::util::prng::Rng;
use flux::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    if !Runtime::pjrt_available() {
        println!(
            "serve_e2e needs the AOT artifacts on a live PJRT backend; \
             this build links the in-tree xla stub (no backend), so the \
             end-to-end run is skipped. Swap in the real xla bindings \
             and run `make artifacts` to enable it."
        );
        return Ok(());
    }
    let rt = Runtime::load_default()?;
    let art_dir = rt.dir.clone();
    println!(
        "model: tiny GPT (d={}, {} layers, TP={}), {} artifacts",
        rt.manifest.d_model, rt.manifest.n_layers, rt.manifest.n_tp,
        rt.manifest.artifacts.len()
    );
    let mut eng = Engine::new(rt)?;

    // --- correctness gate: prefill against the Python golden ----------
    let golden = Json::parse(&std::fs::read_to_string(
        art_dir.join("golden_swizzle.json"),
    )?)?;
    let p = golden.get("prefill")?;
    let lens = p.get("lens")?.usize_vec()?;
    let prompts: Vec<Vec<i32>> = p
        .get("ids")?
        .as_arr()?
        .iter()
        .zip(&lens)
        .map(|(row, &l)| {
            row.as_arr().unwrap()[..l]
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect()
        })
        .collect();
    let got = eng.prefill(&prompts)?;
    let want: Vec<Vec<f64>> = p
        .get("last_logits")?
        .as_arr()?
        .iter()
        .map(|r| r.f64_vec().unwrap())
        .collect();
    let mut max_diff = 0.0f64;
    for (g, w) in got.iter().zip(&want) {
        for (x, y) in g.iter().zip(w) {
            max_diff = max_diff.max((*x as f64 - y).abs());
        }
    }
    anyhow::ensure!(max_diff < 5e-3, "golden mismatch: {max_diff}");
    println!(
        "correctness gate: rust TP execution == python full model \
         (max logit diff {max_diff:.2e})"
    );

    // --- open-loop workload -------------------------------------------
    let n_requests = 12usize;
    let gen_len = 12usize;
    let mut rng = Rng::new(99);
    let mut batcher = Batcher::new(BatcherConfig {
        max_prefill_batch: eng.b,
        max_decode_batch: eng.b,
        max_prompt: eng.s,
        max_seq: eng.smax,
        ..Default::default()
    });
    let mut kv = KvCacheManager::new(96, 16);
    for i in 0..n_requests as u64 {
        let plen = rng.range(4, 33) as usize;
        let prompt: Vec<i32> = (0..plen)
            .map(|_| rng.below(eng.vocab as u64) as i32)
            .collect();
        batcher.submit(Request::new(i, 0.0, prompt, gen_len));
    }

    let t0 = Stopwatch::start();
    let now_ns = |t0: &Stopwatch| t0.elapsed_ns();
    let mut last_tok = vec![0i32; eng.b];
    let mut slot_of = std::collections::BTreeMap::new();
    let mut prefill_batches = 0usize;
    let mut decode_steps = 0usize;
    loop {
        match batcher.next_work(&mut kv)? {
            Work::Prefill(ids) => {
                prefill_batches += 1;
                let prompts: Vec<Vec<i32>> = ids
                    .iter()
                    .map(|&id| batcher.get(id).prompt.clone())
                    .collect();
                let logits = eng.prefill(&prompts)?;
                let mut toks = Vec::new();
                for (slot, &id) in ids.iter().enumerate() {
                    slot_of.insert(id, slot);
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                    batcher.get_mut(id).prefill_done_ns =
                        Some(now_ns(&t0));
                }
                batcher.complete_decode(&ids, &toks, &mut kv, now_ns(&t0))?;
            }
            Work::Decode(ids) => {
                decode_steps += 1;
                let logits = eng.decode_step(&last_tok)?;
                let mut toks = Vec::new();
                for &id in &ids {
                    let slot = slot_of[&id];
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                }
                batcher.complete_decode(&ids, &toks, &mut kv, now_ns(&t0))?;
            }
            Work::Idle => break,
        }
        kv.check_invariants()?;
    }
    let wall = t0.elapsed();

    // --- report --------------------------------------------------------
    let ttfts: Vec<f64> = batcher
        .requests
        .iter()
        .filter_map(|r| r.ttft_ns())
        .map(|x| x / 1e6)
        .collect();
    let lats: Vec<f64> = batcher
        .requests
        .iter()
        .filter_map(|r| r.latency_ns())
        .map(|x| x / 1e6)
        .collect();
    let total_toks: usize =
        batcher.requests.iter().map(|r| r.generated.len()).sum();
    let ttft = Summary::of(&ttfts);
    let lat = Summary::of(&lats);
    println!("\n=== serve_e2e report ===");
    println!("requests completed   : {n_requests}");
    println!("tokens generated     : {total_toks}");
    println!(
        "prefill batches      : {prefill_batches}   decode steps: \
         {decode_steps}"
    );
    println!("wall time            : {:.2?}", wall);
    println!(
        "throughput           : {:.1} tok/s",
        total_toks as f64 / wall.as_secs_f64()
    );
    println!(
        "TTFT ms              : p50 {:.1}  p95 {:.1}  max {:.1}",
        ttft.p50, ttft.p95, ttft.max
    );
    println!(
        "latency ms           : p50 {:.1}  p95 {:.1}  max {:.1}",
        lat.p50, lat.p95, lat.max
    );
    println!(
        "KV peak blocks       : {} / {}",
        kv.peak_used, kv.total_blocks
    );
    println!("PJRT executions      : {}", eng.rt.execute_calls);
    anyhow::ensure!(
        batcher.requests.iter().all(|r| r.generated.len() == gen_len),
        "every request must complete"
    );
    Ok(())
}
