//! Quickstart: the FLUX idea in one file.
//!
//! 1. Run the REAL fused GEMM+ReduceScatter Pallas kernels (AOT-compiled
//!    to `artifacts/*.hlo.txt`) for 4 simulated ranks on the PJRT CPU
//!    client, do the AlltoAll transport + local reduction in Rust, and
//!    check the result against the monolithic computation.
//! 2. Price the same op at paper scale on the cluster simulator and
//!    print Effective Communication Time / overlap efficiency for
//!    PyTorch vs TransformerEngine vs Flux.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use flux::collectives::host::{all_to_all, local_reduce, Mat};
use flux::cost::arch::A100_NVLINK;
use flux::overlap::numeric;
use flux::overlap::{baseline, medium, Problem};
use flux::runtime::{literal_f32, to_f32_vec, Runtime};
use flux::tuner;
use flux::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // ---- Part 1: real numerics through the fused kernels ------------
    // Needs the AOT artifacts and a live PJRT backend; on a hermetic
    // checkout (in-tree xla stub, goldens only) this part is skipped
    // and the simulated half below still runs.
    if Runtime::pjrt_available() {
        part1_real_numerics()?;
    } else {
        println!(
            "skipping fused-kernel PJRT demo: this build links the \
             in-tree xla stub (no backend); run `make artifacts` with \
             the real xla bindings to enable it\n"
        );
    }
    part2_paper_scale();
    Ok(())
}

fn part1_real_numerics() -> anyhow::Result<()> {
    let mut rt = Runtime::load_default()?;
    let man = rt.manifest.clone();
    let (n_tp, m, n) = (man.op_n_tp, man.op_m, man.op_n);
    let kl = man.op_k / n_tp;
    println!(
        "fused GEMM+ReduceScatter: {n_tp} ranks, local GEMM {m}x{n}x{kl}"
    );

    let mut rng = Rng::new(2024);
    let a: Vec<Mat> = (0..n_tp)
        .map(|_| Mat::from_vec(m, kl, rng.normal_vec(m * kl)))
        .collect();
    let b: Vec<Mat> = (0..n_tp)
        .map(|_| Mat::from_vec(kl, n, rng.normal_vec(kl * n)))
        .collect();

    // Each rank's fused kernel: GEMM whose epilogue scatters every
    // output tile to its destination rank (Alg. 1) — compiled from the
    // Pallas kernel in python/compile/kernels/flux_gemm_rs.py.
    let mut scattered = Vec::new();
    for r in 0..n_tp {
        let a_lit = literal_f32(&[m, kl], &a[r].data)?;
        let b_lit = literal_f32(&[kl, n], &b[r].data)?;
        let out = rt.run(&format!("flux_gemm_rs_r{r}"), &[&a_lit, &b_lit])?;
        let flat = to_f32_vec(&out[0])?;
        let per = m / n_tp;
        scattered.push(
            (0..n_tp)
                .map(|d| {
                    Mat::from_vec(per, n,
                        flat[d * per * n..(d + 1) * per * n].to_vec())
                })
                .collect::<Vec<_>>(),
        );
    }
    // The decoupled ReduceScatter (§3.1): AlltoAll + local reduce.
    let received = all_to_all(&scattered)?;
    let got: Vec<Mat> = received.iter().map(|r| local_reduce(r)).collect();
    let want = numeric::gemm_rs_reference(&a, &b)?;
    let mut max_diff = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        max_diff = max_diff.max(g.max_abs_diff(w));
    }
    println!(
        "  fused-kernel RS vs monolithic reference: max |diff| = \
         {max_diff:.2e}  {}",
        if max_diff < 1e-2 { "OK" } else { "FAIL" }
    );
    assert!(max_diff < 1e-2);
    Ok(())
}

// ---- Part 2: the same op at paper scale, simulated -------------------
fn part2_paper_scale() {
    let p = Problem::rs(4096, 12288, 49152, 8);
    let cl = &A100_NVLINK;
    println!(
        "\npaper-scale {} m={} on {} (simulated):",
        p.op.name(), p.m, cl.name
    );
    let base = baseline::simulate(cl, &p);
    let te = medium::simulate(cl, &p, 7);
    let fx = tuner::tune(cl, &p, 7);
    println!(
        "  GEMM (Eq.1 non-split): {:8.3} ms",
        base.gemm_nonsplit_ns / 1e6
    );
    for (name, t) in [
        ("PyTorch + NCCL", base),
        ("TransformerEngine", te),
        ("Flux (auto-tuned)", fx.timing),
    ] {
        println!(
            "  {name:18}: overall {:8.3} ms   ECT {:8.3} ms   eff {:5.1}%",
            t.overall_ns / 1e6,
            t.ect_ns() / 1e6,
            t.overlap_efficiency(&base) * 100.0
        );
    }
}
