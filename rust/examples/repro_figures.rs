//! Regenerate every table and figure of the paper's evaluation in one
//! run (the same generators back the per-figure benches).
//!
//! Run: `cargo run --release --example repro_figures`
//!
//! `FLUX_SMOKE=1` prints only the cheap closed-form/simulator figures —
//! the CI example-smoke test uses it to bound debug-mode runtime.

fn main() {
    if std::env::var("FLUX_SMOKE").is_ok() {
        for t in [
            flux::figures::fig01(),
            flux::figures::fig04(),
            flux::figures::fig08(),
            flux::figures::fig09(),
        ] {
            flux::figures::print_table(&t);
        }
        println!("\n(FLUX_SMOKE set: tuner-heavy figures skipped)");
        return;
    }
    for t in flux::figures::all() {
        flux::figures::print_table(&t);
    }
}
