//! Model-level training comparison on the simulated 128-GPU clusters
//! (the Fig. 16 training rows): Megatron-LM (non-overlap) vs
//! TransformerEngine vs Flux for GPT-3 175B and Llama-2 70B under
//! DP2 x PP8 x TP8 with a 1F1B pipeline.
//!
//! Run: `cargo run --release --example train_cluster`

use flux::cost::arch::ALL_CLUSTERS;
use flux::model::analysis::comm_portion;
use flux::model::configs::{GPT3_175B, LLAMA2_70B};
use flux::parallel::{stage_times, train_step_ns, Layout, Method};
use flux::util::bench::table;

fn main() {
    let layout = Layout::PAPER_TRAINING;
    // FLUX_SMOKE=1: fewer microbatches, for the CI example-smoke run
    // (step-time *ratios* are unaffected; only fill/drain shares move).
    let smoke = std::env::var("FLUX_SMOKE").is_ok();
    let (micro, tokens, seq) =
        (if smoke { 4usize } else { 16 }, 2048usize, 2048usize);
    println!(
        "training layout: DP{} x PP{} x TP{} = {} GPUs, {} microbatches \
         of {} tokens",
        layout.dp, layout.pp, layout.tp, layout.gpus(), micro, tokens
    );

    let mut rows = Vec::new();
    for cl in ALL_CLUSTERS {
        for model in [&GPT3_175B, &LLAMA2_70B] {
            let step = |m: Method| {
                train_step_ns(cl, model, &layout, micro, tokens, seq, m, 7)
            };
            let base = step(Method::NonOverlap);
            let te = step(Method::Medium);
            let fx = step(Method::Flux);
            let portion =
                comm_portion(cl, model, tokens, seq, layout.tp, true)
                    .fraction();
            rows.push(vec![
                cl.name.to_string(),
                model.name.to_string(),
                format!("{:.0}%", portion * 100.0),
                format!("{:.0}", base / 1e6),
                format!("{:.0}", te / 1e6),
                format!("{:.0}", fx / 1e6),
                format!("{:.2}x", base / fx),
                format!("{:.2}x", te / fx),
            ]);
        }
    }
    table(
        "Fig 16 (training): step time per method",
        &["cluster", "model", "comm %", "Megatron ms", "TE ms", "Flux ms",
          "Flux vs Megatron", "Flux vs TE"],
        &rows,
    );

    // Stage-level detail for one configuration.
    let cl = ALL_CLUSTERS[0];
    println!("\nper-microbatch stage times on {} (GPT-3 175B):", cl.name);
    for m in Method::ALL {
        let st = stage_times(cl, &GPT3_175B, &layout, tokens, seq, m, 7);
        println!(
            "  {:12} fwd {:7.1} ms   bwd {:7.1} ms",
            m.name(), st.fwd_ns / 1e6, st.bwd_ns / 1e6
        );
    }
}
