//! Hermetic golden generator: `flux gen-goldens`.
//!
//! Emits `artifacts/golden_swizzle.json` from the *Rust* tile
//! bookkeeping (overlap/tiles.rs), covering exactly the case grid
//! `python/compile/aot.py::export_goldens` emits from the Python
//! reference (`kernels/ref.py` + `flux_ag_gemm.comm_tile_schedule`).
//!
//! Two producers, one consumer: `rust/tests/golden.rs` parses the file
//! and re-derives every case from the Rust functions, so
//!
//! * with JAX available, `make artifacts` writes the Python version and
//!   the test is a true cross-language check;
//! * without JAX (clean CI checkout), the checked-in copy of this
//!   generator's output keeps the suite hermetic — and because this
//!   generator shares no code path with the *test's* expectations
//!   beyond the functions under test, it still guards the JSON plumbing
//!   and the schedule shape.
//!
//! Output is deterministic byte-for-byte: `util::json` writes objects in
//! BTreeMap (sorted-key) order and all golden values are integers.

use std::path::Path;

use anyhow::{Context, Result};

use crate::overlap::tiles;
use crate::util::json::{obj, Json};

/// The swizzle/ring case grid of aot.py: N_TP in {2, 4, 8}, every rank,
/// 4 row-tiles per rank.
const TP_DEGREES: [usize; 3] = [2, 4, 8];

/// The comm-schedule case grid of aot.py: (m, n_tp, comm rows).
const COMM_CASES: [(usize, usize, usize); 3] =
    [(128, 4, 16), (256, 8, 32), (64, 2, 32)];

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::from(x)).collect())
}

/// Build the golden document.
pub fn golden_doc() -> Json {
    let mut swizzle = Vec::new();
    let mut ring = Vec::new();
    for n_tp in TP_DEGREES {
        for rank in 0..n_tp {
            let num_tiles = 4 * n_tp;
            swizzle.push(obj(vec![
                ("num_tiles", Json::from(num_tiles)),
                ("rank", Json::from(rank)),
                ("n_tp", Json::from(n_tp)),
                (
                    "order",
                    usize_arr(&tiles::swizzle_order(num_tiles, rank, n_tp)),
                ),
            ]));
            ring.push(obj(vec![
                ("rank", Json::from(rank)),
                ("n_tp", Json::from(n_tp)),
                ("order", usize_arr(&tiles::ring_comm_order(rank, n_tp))),
            ]));
        }
    }
    let mut comm_sched = Vec::new();
    for (m, n_tp, rows) in COMM_CASES {
        for rank in 0..n_tp {
            let schedule = tiles::comm_schedule(m, rank, n_tp, rows, true);
            let sched: Vec<Json> = schedule
                .into_iter()
                .map(|t| {
                    obj(vec![
                        ("src", Json::from(t.src)),
                        ("dst", Json::from(t.dst)),
                        ("row0", Json::from(t.row0)),
                        ("rows", Json::from(t.rows)),
                        ("pull", Json::from(true)),
                        ("signal", Json::from(t.signal)),
                    ])
                })
                .collect();
            comm_sched.push(obj(vec![
                ("m", Json::from(m)),
                ("rank", Json::from(rank)),
                ("n_tp", Json::from(n_tp)),
                ("rows", Json::from(rows)),
                ("schedule", Json::Arr(sched)),
            ]));
        }
    }
    obj(vec![
        ("swizzle", Json::Arr(swizzle)),
        ("ring", Json::Arr(ring)),
        ("comm_sched", Json::Arr(comm_sched)),
    ])
}

/// Write the golden document to `path`, creating parent directories.
pub fn write_goldens(path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, golden_doc().to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        assert_eq!(golden_doc().to_string(), golden_doc().to_string());
    }

    #[test]
    fn document_round_trips_and_covers_all_sections() {
        let doc = golden_doc();
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, doc);
        let n_ranks: usize = TP_DEGREES.iter().sum();
        let section_len = |key: &str| {
            parsed.get(key).unwrap().as_arr().unwrap().len()
        };
        assert_eq!(section_len("swizzle"), n_ranks);
        assert_eq!(section_len("ring"), n_ranks);
        let cs: usize = COMM_CASES.iter().map(|&(_, n, _)| n).sum();
        assert_eq!(
            parsed.get("comm_sched").unwrap().as_arr().unwrap().len(),
            cs
        );
    }

    #[test]
    fn cases_agree_with_tile_functions() {
        // The consumer-side decode of every case must re-derive exactly.
        let doc = golden_doc();
        for c in doc.get("swizzle").unwrap().as_arr().unwrap() {
            let num = c.get("num_tiles").unwrap().as_usize().unwrap();
            let rank = c.get("rank").unwrap().as_usize().unwrap();
            let n_tp = c.get("n_tp").unwrap().as_usize().unwrap();
            assert_eq!(
                c.get("order").unwrap().usize_vec().unwrap(),
                tiles::swizzle_order(num, rank, n_tp)
            );
        }
        for c in doc.get("comm_sched").unwrap().as_arr().unwrap() {
            let sched = c.get("schedule").unwrap().as_arr().unwrap();
            assert!(!sched.is_empty());
            // Signals are unique within a schedule (golden invariant).
            let mut sigs: Vec<usize> = sched
                .iter()
                .map(|t| t.get("signal").unwrap().as_usize().unwrap())
                .collect();
            sigs.sort_unstable();
            sigs.dedup();
            assert_eq!(sigs.len(), sched.len());
        }
    }

    #[test]
    fn writes_to_disk() {
        let dir = std::env::temp_dir().join("flux_golden_test");
        let path = dir.join("golden_swizzle.json");
        write_goldens(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), golden_doc());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
