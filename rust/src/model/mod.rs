//! Model configurations and tensor-parallel communication analysis.
//!
//! The paper evaluates GPT-3 175B and Llama-2 70B; at the model level the
//! coordinator only needs shapes, FLOPs and the TP collective volumes per
//! layer — the numerics live in the tiny exported transformer
//! (python/compile/model.py) served by `serving::engine`.

pub mod analysis;
pub mod configs;

pub use analysis::*;
pub use configs::*;
