//! Per-layer TP op extraction + communication-portion analysis (Fig. 1).
//!
//! With the paper's partitioning (Fig. 2 + Megatron attention), one
//! transformer layer under N-way TP performs, per forward pass over
//! m = batch * seq tokens:
//!
//!   attention:  AG+GEMM  (m, 3d, d)   — qkv projection
//!               GEMM+RS  (m, d, d)    — output projection
//!   MLP:        AG+GEMM  (m, ff, d)   — up projection
//!               GEMM+RS  (m, d, ff)   — down projection
//!
//! Backward doubles the GEMM work (dgrad + wgrad) and mirrors the
//! collectives (AG <-> RS interchange, §2.1), i.e. the same four comm
//! volumes again.

use crate::cost::arch::ClusterSpec;
use crate::cost::gemm::{gemm_time_ns, GemmShape};
use crate::model::configs::TransformerConfig;
use crate::overlap::{Op, Problem};

/// The four TP'd GEMMs of one layer's forward, global shapes.
pub fn layer_fwd_ops(
    cfg: &TransformerConfig,
    m: usize,
    n_tp: usize,
) -> Vec<Problem> {
    // Megatron pads token counts to the TP degree; tiny decode batches
    // are padded the same way here.
    let m = m.div_ceil(n_tp) * n_tp;
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    vec![
        Problem { op: Op::AgGemm, m, n: 3 * d, k: d, n_tp },
        Problem { op: Op::GemmRs, m, n: d, k: d, n_tp },
        Problem { op: Op::AgGemm, m, n: ff, k: d, n_tp },
        Problem { op: Op::GemmRs, m, n: d, k: ff, n_tp },
    ]
}

/// Backward-pass (dgrad) TP ops: collectives interchanged AND the GEMM
/// transposed. For a forward AG+GEMM C[m,n] = AG(x)[m,k] @ W[k,n/N],
/// dgrad is dx = dy[m,n/N] @ W^T -> partial [m,k] -> ReduceScatter:
/// a GemmRs with (n, k) swapped — and vice versa. Communication volume
/// per op is m*d in both directions, matching Megatron.
pub fn layer_bwd_ops(
    cfg: &TransformerConfig,
    m: usize,
    n_tp: usize,
) -> Vec<Problem> {
    layer_fwd_ops(cfg, m, n_tp)
        .into_iter()
        .map(|p| Problem {
            op: match p.op {
                Op::AgGemm => Op::GemmRs,
                Op::GemmRs => Op::AgGemm,
            },
            n: p.k,
            k: p.n,
            ..p
        })
        .collect()
}

/// Non-TP compute in a layer that the collectives never touch:
/// the attention score/context matmuls (2 * m * seq * d flops each
/// direction), priced as plain GEMMs.
pub fn layer_attention_extra_ns(
    cluster: &ClusterSpec,
    cfg: &TransformerConfig,
    m: usize,
    seq: usize,
    n_tp: usize,
) -> f64 {
    // Per rank: heads/N, so d/N width. Scores: [m, seq] x heads_local.
    let d_local = cfg.d_model / n_tp;
    // Two GEMMs: QK^T (m x seq x d_local) and PV (m x d_local x seq).
    2.0 * gemm_time_ns(&cluster.arch, &GemmShape::new(m, seq, d_local))
}

/// Backward GEMM multiplier: dgrad + wgrad.
pub const BWD_GEMM_FACTOR: f64 = 2.0;

/// Fig.-1 style analysis: fraction of per-layer time that is exposed
/// TP communication under the *non-overlapping* method.
pub struct CommPortion {
    pub compute_ns: f64,
    pub comm_ns: f64,
}

impl CommPortion {
    pub fn fraction(&self) -> f64 {
        self.comm_ns / (self.comm_ns + self.compute_ns)
    }
}

/// Communication portion for one layer forward (+ optionally backward),
/// the quantity Fig. 1 plots per cluster/model/phase.
pub fn comm_portion(
    cluster: &ClusterSpec,
    cfg: &TransformerConfig,
    m: usize,
    seq: usize,
    n_tp: usize,
    with_backward: bool,
) -> CommPortion {
    use crate::cost::comm::{ring_all_gather_ns, ring_reduce_scatter_ns};
    let mut compute = layer_attention_extra_ns(cluster, cfg, m, seq, n_tp);
    let mut comm = 0.0;
    let add_ops = |ops: &[Problem], factor: f64, c: &mut f64, x: &mut f64| {
        for p in ops {
            *x += factor * gemm_time_ns(&cluster.arch, &p.local_gemm());
            *c += match p.op {
                Op::AgGemm => {
                    ring_all_gather_ns(cluster, n_tp, p.comm_bytes())
                }
                Op::GemmRs => {
                    ring_reduce_scatter_ns(cluster, n_tp, p.comm_bytes())
                }
            };
        }
    };
    add_ops(&layer_fwd_ops(cfg, m, n_tp), 1.0, &mut comm, &mut compute);
    if with_backward {
        compute +=
            layer_attention_extra_ns(cluster, cfg, m, seq, n_tp) * 2.0;
        add_ops(
            &layer_bwd_ops(cfg, m, n_tp),
            BWD_GEMM_FACTOR,
            &mut comm,
            &mut compute,
        );
    }
    CommPortion { compute_ns: compute, comm_ns: comm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};
    use crate::model::configs::{GPT3_175B, LLAMA2_70B};

    #[test]
    fn gpt3_ops_match_the_papers_shapes() {
        let ops = layer_fwd_ops(&GPT3_175B, 4096, 8);
        // MLP up: AG with (n, k) = (49152, 12288); down: RS (12288, 49152).
        assert_eq!((ops[2].n, ops[2].k), (49152, 12288));
        assert_eq!((ops[3].n, ops[3].k), (12288, 49152));
    }

    #[test]
    fn bwd_interchanges_collectives() {
        let fwd = layer_fwd_ops(&GPT3_175B, 1024, 8);
        let bwd = layer_bwd_ops(&GPT3_175B, 1024, 8);
        for (f, b) in fwd.iter().zip(&bwd) {
            assert_ne!(f.op, b.op);
            // Transposed GEMM: n and k swap; m preserved.
            assert_eq!((f.m, f.n, f.k), (b.m, b.k, b.n));
        }
    }

    #[test]
    fn fig1_ordering_of_comm_portions() {
        // Fig. 1: PCIe training ~40-75%, A100 NVLink ~8-11%, H800 in
        // between; inference (prefill, no bwd) similar ordering.
        let m = 4096;
        let pcie = comm_portion(&A100_PCIE, &GPT3_175B, m, 2048, 8, true)
            .fraction();
        let nvl = comm_portion(&A100_NVLINK, &GPT3_175B, m, 2048, 8, true)
            .fraction();
        let h800 = comm_portion(&H800_NVLINK, &GPT3_175B, m, 2048, 8, true)
            .fraction();
        assert!(pcie > 0.35 && pcie < 0.85, "pcie {pcie}");
        assert!(nvl > 0.04 && nvl < 0.24, "nvl {nvl}");
        assert!(h800 > nvl, "h800 {h800} should exceed a100 nvlink {nvl}");
        assert!(pcie > h800);
    }

    #[test]
    fn llama_ops_sane() {
        let ops = layer_fwd_ops(&LLAMA2_70B, 2048, 8);
        assert_eq!(ops.len(), 4);
        assert!(ops.iter().all(|p| p.m == 2048 && p.n_tp == 8));
    }
}
