//! The evaluated model configurations (§5.2).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// MLP matrices per layer: 2 (GELU up/down) or 3 (SwiGLU).
    pub mlp_mats: usize,
    /// KV heads (GQA); == n_heads for classic MHA.
    pub kv_heads: usize,
}

/// GPT-3 175B (Brown et al. 2020): the source of the paper's op-level
/// GEMM shapes — (n, k) = (49152, 12288) for AG and (12288, 49152) for RS.
pub const GPT3_175B: TransformerConfig = TransformerConfig {
    name: "GPT-3 175B",
    n_layers: 96,
    d_model: 12288,
    n_heads: 96,
    d_ff: 49152,
    vocab: 50257,
    mlp_mats: 2,
    kv_heads: 96,
};

/// Llama-2 70B (Touvron et al. 2023): SwiGLU MLP, grouped-query
/// attention with 8 KV heads.
pub const LLAMA2_70B: TransformerConfig = TransformerConfig {
    name: "Llama-2 70B",
    n_layers: 80,
    d_model: 8192,
    n_heads: 64,
    d_ff: 28672,
    vocab: 32000,
    mlp_mats: 3,
    kv_heads: 8,
};

impl TransformerConfig {
    pub fn by_name(name: &str) -> Option<&'static TransformerConfig> {
        match name.to_ascii_lowercase().as_str() {
            "gpt3" | "gpt-3" | "gpt-3 175b" | "gpt3-175b" => Some(&GPT3_175B),
            "llama2" | "llama-2" | "llama-2 70b" | "llama2-70b" => {
                Some(&LLAMA2_70B)
            }
            _ => None,
        }
    }

    /// Approximate parameter count (embeddings + per-layer matrices).
    pub fn params(&self) -> f64 {
        let d = self.d_model as f64;
        let kv_frac = self.kv_heads as f64 / self.n_heads as f64;
        let per_layer = (2.0 + 2.0 * kv_frac) * d * d // q,o + GQA k,v
            + self.mlp_mats as f64 * d * self.d_ff as f64
            + 4.0 * d; // norms
        self.n_layers as f64 * per_layer + self.vocab as f64 * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt3_is_roughly_175b() {
        let p = GPT3_175B.params();
        assert!(p > 1.6e11 && p < 1.9e11, "params {p:.3e}");
    }

    #[test]
    fn llama2_is_roughly_70b() {
        let p = LLAMA2_70B.params();
        assert!(p > 6.0e10 && p < 8.0e10, "params {p:.3e}");
    }

    #[test]
    fn op_level_shapes_come_from_gpt3() {
        // §5.1: (n, k) = (49152, 12288) in AllGather — that is (d_ff, d).
        assert_eq!(GPT3_175B.d_ff, 49152);
        assert_eq!(GPT3_175B.d_model, 12288);
    }

    #[test]
    fn lookup() {
        assert_eq!(TransformerConfig::by_name("gpt3"), Some(&GPT3_175B));
        assert_eq!(TransformerConfig::by_name("LLaMA2"), Some(&LLAMA2_70B));
        assert!(TransformerConfig::by_name("bert").is_none());
    }
}
