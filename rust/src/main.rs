//! `flux` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   figures      regenerate every paper table/figure (default)
//!   simulate     one op-level comparison (--cluster, --op, --m, --tp)
//!   tune         auto-tune one problem and print the winning config
//!   train        model-level training step comparison
//!   serve        run the REAL tiny TP transformer on PJRT via the batcher
//!   sweep-workloads  workload preset x topology serving matrix
//!   gen-goldens  emit artifacts/golden_swizzle.json hermetically (no JAX)
//!   bench        run the pinned-seed suite; --json writes BENCH_<n>.json
//!
//! Examples:
//!   flux simulate --cluster "a100 nvlink" --op rs --m 4096
//!   flux simulate --scale --workload bursty-decode --quick
//!   flux simulate --scale --topo "1-node tp8" --trace trace.json
//!   flux sweep-workloads --quick --json
//!   flux tune --cluster "a100 pcie" --op ag --m 8192
//!   flux serve --requests 6 --gen 8
//!   flux gen-goldens
//!   flux bench --json --quick

use anyhow::{bail, Result};

use flux::cost::arch::ClusterSpec;
use flux::figures;
use flux::model::configs::TransformerConfig;
use flux::overlap::{baseline, medium, Problem};
use flux::parallel::{train_step_ns, Layout, Method};
use flux::runtime::Runtime;
use flux::serving::engine::{argmax, Engine};
use flux::serving::kvcache::KvCacheManager;
use flux::serving::{Batcher, BatcherConfig, Request};
use flux::tuner;
use flux::util::cli::Args;

const USAGE: &str = "\
flux — FLUX (fine-grained communication overlap) reproduction CLI

USAGE:
    flux [COMMAND] [FLAGS]

COMMANDS:
    figures      regenerate every paper table/figure (default)
                   [--json <path>] also write the tables as JSON
    simulate     one op-level comparison
                   [--cluster <name>] [--op ag|rs] [--m <rows>]
                   [--tp <degree>] [--seed <n>]
                 --scale: multi-node TP x DP serving-at-scale sweep
                   (seeded arrivals, per-replica continuous batching,
                   flux vs decoupled per topology); [--topo <name>]
                   restricts to one topology, [--quick] trims the
                   workload, [--workload <preset|file.json>] swaps
                   the request source (arrival process, length mix,
                   routing, SLOs), [--trace <path>] (with --topo)
                   dumps the DES event stream as chrome://tracing
                   JSON, [--json] writes the byte-stable
                   flux-scale-v2 report ([--out <path>], default
                   BENCH_<n>.json)
                 --train: event-driven DP x PP x TP training sweep
                   (1F1B microbatch schedule on the DES, PP hops on
                   NIC links, DP all-reduce streamed behind backward;
                   megatron vs TE vs flux per topology); same
                   [--topo] [--quick] [--json] [--out] [--trace]
                   flags, report schema flux-train-v1
    tune         auto-tune one problem, print the winning config
                   (same flags as simulate)
    train        model-level training-step comparison
                   [--cluster <name>] [--model gpt3|llama2]
                   [--microbatches <n>]
    serve        run the real tiny TP transformer on PJRT
                   [--requests <n>] [--gen <tokens>]
                   (needs `make artifacts` + the real xla bindings)
    sweep-workloads  run every workload preset (poisson-balanced,
                   steady/bursty-decode, open/closed-prefill,
                   diurnal-chat, long-context) on every serving
                   topology, flux vs decoupled; [--quick] trims
                   request counts, [--json] writes the byte-stable
                   flux-sweep-v1 report ([--out <path>])
    gen-goldens  emit the cross-language golden file from the Rust tile
                   bookkeeping [--out <path>] (default:
                   <artifacts dir>/golden_swizzle.json)
    bench        pinned-seed benchmark suite
                   --json write BENCH_<n>.json (byte-stable) instead of
                          printing; [--out <path>] [--quick] [--wall]

Clusters: \"a100 pcie\" | \"a100 nvlink\" | \"h800 nvlink\"
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let first = argv.first().map(|s| s.as_str()).unwrap_or("figures");
    // `--help` anywhere wins (so `flux bench --help` works too).
    if first == "help"
        || argv.iter().any(|a| matches!(a.as_str(), "--help" | "-h"))
    {
        print!("{USAGE}");
        return Ok(());
    }
    // A leading flag means "no command named": keep the historical
    // default of `figures` and hand it the whole argv (so e.g.
    // `flux --json report.json` still writes the JSON report).
    let (cmd, flag_args) = if first.starts_with("--") {
        ("figures", &argv[..])
    } else {
        (first, &argv[1..])
    };
    // Commands take flags only; parse everything after the command name
    // with the command's switch set (flags not listed consume a value).
    let rest = || flag_args.iter().cloned();
    match cmd {
        "figures" => cmd_figures(&Args::parse(rest(), &["verbose"])?),
        // `--scale` selects a different flag set: json/quick become
        // switches there, while the plain op-level form keeps rejecting
        // them (they would be silently ignored otherwise).
        "simulate"
            if flag_args.iter().any(|a| a == "--scale")
                && flag_args.iter().any(|a| a == "--train") =>
        {
            bail!("--scale and --train are separate sweeps; pick one")
        }
        "simulate" if flag_args.iter().any(|a| a == "--scale") => {
            cmd_simulate_scale(&Args::parse(
                rest(),
                &["verbose", "scale", "json", "quick"],
            )?)
        }
        "simulate" if flag_args.iter().any(|a| a == "--train") => {
            cmd_simulate_train(&Args::parse(
                rest(),
                &["verbose", "train", "json", "quick"],
            )?)
        }
        "simulate" => cmd_simulate(&Args::parse(rest(), &["verbose"])?),
        "sweep-workloads" => cmd_sweep_workloads(&Args::parse(
            rest(),
            &["json", "quick"],
        )?),
        "tune" => cmd_tune(&Args::parse(rest(), &["verbose"])?),
        "train" => cmd_train(&Args::parse(rest(), &["verbose"])?),
        "serve" => cmd_serve(&Args::parse(rest(), &["verbose"])?),
        "gen-goldens" => cmd_gen_goldens(&Args::parse(rest(), &[])?),
        "bench" => {
            cmd_bench(&Args::parse(rest(), &["json", "quick", "wall"])?)
        }
        other => bail!(
            "unknown command {other:?}; try figures|simulate|\
             sweep-workloads|tune|train|serve|gen-goldens|bench \
             (or --help)"
        ),
    }
}

fn cmd_gen_goldens(args: &Args) -> Result<()> {
    let path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => Runtime::artifacts_dir().join("golden_swizzle.json"),
    };
    flux::goldens::write_goldens(&path)?;
    println!("wrote goldens to {}", path.display());
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.has("quick");
    let wall = args.has("wall");
    // `--out` only makes sense for a file report: it implies `--json`.
    let json = args.has("json") || args.get("out").is_some();
    if json {
        let out = args.get("out").map(std::path::Path::new);
        let path = flux::report::write_bench(quick, wall, out)?;
        println!("wrote bench report to {}", path.display());
    } else {
        flux::report::print_bench(&flux::report::bench_doc(quick))?;
        if wall {
            // Bench::run prints one line per hotpath as it measures.
            println!("\nwall-clock hotpath timings (machine-local):");
            let _ = flux::report::wall_doc();
        }
    }
    Ok(())
}

fn cluster_of(args: &Args) -> Result<&'static ClusterSpec> {
    let name = args.get_or("cluster", "a100 nvlink");
    ClusterSpec::by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown cluster {name:?} (a100-pcie | a100-nvlink | h800-nvlink)"
        )
    })
}

fn problem_of(args: &Args) -> Result<Problem> {
    let m = args.get_usize("m", 4096)?;
    let tp = args.get_usize("tp", 8)?;
    Ok(match args.get_or("op", "rs") {
        "ag" => figures::ag_problem(m, tp),
        "rs" => figures::rs_problem(m, tp),
        o => bail!("unknown --op {o:?} (ag|rs)"),
    })
}

fn cmd_figures(args: &Args) -> Result<()> {
    for t in figures::all() {
        figures::print_table(&t);
    }
    if let Some(path) = args.get("json") {
        figures::write_json_report(std::path::Path::new(path))?;
        println!("\nwrote JSON report to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    // Reject stray flags (e.g. `--topo` without `--scale`, or a typo)
    // instead of silently simulating the defaults.
    if let Some(k) = args.flags.keys().find(|k| {
        !matches!(k.as_str(), "cluster" | "op" | "m" | "tp" | "seed")
    }) {
        bail!(
            "--{k} is not an op-level simulate flag (cluster|op|m|tp|\
             seed); the sweep flags need `simulate --scale` or \
             `simulate --train`"
        );
    }
    let cl = cluster_of(args)?;
    let p = problem_of(args)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let base = baseline::simulate(cl, &p);
    let te = medium::simulate(cl, &p, seed);
    let mut cache = tuner::TunerCache::new();
    let fx = cache.get(cl, &p, seed);
    println!(
        "{} m={} N_TP={} on {}",
        p.op.name(), p.m, p.n_tp, cl.name
    );
    println!(
        "  GEMM (non-split, Eq.1) : {:9.3} ms",
        base.gemm_nonsplit_ns / 1e6
    );
    for (name, t) in [
        ("PyTorch (no overlap)", base),
        ("TransformerEngine", te),
        ("Flux (tuned)", fx.timing),
    ] {
        println!(
            "  {name:22}: {:9.3} ms  ECT {:9.3} ms  eff {:5.1}%",
            t.overall_ns / 1e6,
            t.ect_ns() / 1e6,
            t.overlap_efficiency(&base) * 100.0
        );
    }
    println!("  tuned config: {:?}", fx.config);
    Ok(())
}

/// `flux simulate --scale`: the multi-node TP x DP serving sweep over
/// every `ScaleTopology` (or one, with `--topo`), flux vs decoupled,
/// with the request source swappable via `--workload`.
fn cmd_simulate_scale(args: &Args) -> Result<()> {
    use flux::cost::arch::{ScaleTopology, ALL_SCALE_TOPOLOGIES};
    // The sweep is pinned (fixed seeds per topology) so the report
    // stays byte-stable: reject the op-level flags instead of silently
    // ignoring them.
    if let Some(k) = args.flags.keys().find(|k| {
        !matches!(k.as_str(), "out" | "topo" | "workload" | "trace")
    }) {
        bail!("--{k} is not supported with --scale (only --topo, \
               --workload, --trace, --quick, --json, --out)");
    }
    let only = match args.get("topo") {
        Some(name) => Some(ScaleTopology::by_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown topology {name:?}; one of: {}",
                ALL_SCALE_TOPOLOGIES
                    .iter()
                    .map(|t| t.name)
                    .collect::<Vec<_>>()
                    .join(" | ")
            )
        })?),
        None => None,
    };
    let quick = args.has("quick");
    // A trace of the whole sweep would interleave topologies into one
    // meaningless timeline; require the single-topology form up front.
    if args.get("trace").is_some() && only.is_none() {
        bail!("--trace needs --topo <name>: a trace is one \
               topology's event stream");
    }
    let workload = match args.get("workload") {
        Some(arg) => {
            Some(flux::workload::WorkloadSpec::resolve(arg, quick)?)
        }
        None => None,
    };
    // `--out` implies a JSON file report, mirroring `flux bench`.
    let json = args.has("json") || args.get("out").is_some();
    if json {
        let out = args.get("out").map(std::path::Path::new);
        let path = flux::report::write_scale(
            quick,
            only,
            workload.as_ref(),
            out,
        )?;
        println!("wrote scale report to {}", path.display());
    } else {
        flux::report::print_scale(&flux::report::scale_doc_with(
            quick,
            only,
            workload.as_ref(),
        )?)?;
    }
    if let Some(trace_path) = args.get("trace") {
        // Deliberately re-simulates the (seed-deterministic, quick)
        // comparison rather than threading a Trace through the report
        // emitters: the trace is identical either way and the report
        // path stays untangled from tracing.
        let topo = only.expect("checked above");
        let wl = match &workload {
            Some(wl) => wl.clone(),
            None => flux::workload::preset("poisson-balanced", quick)
                .expect("default preset exists"),
        };
        let sc = flux::serving::scale::ScaleScenario::with_workload(
            topo, wl,
        );
        let mut trace = flux::sim::trace::Trace::new();
        flux::serving::scale::compare_scale_traced(&sc, &mut trace)?;
        let path = std::path::Path::new(trace_path);
        trace.write(path)?;
        println!(
            "wrote chrome trace ({} events) to {trace_path}",
            trace.len()
        );
    }
    Ok(())
}

/// `flux sweep-workloads`: every workload preset on every serving
/// topology, flux vs decoupled (`flux-sweep-v1`).
fn cmd_sweep_workloads(args: &Args) -> Result<()> {
    if let Some(k) =
        args.flags.keys().find(|k| !matches!(k.as_str(), "out"))
    {
        bail!(
            "--{k} is not a sweep-workloads flag (only --quick, \
             --json, --out)"
        );
    }
    let quick = args.has("quick");
    let json = args.has("json") || args.get("out").is_some();
    if json {
        let out = args.get("out").map(std::path::Path::new);
        let path = flux::report::write_sweep(quick, out)?;
        println!("wrote workload sweep report to {}", path.display());
    } else {
        flux::report::print_sweep(&flux::report::sweep_doc(quick)?)?;
    }
    Ok(())
}

/// `flux simulate --train`: the event-driven DP x PP x TP training
/// sweep over every `TrainTopology` (or one, with `--topo`), megatron
/// vs TE vs flux.
fn cmd_simulate_train(args: &Args) -> Result<()> {
    use flux::cost::arch::{TrainTopology, ALL_TRAIN_TOPOLOGIES};
    if let Some(k) = args
        .flags
        .keys()
        .find(|k| !matches!(k.as_str(), "out" | "topo" | "trace"))
    {
        bail!("--{k} is not supported with --train (only --topo, \
               --trace, --quick, --json, --out)");
    }
    let only = match args.get("topo") {
        Some(name) => Some(TrainTopology::by_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown topology {name:?}; one of: {}",
                ALL_TRAIN_TOPOLOGIES
                    .iter()
                    .map(|t| t.name)
                    .collect::<Vec<_>>()
                    .join(" | ")
            )
        })?),
        None => None,
    };
    let quick = args.has("quick");
    if args.get("trace").is_some() && only.is_none() {
        bail!("--trace needs --topo <name>: a trace is one \
               topology's event stream");
    }
    // `--out` implies a JSON file report, mirroring `flux bench`.
    let json = args.has("json") || args.get("out").is_some();
    if json {
        let out = args.get("out").map(std::path::Path::new);
        let path = flux::report::write_train(quick, only, out)?;
        println!("wrote train report to {}", path.display());
    } else {
        flux::report::print_train(&flux::report::train_doc_for(
            quick, only,
        )?)?;
    }
    if let Some(trace_path) = args.get("trace") {
        let topo = only.expect("checked above");
        let sc = if quick {
            flux::training::TrainScenario::quick(topo)
        } else {
            flux::training::TrainScenario::full(topo)
        };
        let mut trace = flux::sim::trace::Trace::new();
        flux::training::compare_train_traced(&sc, &mut trace)?;
        let path = std::path::Path::new(trace_path);
        trace.write(path)?;
        println!(
            "wrote chrome trace ({} events) to {trace_path}",
            trace.len()
        );
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let cl = cluster_of(args)?;
    let p = problem_of(args)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let t = tuner::tune(cl, &p, seed);
    println!(
        "tuned {} m={} on {} over {} candidates:",
        p.op.name(), p.m, cl.name, t.candidates_tried
    );
    println!("  config  : {:?}", t.config);
    println!("  overall : {:.3} ms", t.timing.overall_ns / 1e6);
    println!("  ECT     : {:.3} ms", t.timing.ect_ns() / 1e6);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cl = cluster_of(args)?;
    let model = TransformerConfig::by_name(args.get_or("model", "gpt3"))
        .ok_or_else(|| anyhow::anyhow!("unknown --model (gpt3|llama2)"))?;
    let micro = args.get_usize("microbatches", 16)?;
    let layout = Layout::PAPER_TRAINING;
    println!(
        "{} on {} x{} GPUs (DP{} PP{} TP{}), {} microbatches:",
        model.name, cl.name, layout.gpus(), layout.dp, layout.pp,
        layout.tp, micro
    );
    let mut base = 0.0;
    for m in Method::ALL {
        let t = train_step_ns(cl, model, &layout, micro, 2048, 2048, m, 7);
        if m == Method::NonOverlap {
            base = t;
        }
        println!(
            "  {:12}: {:9.1} ms/step  ({:.2}x)",
            m.name(), t / 1e6, base / t
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 4)?;
    let gen = args.get_usize("gen", 8)?;
    if !Runtime::pjrt_available() {
        bail!(
            "`flux serve` executes the AOT artifacts on PJRT, but this \
             build links the in-tree xla API stub (no backend). Swap \
             rust/Cargo.toml's `xla` path dependency for the real \
             bindings and run `make artifacts` first."
        );
    }
    let rt = Runtime::load_default()?;
    println!(
        "loaded {} artifacts from {} (tiny TP{} transformer, d={})",
        rt.manifest.artifacts.len(), rt.dir.display(),
        rt.manifest.n_tp, rt.manifest.d_model
    );
    let mut eng = Engine::new(rt)?;
    let mut batcher = Batcher::new(BatcherConfig {
        max_prefill_batch: eng.b,
        max_decode_batch: eng.b,
        max_prompt: eng.s,
        max_seq: eng.smax,
        ..Default::default()
    });
    let mut kv = KvCacheManager::new(64, 16);
    for i in 0..n_requests as u64 {
        let plen = 4 + (i as usize * 3) % 12;
        let prompt: Vec<i32> = (0..plen)
            .map(|t| ((i as usize * 131 + t * 17) % eng.vocab) as i32)
            .collect();
        batcher.submit(Request::new(i, 0.0, prompt, gen));
    }
    let t0 = std::time::Instant::now();
    let mut last_tok = vec![0i32; eng.b];
    let mut slot_of = std::collections::BTreeMap::new();
    loop {
        match batcher.next_work(&mut kv)? {
            flux::serving::batcher::Work::Prefill(ids) => {
                let prompts: Vec<Vec<i32>> = ids
                    .iter()
                    .map(|&id| batcher.get(id).prompt.clone())
                    .collect();
                let logits = eng.prefill(&prompts)?;
                let mut toks = Vec::new();
                for (slot, &id) in ids.iter().enumerate() {
                    slot_of.insert(id, slot);
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                }
                batcher.complete_decode(
                    &ids, &toks, &mut kv,
                    t0.elapsed().as_nanos() as f64,
                )?;
            }
            flux::serving::batcher::Work::Decode(ids) => {
                let logits = eng.decode_step(&last_tok)?;
                let mut toks = Vec::new();
                for &id in &ids {
                    let slot = slot_of[&id];
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                }
                batcher.complete_decode(
                    &ids, &toks, &mut kv,
                    t0.elapsed().as_nanos() as f64,
                )?;
            }
            flux::serving::batcher::Work::Idle => break,
        }
    }
    let dt = t0.elapsed();
    let total_toks: usize = batcher
        .requests
        .iter()
        .map(|r| r.generated.len())
        .sum();
    for r in &batcher.requests {
        println!(
            "  req {}: prompt {:?} -> {:?}",
            r.id, r.prompt, r.generated
        );
    }
    println!(
        "served {n_requests} requests / {total_toks} tokens in {:.2?} \
         ({:.1} tok/s, {} PJRT calls)",
        dt,
        total_toks as f64 / dt.as_secs_f64(),
        eng.rt.execute_calls
    );
    Ok(())
}
