//! `flux` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   figures      regenerate every paper table/figure (default)
//!   simulate     one op-level comparison (--cluster, --op, --m, --tp)
//!   tune         auto-tune one problem and print the winning config
//!   train        model-level training step comparison
//!   serve        run the REAL tiny TP transformer on PJRT via the batcher
//!   sweep-workloads  workload preset x topology serving matrix
//!   scenario     run a declarative experiment file (exp::Scenario)
//!   list         topologies, workload presets, methods, schemas
//!   schema       typed field catalog of one report schema
//!   gen-goldens  emit artifacts/golden_swizzle.json hermetically (no JAX)
//!   bench        run the pinned-seed suite; --json writes BENCH_<n>.json
//!
//! The sweep commands (`simulate --scale|--train`, `sweep-workloads`,
//! `scenario`, `bench`) only parse flags here; `flux::exp` owns the
//! scenario expansion, the (parallel, deterministic) execution and the
//! report plumbing.
//!
//! Examples:
//!   flux simulate --cluster "a100 nvlink" --op rs --m 4096
//!   flux simulate --scale --workload bursty-decode --quick
//!   flux simulate --scale --faults replica-churn --quick --json
//!   flux simulate --scale --topo "1-node tp8" --trace trace.json
//!   flux sweep-workloads --quick --json --threads 4
//!   flux scenario artifacts/scenario_h800_bursty.json --json
//!   flux tune --cluster "a100 pcie" --op ag --m 8192
//!   flux serve --requests 6 --gen 8
//!   flux gen-goldens
//!   flux bench --json --quick

use anyhow::{anyhow, bail, Result};

use flux::cost::arch::ClusterSpec;
use flux::exp::{ExecOpts, Runner, Scenario};
use flux::figures;
use flux::model::configs::TransformerConfig;
use flux::overlap::{baseline, medium, Problem};
use flux::parallel::{train_step_ns, Layout, Method};
use flux::runtime::Runtime;
use flux::serving::engine::{argmax, Engine};
use flux::serving::kvcache::KvCacheManager;
use flux::serving::{Batcher, BatcherConfig, Request};
use flux::tuner;
use flux::util::cli::Args;

const USAGE: &str = "\
flux — FLUX (fine-grained communication overlap) reproduction CLI

USAGE:
    flux [COMMAND] [FLAGS]

COMMANDS:
    figures      regenerate every paper table/figure (default)
                   [--json <path>] also write the tables as JSON
    simulate     one op-level comparison
                   [--cluster <name>] [--op ag|rs] [--m <rows>]
                   [--tp <degree>] [--seed <n>]
                 --scale: multi-node TP x DP serving-at-scale sweep
                   (seeded arrivals, per-replica continuous batching,
                   flux vs decoupled per topology); [--topo <name>]
                   restricts to one topology (incl. the parametric
                   fleet pools, e.g. \"fleet nvlink tp8 dp64\" — see
                   `flux list`), [--quick] trims the
                   workload, [--workload <preset|file.json>] swaps
                   the request source (arrival process, length mix,
                   routing, SLOs), [--faults <preset|file.json>]
                   injects seeded failures (replica kills/restarts,
                   stragglers, elastic resizes) and swaps the report
                   for flux-churn-v1 degradation curves,
                   [--trace <path>] (with --topo)
                   dumps the DES event stream as chrome://tracing
                   JSON, [--metrics <path>] writes the byte-stable
                   flux-metrics-v1 telemetry of the observed runs
                   (virtual-time counters/gauges/series; combinable
                   with --trace for chrome counter lanes),
                   [--threads <n>] caps the parallel cell
                   workers (output is byte-identical at any count),
                   [--json] writes the byte-stable flux-scale-v2
                   report ([--out <path>], default BENCH_<n>.json)
                 --train: event-driven DP x PP x TP training sweep
                   (1F1B microbatch schedule on the DES, PP hops on
                   NIC links, DP all-reduce streamed behind backward;
                   megatron vs TE vs flux per topology); same
                   [--topo] [--quick] [--json] [--out] [--trace]
                   [--metrics] [--threads] flags, report schema
                   flux-train-v1;
                   [--faults] applies straggler/NIC specs per
                   pipeline stage (kills have no training analogue)
    tune         auto-tune one problem, print the winning config
                   (same flags as simulate)
    train        model-level training-step comparison
                   [--cluster <name>] [--model gpt3|llama2]
                   [--microbatches <n>]
    serve        run the real tiny TP transformer on PJRT
                   [--requests <n>] [--gen <tokens>]
                   (needs `make artifacts` + the real xla bindings)
    sweep-workloads  run every workload preset (poisson-balanced,
                   steady/bursty-decode, open/closed-prefill,
                   diurnal-chat, long-context) on every serving
                   topology, flux vs decoupled; [--quick] trims
                   request counts, [--threads <n>] caps the parallel
                   cell workers, [--json] writes the byte-stable
                   flux-sweep-v1 report ([--out <path>])
    scenario     run a declarative experiment file:
                   flux scenario <file.json> [--quick] [--json]
                   [--out <path>] [--trace <path>] [--metrics <path>]
                   [--threads <n>]
                   (see `flux list` for the names a file can use and
                   artifacts/scenario_*.json for checked-in examples;
                   a \"metrics\" key in the file sets the default
                   telemetry path, --metrics overrides it; a
                   \"percentiles\": \"sketch\" key adds fixed-boundary
                   sketch percentile twins to serve reports)
    list         print the registries scenarios draw from: serving +
                   training topologies, workload presets, overlap
                   methods, fault presets, report schemas
    schema       print the typed field catalog of one report schema:
                   flux schema <name> [--json]
                   (names come from `flux list`, e.g. flux-metrics-v1)
    gen-goldens  emit the cross-language golden file from the Rust tile
                   bookkeeping [--out <path>] (default:
                   <artifacts dir>/golden_swizzle.json)
    bench        pinned-seed benchmark suite, incl. the DES-engine
                   events_per_sec hold workload and the fleet section
                   (dpN pool hold + quick-scale cells; deterministic
                   counts; wall-clock throughput + heap-queue
                   comparison with --wall; --quick skips dp256)
                   --json write BENCH_<n>.json (byte-stable) instead of
                          printing; [--out <path>] [--quick] [--wall]
                          [--threads <n>]
    lint         determinism & byte-stability lint over rust/src
                   (rules D001-D005; `flux list` prints the table,
                   README \"Determinism discipline\" has the details);
                   [--json] emits the byte-stable flux-lint-v1
                   document; exits nonzero on any finding

Clusters: \"a100 pcie\" | \"a100 nvlink\" | \"h800 nvlink\"
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let first = argv.first().map(|s| s.as_str()).unwrap_or("figures");
    // `--help` anywhere wins (so `flux bench --help` works too).
    if first == "help"
        || argv.iter().any(|a| matches!(a.as_str(), "--help" | "-h"))
    {
        print!("{USAGE}");
        return Ok(());
    }
    // A leading flag means "no command named": keep the historical
    // default of `figures` and hand it the whole argv (so e.g.
    // `flux --json report.json` still writes the JSON report).
    let (cmd, flag_args) = if first.starts_with("--") {
        ("figures", &argv[..])
    } else {
        (first, &argv[1..])
    };
    // Commands take flags only; parse everything after the command name
    // with the command's switch set (flags not listed consume a value).
    let rest = || flag_args.iter().cloned();
    match cmd {
        "figures" => cmd_figures(&Args::parse(rest(), &["verbose"])?),
        // `--scale` selects a different flag set: json/quick become
        // switches there, while the plain op-level form keeps rejecting
        // them (they would be silently ignored otherwise).
        "simulate"
            if flag_args.iter().any(|a| a == "--scale")
                && flag_args.iter().any(|a| a == "--train") =>
        {
            bail!("--scale and --train are separate sweeps; pick one")
        }
        "simulate" if flag_args.iter().any(|a| a == "--scale") => {
            cmd_simulate_scale(&Args::parse(
                rest(),
                &["verbose", "scale", "json", "quick"],
            )?)
        }
        "simulate" if flag_args.iter().any(|a| a == "--train") => {
            cmd_simulate_train(&Args::parse(
                rest(),
                &["verbose", "train", "json", "quick"],
            )?)
        }
        "simulate" => cmd_simulate(&Args::parse(rest(), &["verbose"])?),
        "sweep-workloads" => cmd_sweep_workloads(&Args::parse(
            rest(),
            &["json", "quick"],
        )?),
        "scenario" => {
            cmd_scenario(&Args::parse(rest(), &["json", "quick"])?)
        }
        "list" => cmd_list(),
        "schema" => cmd_schema(&Args::parse(rest(), &["json"])?),
        "tune" => cmd_tune(&Args::parse(rest(), &["verbose"])?),
        "train" => cmd_train(&Args::parse(rest(), &["verbose"])?),
        "serve" => cmd_serve(&Args::parse(rest(), &["verbose"])?),
        "gen-goldens" => cmd_gen_goldens(&Args::parse(rest(), &[])?),
        "bench" => {
            cmd_bench(&Args::parse(rest(), &["json", "quick", "wall"])?)
        }
        "lint" => cmd_lint(&Args::parse(rest(), &["json"])?),
        other => bail!(
            "unknown command {other:?}; try figures|simulate|\
             sweep-workloads|scenario|list|schema|tune|train|serve|\
             gen-goldens|bench|lint (or --help)"
        ),
    }
}

fn cmd_gen_goldens(args: &Args) -> Result<()> {
    let path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => Runtime::artifacts_dir().join("golden_swizzle.json"),
    };
    flux::goldens::write_goldens(&path)?;
    println!("wrote goldens to {}", path.display());
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    if let Some(k) = args
        .flags
        .keys()
        .find(|k| !matches!(k.as_str(), "out" | "threads"))
    {
        bail!(
            "--{k} is not a bench flag (only --quick, --wall, --json, \
             --threads, --out)"
        );
    }
    let quick = args.has("quick");
    let wall = args.has("wall");
    let opts = exec_opts(args)?;
    let runner = Runner::from_flag(opts.threads);
    if opts.json {
        let path = flux::report::write_bench(
            quick,
            wall,
            opts.out.as_deref(),
            &runner,
        )?;
        println!("wrote bench report to {}", path.display());
    } else {
        flux::report::print_bench(&flux::report::bench_doc_with(
            quick, &runner,
        ))?;
        if wall {
            // Bench::run prints one line per hotpath as it measures.
            println!("\nwall-clock hotpath timings (machine-local):");
            let _ = flux::report::wall_doc();
            let eps = flux::report::events_per_sec_doc(
                quick, true, &runner,
            );
            println!(
                "DES engine: {:.2e} events/s (heap queue {:.2e}, \
                 speedup {:.2}x)",
                eps.get("events_per_sec")?.as_f64()?,
                eps.get("heap_events_per_sec")?.as_f64()?,
                eps.get("speedup_vs_heap")?.as_f64()?,
            );
        }
    }
    Ok(())
}

/// The shared output flags (`--json`/`--out`/`--trace`/`--metrics`/
/// `--threads`) as [`ExecOpts`]. `--out` implies a JSON file report.
fn exec_opts(args: &Args) -> Result<ExecOpts> {
    let out = args.get("out").map(std::path::PathBuf::from);
    Ok(ExecOpts {
        json: args.has("json") || out.is_some(),
        out,
        trace: args.get("trace").map(std::path::PathBuf::from),
        metrics: args.get("metrics").map(std::path::PathBuf::from),
        threads: match args.get("threads") {
            Some(s) => Some(
                s.parse()
                    .map_err(|e| anyhow!("--threads {s:?}: {e}"))?,
            ),
            None => None,
        },
    })
}

fn cluster_of(args: &Args) -> Result<&'static ClusterSpec> {
    let name = args.get_or("cluster", "a100 nvlink");
    ClusterSpec::by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown cluster {name:?} (a100-pcie | a100-nvlink | h800-nvlink)"
        )
    })
}

fn problem_of(args: &Args) -> Result<Problem> {
    let m = args.get_usize("m", 4096)?;
    let tp = args.get_usize("tp", 8)?;
    Ok(match args.get_or("op", "rs") {
        "ag" => figures::ag_problem(m, tp),
        "rs" => figures::rs_problem(m, tp),
        o => bail!("unknown --op {o:?} (ag|rs)"),
    })
}

fn cmd_figures(args: &Args) -> Result<()> {
    for t in figures::all() {
        figures::print_table(&t);
    }
    if let Some(path) = args.get("json") {
        figures::write_json_report(std::path::Path::new(path))?;
        println!("\nwrote JSON report to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    // Reject stray flags (e.g. `--topo` without `--scale`, or a typo)
    // instead of silently simulating the defaults.
    if let Some(k) = args.flags.keys().find(|k| {
        !matches!(k.as_str(), "cluster" | "op" | "m" | "tp" | "seed")
    }) {
        bail!(
            "--{k} is not an op-level simulate flag (cluster|op|m|tp|\
             seed); the sweep flags need `simulate --scale` or \
             `simulate --train`"
        );
    }
    let cl = cluster_of(args)?;
    let p = problem_of(args)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let base = baseline::simulate(cl, &p);
    let te = medium::simulate(cl, &p, seed);
    let mut cache = tuner::TunerCache::new();
    let fx = cache.get(cl, &p, seed);
    println!(
        "{} m={} N_TP={} on {}",
        p.op.name(), p.m, p.n_tp, cl.name
    );
    println!(
        "  GEMM (non-split, Eq.1) : {:9.3} ms",
        base.gemm_nonsplit_ns / 1e6
    );
    for (name, t) in [
        ("PyTorch (no overlap)", base),
        ("TransformerEngine", te),
        ("Flux (tuned)", fx.timing),
    ] {
        println!(
            "  {name:22}: {:9.3} ms  ECT {:9.3} ms  eff {:5.1}%",
            t.overall_ns / 1e6,
            t.ect_ns() / 1e6,
            t.overlap_efficiency(&base) * 100.0
        );
    }
    println!("  tuned config: {:?}", fx.config);
    Ok(())
}

/// `flux simulate --scale`: the multi-node TP x DP serving sweep as an
/// anonymous [`Scenario`] — only flag parsing lives here;
/// [`flux::exp::execute`] owns expansion, execution and emission.
fn cmd_simulate_scale(args: &Args) -> Result<()> {
    // The sweep is pinned (fixed seeds per topology) so the report
    // stays byte-stable: reject the op-level flags instead of silently
    // ignoring them.
    if let Some(k) = args.flags.keys().find(|k| {
        !matches!(
            k.as_str(),
            "out" | "topo"
                | "workload"
                | "faults"
                | "trace"
                | "metrics"
                | "threads"
        )
    }) {
        bail!("--{k} is not supported with --scale (only --topo, \
               --workload, --faults, --trace, --metrics, --threads, \
               --quick, --json, --out)");
    }
    let quick = args.has("quick");
    let workload = match args.get("workload") {
        Some(arg) => {
            Some(flux::workload::WorkloadSpec::resolve(arg, quick)?)
        }
        None => None,
    };
    let mut scenario =
        Scenario::serve_cli(args.get("topo"), workload, quick)?;
    scenario.faults = faults_flag(args)?;
    flux::exp::execute(&scenario, &exec_opts(args)?)
}

/// `flux sweep-workloads`: every workload preset on every serving
/// topology, flux vs decoupled (`flux-sweep-v1`), cells in parallel.
fn cmd_sweep_workloads(args: &Args) -> Result<()> {
    if let Some(k) = args
        .flags
        .keys()
        .find(|k| !matches!(k.as_str(), "out" | "threads"))
    {
        bail!(
            "--{k} is not a sweep-workloads flag (only --quick, \
             --json, --threads, --out)"
        );
    }
    flux::exp::execute_sweep(args.has("quick"), &exec_opts(args)?)
}

/// `flux simulate --train`: the event-driven DP x PP x TP training
/// sweep as an anonymous [`Scenario`].
fn cmd_simulate_train(args: &Args) -> Result<()> {
    if let Some(k) = args.flags.keys().find(|k| {
        !matches!(
            k.as_str(),
            "out" | "topo" | "faults" | "trace" | "metrics" | "threads"
        )
    }) {
        bail!("--{k} is not supported with --train (only --topo, \
               --faults, --trace, --metrics, --threads, --quick, \
               --json, --out)");
    }
    let mut scenario =
        Scenario::train_cli(args.get("topo"), args.has("quick"))?;
    scenario.faults = faults_flag(args)?;
    flux::exp::execute(&scenario, &exec_opts(args)?)
}

/// Resolve `--faults <preset|file.json>` up front, so typos fail with
/// the fault layer's pointed error before any cell runs.
fn faults_flag(args: &Args) -> Result<Option<flux::faults::FaultsRef>> {
    Ok(match args.get("faults") {
        Some(arg) => Some(flux::faults::FaultsRef::Inline(
            flux::faults::FaultSpec::resolve(arg)?,
        )),
        None => None,
    })
}

/// `flux schema <name>`: the typed field catalog of one registered
/// report schema (`--json` emits the byte-stable dump).
fn cmd_schema(args: &Args) -> Result<()> {
    if let Some(k) = args.flags.keys().next() {
        bail!("--{k} is not a schema flag (only --json)");
    }
    let name = match args.positional.as_slice() {
        [n] => n,
        _ => bail!(
            "usage: flux schema <name> [--json] (`flux list` prints \
             the registered schema names)"
        ),
    };
    if args.has("json") {
        println!("{}", flux::report::schema_dump(name)?);
    } else {
        flux::report::print_schema(name)?;
    }
    Ok(())
}

/// `flux scenario <file.json>`: run a checked-in declarative
/// experiment.
fn cmd_scenario(args: &Args) -> Result<()> {
    // The file owns topology/workload/method selection: reject the
    // sweep flags instead of silently ignoring an attempted override.
    if let Some(k) = args.flags.keys().find(|k| {
        !matches!(k.as_str(), "out" | "trace" | "metrics" | "threads")
    }) {
        bail!(
            "--{k} is not a scenario flag (only --quick, --json, \
             --out, --trace, --metrics, --threads); topologies, \
             workload and methods come from the file"
        );
    }
    let path = match args.positional.as_slice() {
        [p] => p,
        _ => bail!(
            "usage: flux scenario <file.json> [--quick] [--json] \
             [--out <path>] [--trace <path>] [--metrics <path>] \
             [--threads <n>]"
        ),
    };
    let mut scenario = Scenario::load(std::path::Path::new(path))?;
    // `--quick` forces the CI-sized variant regardless of the file.
    // (Preset workloads and the train plan resize; an inline workload
    // spec carries explicit counts and runs as written.)
    if args.has("quick") {
        scenario.quick = true;
    }
    flux::exp::execute(&scenario, &exec_opts(args)?)
}

/// `flux list`: the registries scenarios (and the sweep flags) draw
/// from — sourced from the same tables the runner resolves against.
fn cmd_list() -> Result<()> {
    use flux::cost::arch::{
        ALL_FLEET_TOPOLOGIES, ALL_SCALE_TOPOLOGIES, ALL_TRAIN_TOPOLOGIES,
    };
    println!("serving topologies (simulate --scale --topo <name>):");
    for t in ALL_SCALE_TOPOLOGIES {
        println!(
            "  {:<22} {} | {} node(s), TP{} x DP{}",
            t.name, t.cluster.name, t.nodes, t.tp, t.dp
        );
    }
    println!(
        "\nfleet topologies (parametric dpN pools; same --topo flag \
         and scenario \"topos\" key):"
    );
    for t in ALL_FLEET_TOPOLOGIES {
        println!(
            "  {:<22} {} | {} node(s), TP{} x DP{}",
            t.name, t.cluster.name, t.nodes, t.tp, t.dp
        );
    }
    println!("\ntraining topologies (simulate --train --topo <name>):");
    for t in ALL_TRAIN_TOPOLOGIES {
        println!(
            "  {:<22} {} | DP{} x PP{} x TP{} = {} GPUs",
            t.name,
            t.cluster.name,
            t.dp,
            t.pp,
            t.tp,
            t.gpus()
        );
    }
    println!("\nworkload presets (--workload <name>, sweep-workloads):");
    for name in flux::workload::PRESET_NAMES {
        let wl = flux::workload::preset(name, true)
            .expect("preset table is closed");
        println!("  {:<18} {} arrivals", name, wl.arrival.kind());
    }
    println!("\noverlap methods (scenario \"methods\" keys):");
    for m in Method::ALL {
        println!("  {:<10} {:<12} {}", m.key(), m.name(), m.summary());
    }
    println!(
        "\nfault presets (--faults <name|file.json>, scenario \
         \"faults\" key):"
    );
    for spec in flux::faults::all_presets() {
        println!(
            "  {:<18} seed {} | {} kill(s), {} straggler(s), {} nic \
             window(s), {} resize(s)",
            spec.name,
            spec.seed,
            spec.kills.len(),
            spec.stragglers.len(),
            spec.nic.len(),
            spec.resizes.len()
        );
    }
    println!("\nreport schemas (flux schema <name> for the fields):");
    for s in flux::report::SCHEMAS {
        println!("  {:<15} {:<32} {}", s.name, s.command, s.summary);
    }
    println!(
        "\nlint rules (flux lint [--json], schema {}):",
        flux_lint::SCHEMA
    );
    for r in flux_lint::RULES {
        println!("  {}  {:<22} {}", r.id, r.title, r.protects);
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = flux_lint::find_root(&std::env::current_dir()?)?;
    let budget_path = root.join(flux_lint::BUDGET_PATH);
    // The checked-in ratchet is required here (unlike the standalone
    // binary, which tolerates its absence for fixture trees): `flux
    // lint` is the CI entry point and D005 must not silently skip.
    let budget = flux_lint::Budget::load(&budget_path)?;
    let report = flux_lint::run(&root, Some(&budget))?;
    if args.has("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_human());
    }
    if !report.findings.is_empty() {
        bail!("flux lint: {} finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let cl = cluster_of(args)?;
    let p = problem_of(args)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let t = tuner::tune(cl, &p, seed);
    println!(
        "tuned {} m={} on {} over {} candidates:",
        p.op.name(), p.m, cl.name, t.candidates_tried
    );
    println!("  config  : {:?}", t.config);
    println!("  overall : {:.3} ms", t.timing.overall_ns / 1e6);
    println!("  ECT     : {:.3} ms", t.timing.ect_ns() / 1e6);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cl = cluster_of(args)?;
    let model = TransformerConfig::by_name(args.get_or("model", "gpt3"))
        .ok_or_else(|| anyhow::anyhow!("unknown --model (gpt3|llama2)"))?;
    let micro = args.get_usize("microbatches", 16)?;
    let layout = Layout::PAPER_TRAINING;
    println!(
        "{} on {} x{} GPUs (DP{} PP{} TP{}), {} microbatches:",
        model.name, cl.name, layout.gpus(), layout.dp, layout.pp,
        layout.tp, micro
    );
    let mut base = 0.0;
    for m in Method::ALL {
        let t = train_step_ns(cl, model, &layout, micro, 2048, 2048, m, 7);
        if m == Method::NonOverlap {
            base = t;
        }
        println!(
            "  {:12}: {:9.1} ms/step  ({:.2}x)",
            m.name(), t / 1e6, base / t
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 4)?;
    let gen = args.get_usize("gen", 8)?;
    if !Runtime::pjrt_available() {
        bail!(
            "`flux serve` executes the AOT artifacts on PJRT, but this \
             build links the in-tree xla API stub (no backend). Swap \
             rust/Cargo.toml's `xla` path dependency for the real \
             bindings and run `make artifacts` first."
        );
    }
    let rt = Runtime::load_default()?;
    println!(
        "loaded {} artifacts from {} (tiny TP{} transformer, d={})",
        rt.manifest.artifacts.len(), rt.dir.display(),
        rt.manifest.n_tp, rt.manifest.d_model
    );
    let mut eng = Engine::new(rt)?;
    let mut batcher = Batcher::new(BatcherConfig {
        max_prefill_batch: eng.b,
        max_decode_batch: eng.b,
        max_prompt: eng.s,
        max_seq: eng.smax,
        ..Default::default()
    });
    let mut kv = KvCacheManager::new(64, 16);
    for i in 0..n_requests as u64 {
        let plen = 4 + (i as usize * 3) % 12;
        let prompt: Vec<i32> = (0..plen)
            .map(|t| ((i as usize * 131 + t * 17) % eng.vocab) as i32)
            .collect();
        batcher.submit(Request::new(i, 0.0, prompt, gen));
    }
    // Wall clock on purpose: `flux serve` measures the real PJRT
    // execution; nothing here feeds a deterministic report.
    let t0 = flux::util::bench::Stopwatch::start();
    let mut last_tok = vec![0i32; eng.b];
    let mut slot_of = std::collections::BTreeMap::new();
    loop {
        match batcher.next_work(&mut kv)? {
            flux::serving::batcher::Work::Prefill(ids) => {
                let prompts: Vec<Vec<i32>> = ids
                    .iter()
                    .map(|&id| batcher.get(id).prompt.clone())
                    .collect();
                let logits = eng.prefill(&prompts)?;
                let mut toks = Vec::new();
                for (slot, &id) in ids.iter().enumerate() {
                    slot_of.insert(id, slot);
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                }
                batcher.complete_decode(
                    &ids, &toks, &mut kv,
                    t0.elapsed().as_nanos() as f64,
                )?;
            }
            flux::serving::batcher::Work::Decode(ids) => {
                let logits = eng.decode_step(&last_tok)?;
                let mut toks = Vec::new();
                for &id in &ids {
                    let slot = slot_of[&id];
                    last_tok[slot] = argmax(&logits[slot]);
                    toks.push(last_tok[slot]);
                }
                batcher.complete_decode(
                    &ids, &toks, &mut kv,
                    t0.elapsed().as_nanos() as f64,
                )?;
            }
            flux::serving::batcher::Work::Idle => break,
        }
    }
    let dt = t0.elapsed();
    let total_toks: usize = batcher
        .requests
        .iter()
        .map(|r| r.generated.len())
        .sum();
    for r in &batcher.requests {
        println!(
            "  req {}: prompt {:?} -> {:?}",
            r.id, r.prompt, r.generated
        );
    }
    println!(
        "served {n_requests} requests / {total_toks} tokens in {:.2?} \
         ({:.1} tok/s, {} PJRT calls)",
        dt,
        total_toks as f64 / dt.as_secs_f64(),
        eng.rt.execute_calls
    );
    Ok(())
}
