//! Perf-trajectory substrate: `flux bench --json` writes a
//! schema-stable `BENCH_<n>.json` so every future PR has a baseline to
//! beat.
//!
//! Two kinds of numbers, separated on purpose:
//!
//! * **Simulated** (default, always emitted): the hotpath op suite run
//!   on the cluster simulator with pinned `util::prng` seeds. Fully
//!   deterministic — two consecutive runs produce byte-identical files —
//!   so CI can diff them and regressions in the *model* (op latency,
//!   overlap efficiency, tiles/sec) are attributable to code changes,
//!   never to noise.
//! * **Wall-clock** (`--wall`, off by default): `util::bench` timings of
//!   the simulator hot paths themselves. Machine-dependent by nature;
//!   excluded from the byte-stability contract and from CI diffing, but
//!   useful for eyeballing coordinator-side speedups on one box.
//!
//! Schema (`"schema": "flux-bench-v1"`): see [`bench_doc`]. Consumers
//! must tolerate added keys; existing keys are stable.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cost::arch::{
    ALL_CLUSTERS, ALL_SCALE_TOPOLOGIES, ALL_TRAIN_TOPOLOGIES,
};
use crate::cost::gemm::tile_grid;
use crate::figures::{ag_problem, rs_problem};
use crate::overlap::{baseline, medium, Problem};
use crate::parallel::schedule;
use crate::serving::scale::{compare_scale, ScaleReport, ScaleScenario};
use crate::training::{
    compare_train, ideal_step_ns, overlap_efficiency_vs_ideal, TrainRun,
    TrainScenario,
};
use crate::tuner::TunerCache;
use crate::util::json::{obj, Json};
use crate::util::stats::{percentile, Summary};

pub const SCHEMA: &str = "flux-bench-v1";
/// Schema of the `flux simulate --scale --json` report. v2 folds in
/// the workload subsystem: a `workload` spec object per topology and
/// per-method `slo` goodput/abandonment accounting. Every v1 field is
/// preserved with identical values for the default Poisson workload
/// (the coordinator replays PR-2's PRNG draw sequence bit-for-bit;
/// `prompt`/`gen`/`arrival_mean_ns` remain emitted for fixed-mix
/// Poisson workloads).
pub const SCALE_SCHEMA: &str = "flux-scale-v2";
/// Schema of the `flux simulate --train --json` report.
pub const TRAIN_SCHEMA: &str = "flux-train-v1";
/// Schema of the `flux sweep-workloads --json` report: the workload
/// preset x topology matrix, flux vs decoupled.
pub const SWEEP_SCHEMA: &str = "flux-sweep-v1";

/// Pinned seeds for the simulated suite (full / quick).
const SEEDS_FULL: [u64; 5] = [7, 11, 13, 17, 23];
const SEEDS_QUICK: [u64; 2] = [7, 11];

/// GEMM m sweep (full / quick); GPT-3 op shapes, 8-way TP.
const MS_FULL: [usize; 3] = [512, 2048, 8192];
const MS_QUICK: [usize; 1] = [2048];

fn p50_p95(xs: &[f64]) -> (f64, f64) {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    (percentile(&s, 0.50), percentile(&s, 0.95))
}

/// One suite entry: a (cluster, op, m) cell with per-method metrics.
fn suite_entry(
    cache: &mut TunerCache,
    cluster: &'static crate::cost::arch::ClusterSpec,
    p: &Problem,
    seeds: &[u64],
) -> Json {
    let base = baseline::simulate(cluster, p);

    let te_t: Vec<crate::overlap::OpTiming> = seeds
        .iter()
        .map(|&s| medium::simulate(cluster, p, s))
        .collect();
    let te: Vec<f64> = te_t.iter().map(|t| t.overall_ns).collect();
    let te_eff: Vec<f64> =
        te_t.iter().map(|t| t.overlap_efficiency(&base)).collect();

    // Tuned config is picked once with the first pinned seed (the same
    // cache a serving loop would hold), then timed across all seeds.
    let tuned = cache.get(cluster, p, seeds[0]);
    let fx_t: Vec<crate::overlap::OpTiming> = seeds
        .iter()
        .map(|&s| {
            crate::overlap::flux::simulate(cluster, p, &tuned.config, s)
        })
        .collect();
    let fx: Vec<f64> = fx_t.iter().map(|t| t.overall_ns).collect();
    let fx_eff: Vec<f64> =
        fx_t.iter().map(|t| t.overlap_efficiency(&base)).collect();

    // Simulated tile throughput: GEMM tiles the whole TP group retires
    // per second of simulated time (p50).
    let (_, tasks) = tile_grid(&cluster.arch, &p.local_gemm());
    let total_tiles = (tasks.len() * p.n_tp) as f64;

    let method = |xs: &[f64], effs: &[f64]| -> Json {
        let (p50, p95) = p50_p95(xs);
        let (eff50, _) = p50_p95(effs);
        obj(vec![
            ("p50_ns", Json::from(p50)),
            ("p95_ns", Json::from(p95)),
            ("overlap_eff_pct", Json::from(eff50 * 100.0)),
            ("tiles_per_sec", Json::from(total_tiles / (p50 * 1e-9))),
        ])
    };

    obj(vec![
        ("cluster", Json::from(cluster.name)),
        ("op", Json::from(p.op.name())),
        ("m", Json::from(p.m)),
        ("n_tp", Json::from(p.n_tp)),
        ("gemm_nonsplit_ns", Json::from(base.gemm_nonsplit_ns)),
        (
            "baseline",
            obj(vec![
                ("overall_ns", Json::from(base.overall_ns)),
                ("ect_ns", Json::from(base.ect_ns())),
            ]),
        ),
        ("te", method(&te, &te_eff)),
        ("flux", method(&fx, &fx_eff)),
        ("flux_config", Json::from(format!("{:?}", tuned.config))),
    ])
}

/// Build the full bench document (deterministic for a given `quick`).
pub fn bench_doc(quick: bool) -> Json {
    let seeds: &[u64] = if quick { &SEEDS_QUICK } else { &SEEDS_FULL };
    let ms: &[usize] = if quick { &MS_QUICK } else { &MS_FULL };
    let mut cache = TunerCache::new();
    let mut suite = Vec::new();
    for cluster in ALL_CLUSTERS {
        for &m in ms {
            for p in [ag_problem(m, 8), rs_problem(m, 8)] {
                suite.push(suite_entry(&mut cache, cluster, &p, seeds));
            }
        }
    }
    obj(vec![
        ("schema", Json::from(SCHEMA)),
        ("quick", Json::from(quick)),
        (
            "seeds",
            Json::Arr(seeds.iter().map(|&s| Json::from(s as usize)).collect()),
        ),
        ("suite", Json::Arr(suite)),
    ])
}

fn latency_percentiles(s: &Summary) -> Json {
    obj(vec![
        ("p50_ns", Json::from(s.p50)),
        ("p95_ns", Json::from(s.p95)),
        ("p99_ns", Json::from(s.p99)),
    ])
}

fn scale_method_json(r: &ScaleReport) -> Json {
    let mut fields = vec![
        ("completed", Json::from(r.completed)),
        ("tokens", Json::from(r.tokens)),
        ("makespan_ns", Json::from(r.makespan_ns)),
        ("tokens_per_sec", Json::from(r.tokens_per_sec)),
        ("overlap_eff_pct", Json::from(r.overlap_eff * 100.0)),
        ("ttft_ns", latency_percentiles(&r.ttft)),
        ("per_token_ns", latency_percentiles(&r.per_token)),
        ("latency_ns", latency_percentiles(&r.latency)),
    ];
    if let Some(slo) = &r.slo {
        fields.push(("slo", slo.to_json()));
    }
    obj(fields)
}

/// The serving-at-scale document (`flux simulate --scale --json`):
/// every topology in `ALL_SCALE_TOPOLOGIES` under the decoupled and
/// Flux executions. Deterministic for a given `quick` — byte-identical
/// across reruns, same contract as [`bench_doc`].
pub fn scale_doc(quick: bool) -> Result<Json> {
    scale_doc_for(quick, None)
}

/// Like [`scale_doc`], restricted to one topology when `only` is set
/// (`flux simulate --scale --topo <name>`).
pub fn scale_doc_for(
    quick: bool,
    only: Option<&'static crate::cost::arch::ScaleTopology>,
) -> Result<Json> {
    scale_doc_with(quick, only, None)
}

/// One topology's entry of the scale/sweep documents: legacy v1
/// fields (`prompt`/`gen` for fixed mixes, `arrival_mean_ns` for
/// Poisson arrivals, cluster-level), the workload spec, and both
/// methods' metrics.
fn scale_entry(sc: &ScaleScenario) -> Result<Json> {
    use crate::workload::ArrivalSpec;
    let topo = sc.topo;
    let cmp = compare_scale(sc)?;
    let mut fields = vec![
        ("topology", Json::from(topo.name)),
        ("cluster", Json::from(topo.cluster.name)),
        ("nodes", Json::from(topo.nodes)),
        ("tp", Json::from(topo.tp)),
        ("dp", Json::from(topo.dp)),
        ("requests", Json::from(sc.n_requests())),
    ];
    if let Some(c) = sc.workload.mix.fixed() {
        fields.push(("prompt", Json::from(c.prompt)));
        fields.push(("gen", Json::from(c.gen)));
    }
    if let ArrivalSpec::Poisson { mean_ns } = sc.workload.arrival {
        fields.push((
            "arrival_mean_ns",
            Json::from(mean_ns / topo.dp as f64),
        ));
    }
    fields.push(("seed", Json::from(sc.seed as usize)));
    fields.push(("workload", sc.workload.to_json()));
    fields.push(("decoupled", scale_method_json(&cmp.decoupled)));
    fields.push(("flux", scale_method_json(&cmp.flux)));
    fields.push(("speedup", Json::from(cmp.speedup())));
    fields.push(("latency_speedup", Json::from(cmp.latency_speedup())));
    if let Some(delta) = cmp.goodput_delta() {
        fields.push(("goodput_delta", Json::from(delta)));
    }
    Ok(obj(fields))
}

/// Like [`scale_doc_for`], with the request source swapped for a
/// custom workload (`flux simulate --scale --workload <preset|file>`).
pub fn scale_doc_with(
    quick: bool,
    only: Option<&'static crate::cost::arch::ScaleTopology>,
    workload: Option<&crate::workload::WorkloadSpec>,
) -> Result<Json> {
    let mut topologies = Vec::new();
    for topo in ALL_SCALE_TOPOLOGIES {
        if only.is_some_and(|o| o.name != topo.name) {
            continue;
        }
        let sc = match workload {
            Some(wl) => ScaleScenario::with_workload(topo, wl.clone()),
            None if quick => ScaleScenario::quick(topo),
            None => ScaleScenario::full(topo),
        };
        topologies.push(scale_entry(&sc)?);
    }
    let mut top = vec![
        ("schema", Json::from(SCALE_SCHEMA)),
        ("quick", Json::from(quick)),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("topologies", Json::Arr(topologies)),
    ];
    if let Some(o) = only {
        // A filtered doc must be distinguishable from a full sweep:
        // the trajectory diffing contract compares like with like.
        top.push(("topo_filter", Json::from(o.name)));
    }
    if let Some(wl) = workload {
        // Same contract for a swapped request source.
        top.push(("workload_filter", Json::from(wl.name.as_str())));
    }
    Ok(obj(top))
}

/// Write the scale document; returns the path written. Defaults to the
/// next free `BENCH_<n>.json`, extending the same perf trajectory the
/// op-level bench feeds.
pub fn write_scale(
    quick: bool,
    only: Option<&'static crate::cost::arch::ScaleTopology>,
    workload: Option<&crate::workload::WorkloadSpec>,
    out: Option<&Path>,
) -> Result<PathBuf> {
    write_doc(&scale_doc_with(quick, only, workload)?, out)
}

/// Human-readable rendering of the scale document.
pub fn print_scale(doc: &Json) -> Result<()> {
    fn ms(j: &Json, k: &str) -> Result<String> {
        Ok(format!("{:.1}", j.get(k)?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("topologies")?.as_arr()? {
        let fx = e.get("flux")?;
        let de = e.get("decoupled")?;
        rows.push(vec![
            e.get("topology")?.as_str()?.to_string(),
            format!(
                "{}x{}",
                e.get("tp")?.as_usize()?,
                e.get("dp")?.as_usize()?
            ),
            ms(fx.get("ttft_ns")?, "p50_ns")?,
            ms(fx.get("ttft_ns")?, "p99_ns")?,
            ms(fx.get("per_token_ns")?, "p50_ns")?,
            format!("{:.1}", fx.get("tokens_per_sec")?.as_f64()?),
            format!("{:.1}", de.get("tokens_per_sec")?.as_f64()?),
            format!("{:.1}%", fx.get("overlap_eff_pct")?.as_f64()?),
            format!("{:.2}x", e.get("speedup")?.as_f64()?),
        ]);
    }
    crate::util::bench::table(
        "serving at scale (flux vs decoupled, pinned seeds)",
        &[
            "topology",
            "tp x dp",
            "ttft p50 ms",
            "ttft p99 ms",
            "tok p50 ms",
            "flux tok/s",
            "dec tok/s",
            "flux eff",
            "speedup",
        ],
        &rows,
    );
    Ok(())
}

/// The workload-sweep document (`flux sweep-workloads --json`): every
/// built-in preset ([`crate::workload::all_presets`]) on every
/// [`ALL_SCALE_TOPOLOGIES`] entry, flux vs decoupled — the matrix that
/// shows where the speedup and goodput gaps diverge (burst backlog
/// widens them, closed-loop think pauses compress them, the H800
/// narrow-store cliff turns decode-heavy cells against Flux).
/// Deterministic for a given `quick`, same byte-stability contract as
/// [`bench_doc`].
pub fn sweep_doc(quick: bool) -> Result<Json> {
    let mut presets = Vec::new();
    for wl in crate::workload::all_presets(quick) {
        let mut topologies = Vec::new();
        for topo in ALL_SCALE_TOPOLOGIES {
            let sc = ScaleScenario::with_workload(topo, wl.clone());
            topologies.push(scale_entry(&sc)?);
        }
        presets.push(obj(vec![
            ("name", Json::from(wl.name.as_str())),
            ("workload", wl.to_json()),
            ("topologies", Json::Arr(topologies)),
        ]));
    }
    Ok(obj(vec![
        ("schema", Json::from(SWEEP_SCHEMA)),
        ("quick", Json::from(quick)),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("presets", Json::Arr(presets)),
    ]))
}

/// Write the sweep document; returns the path written (same
/// `BENCH_<n>.json` trajectory policy as the other reports).
pub fn write_sweep(quick: bool, out: Option<&Path>) -> Result<PathBuf> {
    write_doc(&sweep_doc(quick)?, out)
}

/// Human-readable rendering of the sweep document.
pub fn print_sweep(doc: &Json) -> Result<()> {
    let mut rows = Vec::new();
    for p in doc.get("presets")?.as_arr()? {
        let name = p.get("name")?.as_str()?;
        for e in p.get("topologies")?.as_arr()? {
            let fx = e.get("flux")?;
            let de = e.get("decoupled")?;
            let goodput = |m: &Json| -> String {
                match m.opt("slo") {
                    Some(s) => s
                        .get("goodput")
                        .and_then(|g| g.as_f64())
                        .map(|g| format!("{:.0}%", g * 100.0))
                        .unwrap_or_else(|_| "-".to_string()),
                    None => "-".to_string(),
                }
            };
            rows.push(vec![
                name.to_string(),
                e.get("topology")?.as_str()?.to_string(),
                format!(
                    "{:.1}",
                    fx.get("ttft_ns")?.get("p99_ns")?.as_f64()? / 1e6
                ),
                format!("{:.1}", fx.get("tokens_per_sec")?.as_f64()?),
                goodput(fx),
                goodput(de),
                format!("{:.2}x", e.get("speedup")?.as_f64()?),
                format!(
                    "{:.2}x",
                    e.get("latency_speedup")?.as_f64()?
                ),
            ]);
        }
    }
    crate::util::bench::table(
        "workload sweep (presets x topologies, flux vs decoupled)",
        &[
            "workload",
            "topology",
            "ttft p99 ms",
            "flux tok/s",
            "flux goodput",
            "dec goodput",
            "speedup",
            "lat speedup",
        ],
        &rows,
    );
    Ok(())
}

/// The event-driven training document (`flux simulate --train --json`):
/// every topology in `ALL_TRAIN_TOPOLOGIES` under the Megatron-LM
/// (non-overlap), TransformerEngine and Flux executions of the 1F1B
/// step. Deterministic for a given `quick` — byte-identical across
/// reruns, same contract as [`bench_doc`] / [`scale_doc`].
pub fn train_doc(quick: bool) -> Result<Json> {
    train_doc_for(quick, None)
}

/// Like [`train_doc`], restricted to one topology when `only` is set
/// (`flux simulate --train --topo <name>`).
pub fn train_doc_for(
    quick: bool,
    only: Option<&'static crate::cost::arch::TrainTopology>,
) -> Result<Json> {
    let mut topologies = Vec::new();
    for topo in ALL_TRAIN_TOPOLOGIES {
        if only.is_some_and(|o| o.name != topo.name) {
            continue;
        }
        let sc = if quick {
            TrainScenario::quick(topo)
        } else {
            TrainScenario::full(topo)
        };
        let cmp = compare_train(&sc)?;
        let ideal = ideal_step_ns(&sc)?;
        // Eq. 2 at the step level, ideal computed once per topology.
        let eff = |r: &TrainRun| {
            overlap_efficiency_vs_ideal(
                cmp.megatron.step_ns,
                r.step_ns,
                ideal,
            )
        };
        let method_json = |r: &TrainRun| {
            obj(vec![
                ("step_ns", Json::from(r.step_ns)),
                ("analytic_ns", Json::from(r.analytic_ns)),
                ("pipe_ns", Json::from(r.pipe_ns)),
                (
                    "bubble_fraction_pct",
                    Json::from(r.bubble_fraction * 100.0),
                ),
                ("dp_exposed_ns", Json::from(r.dp_exposed_ns)),
                ("opt_ns", Json::from(r.opt_ns)),
                ("overlap_eff_pct", Json::from(eff(r) * 100.0)),
                (
                    "des_vs_analytic",
                    Json::from(r.step_ns / r.analytic_ns),
                ),
                ("events", Json::from(r.events)),
            ])
        };
        topologies.push(obj(vec![
            ("topology", Json::from(topo.name)),
            ("cluster", Json::from(topo.cluster.name)),
            ("dp", Json::from(topo.dp)),
            ("pp", Json::from(topo.pp)),
            ("tp", Json::from(topo.tp)),
            ("gpus", Json::from(topo.gpus())),
            ("microbatches", Json::from(sc.microbatches)),
            ("micro_tokens", Json::from(sc.micro_tokens)),
            ("seq", Json::from(sc.seq)),
            ("seed", Json::from(sc.seed as usize)),
            (
                "bubble_analytic_pct",
                Json::from(
                    schedule::bubble_fraction(topo.pp, sc.microbatches)
                        * 100.0,
                ),
            ),
            ("ideal_step_ns", Json::from(ideal)),
            ("megatron", method_json(&cmp.megatron)),
            ("te", method_json(&cmp.te)),
            ("flux", method_json(&cmp.flux)),
            ("speedup", Json::from(cmp.speedup())),
            ("speedup_vs_te", Json::from(cmp.speedup_vs_te())),
        ]));
    }
    let mut top = vec![
        ("schema", Json::from(TRAIN_SCHEMA)),
        ("quick", Json::from(quick)),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("topologies", Json::Arr(topologies)),
    ];
    if let Some(o) = only {
        // Same contract as the scale doc: a filtered report must be
        // distinguishable from a full sweep when diffing trajectories.
        top.push(("topo_filter", Json::from(o.name)));
    }
    Ok(obj(top))
}

/// Write the training document; returns the path written. Defaults to
/// the next free `BENCH_<n>.json` on the shared perf trajectory.
pub fn write_train(
    quick: bool,
    only: Option<&'static crate::cost::arch::TrainTopology>,
    out: Option<&Path>,
) -> Result<PathBuf> {
    write_doc(&train_doc_for(quick, only)?, out)
}

/// Human-readable rendering of the training document.
pub fn print_train(doc: &Json) -> Result<()> {
    fn ms(j: &Json, k: &str) -> Result<String> {
        Ok(format!("{:.1}", j.get(k)?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("topologies")?.as_arr()? {
        let fx = e.get("flux")?;
        rows.push(vec![
            e.get("topology")?.as_str()?.to_string(),
            format!(
                "{}x{}x{}",
                e.get("dp")?.as_usize()?,
                e.get("pp")?.as_usize()?,
                e.get("tp")?.as_usize()?
            ),
            ms(e.get("megatron")?, "step_ns")?,
            ms(e.get("te")?, "step_ns")?,
            ms(fx, "step_ns")?,
            format!(
                "{:.1}%",
                fx.get("bubble_fraction_pct")?.as_f64()?
            ),
            format!("{:.1}%", fx.get("overlap_eff_pct")?.as_f64()?),
            ms(fx, "dp_exposed_ns")?,
            format!("{:.2}x", e.get("speedup")?.as_f64()?),
            format!("{:.2}x", e.get("speedup_vs_te")?.as_f64()?),
        ]);
    }
    crate::util::bench::table(
        "training at scale (event-driven 1F1B, flux vs Megatron-LM/TE)",
        &[
            "topology",
            "dp x pp x tp",
            "megatron ms",
            "TE ms",
            "flux ms",
            "bubble",
            "flux eff",
            "dp tail ms",
            "vs megatron",
            "vs TE",
        ],
        &rows,
    );
    Ok(())
}

/// Wall-clock hotpath timings (NOT byte-stable; appended only on
/// `--wall`).
pub fn wall_doc() -> Json {
    use crate::cost::arch::{A100_NVLINK, A100_PCIE};
    use crate::overlap::flux::FluxConfig;
    use crate::overlap::tiles;
    use crate::util::bench::Bench;

    let mut b = Bench::new();
    b.run("swizzle_order_64", || tiles::swizzle_order(64, 3, 8));
    b.run("comm_schedule_m8192_rows128", || {
        tiles::comm_schedule(8192, 3, 8, 128, true)
    });
    let p_rs = rs_problem(4096, 8);
    b.run("flux_rs_sim_m4096_nvlink", || {
        crate::overlap::flux::simulate(
            &A100_NVLINK,
            &p_rs,
            &FluxConfig::default(),
            7,
        )
    });
    let p_ag = ag_problem(4096, 8);
    b.run("flux_ag_sim_m4096_pcie", || {
        crate::overlap::flux::simulate(
            &A100_PCIE,
            &p_ag,
            &FluxConfig::for_cluster(&A100_PCIE),
            7,
        )
    });
    let entries: Vec<(&str, Json)> = b
        .results()
        .iter()
        .map(|(name, s)| (name.as_str(), summary_json(s)))
        .collect();
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn summary_json(s: &Summary) -> Json {
    obj(vec![
        ("mean_ns", Json::from(s.mean)),
        ("p50_ns", Json::from(s.p50)),
        ("p95_ns", Json::from(s.p95)),
        ("p99_ns", Json::from(s.p99)),
        ("n", Json::from(s.n)),
    ])
}

/// Smallest-unused `BENCH_<n>.json` in `dir` — the perf trajectory is an
/// append-only sequence of these.
pub fn next_bench_path(dir: &Path) -> PathBuf {
    for n in 0..10_000usize {
        let p = dir.join(format!("BENCH_{n}.json"));
        if !p.exists() {
            return p;
        }
    }
    dir.join("BENCH_overflow.json")
}

/// Shared trajectory writer: resolve `out` (default: the next free
/// `BENCH_<n>.json`), create the parent dir, write the document.
/// One path policy for the bench, scale and train reports.
fn write_doc(doc: &Json, out: Option<&Path>) -> Result<PathBuf> {
    let path = match out {
        Some(p) => p.to_path_buf(),
        None => next_bench_path(Path::new(".")),
    };
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Write the bench document; returns the path written.
pub fn write_bench(
    quick: bool,
    wall: bool,
    out: Option<&Path>,
) -> Result<PathBuf> {
    let mut doc = bench_doc(quick);
    if wall {
        if let Json::Obj(m) = &mut doc {
            m.insert("wall".to_string(), wall_doc());
        }
    }
    write_doc(&doc, out)
}

/// Human-readable rendering of a bench document (`flux bench` without
/// `--json`).
pub fn print_bench(doc: &Json) -> Result<()> {
    fn ms_of(j: &Json, k: &str) -> Result<String> {
        Ok(format!("{:.3}", j.get(k)?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("suite")?.as_arr()? {
        let fx = e.get("flux")?;
        let te = e.get("te")?;
        rows.push(vec![
            e.get("cluster")?.as_str()?.to_string(),
            e.get("op")?.as_str()?.to_string(),
            e.get("m")?.as_usize()?.to_string(),
            ms_of(e.get("baseline")?, "overall_ns")?,
            ms_of(te, "p50_ns")?,
            ms_of(fx, "p50_ns")?,
            ms_of(fx, "p95_ns")?,
            format!("{:.1}%", fx.get("overlap_eff_pct")?.as_f64()?),
            format!("{:.2e}", fx.get("tiles_per_sec")?.as_f64()?),
        ]);
    }
    crate::util::bench::table(
        "bench suite (simulated, pinned seeds)",
        &[
            "cluster", "op", "m", "torch ms", "TE p50 ms", "flux p50 ms",
            "flux p95 ms", "flux eff", "tiles/s",
        ],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_doc_is_byte_stable() {
        // The acceptance contract: consecutive runs are byte-identical.
        let a = bench_doc(true).to_string();
        let b = bench_doc(true).to_string();
        assert_eq!(a, b);
        assert!(a.contains("flux-bench-v1"));
    }

    #[test]
    fn quick_doc_parses_and_has_schema_fields() {
        let doc = bench_doc(true);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert!(parsed.get("quick").unwrap().as_bool().unwrap());
        let suite = parsed.get("suite").unwrap().as_arr().unwrap();
        // 3 clusters x 1 m x 2 ops in quick mode.
        assert_eq!(suite.len(), 6);
        for e in suite {
            for k in [
                "cluster", "op", "m", "n_tp", "gemm_nonsplit_ns",
                "baseline", "te", "flux", "flux_config",
            ] {
                assert!(e.opt(k).is_some(), "missing key {k}");
            }
            let fx = e.get("flux").unwrap();
            assert!(fx.get("p50_ns").unwrap().as_f64().unwrap() > 0.0);
            assert!(
                fx.get("p95_ns").unwrap().as_f64().unwrap()
                    >= fx.get("p50_ns").unwrap().as_f64().unwrap()
            );
            assert!(fx.get("tiles_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn scale_doc_is_byte_stable_and_well_formed() {
        let a = scale_doc(true).unwrap().to_string();
        let b = scale_doc(true).unwrap().to_string();
        assert_eq!(a, b, "scale doc must be deterministic");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            SCALE_SCHEMA
        );
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), ALL_SCALE_TOPOLOGIES.len());
        for t in topos {
            for k in [
                "topology", "cluster", "nodes", "tp", "dp", "requests",
                "prompt", "gen", "arrival_mean_ns", "workload",
                "decoupled", "flux", "speedup", "goodput_delta",
            ] {
                assert!(t.opt(k).is_some(), "missing key {k}");
            }
            let fx = t.get("flux").unwrap();
            let ttft = fx.get("ttft_ns").unwrap();
            assert!(
                ttft.get("p99_ns").unwrap().as_f64().unwrap()
                    >= ttft.get("p50_ns").unwrap().as_f64().unwrap()
            );
            assert!(
                fx.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0
            );
            // v2: the default preset defines SLOs, so both methods
            // carry goodput accounting.
            let slo = fx.get("slo").unwrap();
            let g = slo.get("goodput").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&g), "goodput {g}");
            // The workload spec round-trips from the report itself.
            let wl = crate::workload::WorkloadSpec::from_json(
                t.get("workload").unwrap(),
            )
            .unwrap();
            assert_eq!(wl.name, "poisson-balanced");
        }
    }

    #[test]
    fn sweep_doc_is_byte_stable_and_covers_the_matrix() {
        let a = sweep_doc(true).unwrap().to_string();
        let b = sweep_doc(true).unwrap().to_string();
        assert_eq!(a, b, "sweep doc must be deterministic");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            SWEEP_SCHEMA
        );
        let presets = doc.get("presets").unwrap().as_arr().unwrap();
        assert_eq!(presets.len(), crate::workload::PRESET_NAMES.len());
        for (p, name) in
            presets.iter().zip(crate::workload::PRESET_NAMES)
        {
            assert_eq!(p.get("name").unwrap().as_str().unwrap(), name);
            let topos = p.get("topologies").unwrap().as_arr().unwrap();
            assert_eq!(topos.len(), ALL_SCALE_TOPOLOGIES.len());
            for t in topos {
                let speedup =
                    t.get("speedup").unwrap().as_f64().unwrap();
                let nvlink = t
                    .get("cluster")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .contains("NVLink");
                // The acceptance bar: flux >= decoupled end to end on
                // every NVLink topology, for every preset.
                if nvlink {
                    assert!(
                        speedup >= 1.0,
                        "{name} on {}: speedup {speedup}",
                        t.get("topology").unwrap().as_str().unwrap()
                    );
                }
                // Goodput: flux meets at least as many SLOs as the
                // decoupled execution, everywhere.
                let goodput = |m: &Json| {
                    m.get("slo")
                        .unwrap()
                        .get("goodput")
                        .unwrap()
                        .as_f64()
                        .unwrap()
                };
                let gfx = goodput(t.get("flux").unwrap());
                let gde = goodput(t.get("decoupled").unwrap());
                assert!(
                    gfx >= gde,
                    "{name} on {}: flux goodput {gfx} < decoupled {gde}",
                    t.get("topology").unwrap().as_str().unwrap()
                );
            }
        }
        // The human rendering consumes the same document (checked here
        // rather than in its own test to avoid a third full sweep).
        print_sweep(&doc).unwrap();
    }

    #[test]
    fn scale_doc_with_workload_marks_the_document() {
        let wl =
            crate::workload::preset("bursty-decode", true).unwrap();
        use crate::cost::arch::SCALE_TP8;
        let doc =
            scale_doc_with(true, Some(&SCALE_TP8), Some(&wl)).unwrap();
        assert_eq!(
            doc.get("workload_filter").unwrap().as_str().unwrap(),
            "bursty-decode"
        );
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), 1);
        // Two-point mix + MMPP arrivals: no fixed prompt/gen, no
        // Poisson mean — the v1 compat fields are honestly absent.
        assert!(topos[0].opt("prompt").is_none());
        assert!(topos[0].opt("arrival_mean_ns").is_none());
    }

    #[test]
    fn print_scale_renders_without_error() {
        print_scale(&scale_doc(true).unwrap()).unwrap();
    }

    #[test]
    fn train_doc_is_byte_stable_and_well_formed() {
        let a = train_doc(true).unwrap().to_string();
        let b = train_doc(true).unwrap().to_string();
        assert_eq!(a, b, "train doc must be deterministic");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            TRAIN_SCHEMA
        );
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), ALL_TRAIN_TOPOLOGIES.len());
        for t in topos {
            for k in [
                "topology", "cluster", "dp", "pp", "tp", "gpus",
                "microbatches", "megatron", "te", "flux", "speedup",
                "speedup_vs_te", "ideal_step_ns",
            ] {
                assert!(t.opt(k).is_some(), "missing key {k}");
            }
            let fx = t.get("flux").unwrap();
            let step = fx.get("step_ns").unwrap().as_f64().unwrap();
            let pipe = fx.get("pipe_ns").unwrap().as_f64().unwrap();
            assert!(step > pipe && pipe > 0.0);
            let bubble = fx
                .get("bubble_fraction_pct")
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(bubble > 0.0 && bubble < 100.0);
            assert!(
                t.get("speedup").unwrap().as_f64().unwrap() > 1.0,
                "flux must beat megatron on {}",
                t.get("topology").unwrap().as_str().unwrap()
            );
        }
    }

    #[test]
    fn train_doc_topo_filter_marks_the_document() {
        use crate::cost::arch::TRAIN_NVLINK_128;
        let doc = train_doc_for(true, Some(&TRAIN_NVLINK_128)).unwrap();
        assert_eq!(
            doc.get("topo_filter").unwrap().as_str().unwrap(),
            TRAIN_NVLINK_128.name
        );
        assert_eq!(
            doc.get("topologies").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn print_train_renders_without_error() {
        print_train(&train_doc(true).unwrap()).unwrap();
    }

    #[test]
    fn next_bench_path_skips_existing() {
        let dir = std::env::temp_dir().join("flux_bench_path_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(next_bench_path(&dir).ends_with("BENCH_0.json"));
        std::fs::write(dir.join("BENCH_0.json"), "{}").unwrap();
        assert!(next_bench_path(&dir).ends_with("BENCH_1.json"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn print_bench_renders_without_error() {
        print_bench(&bench_doc(true)).unwrap();
    }
}
