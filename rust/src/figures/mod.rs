//! Figure/table regeneration: one function per table and figure in the
//! paper's evaluation (§5) + the motivating Fig. 1. Each returns the
//! header and rows it prints, so the benches, the `flux figures` CLI and
//! EXPERIMENTS.md all share one source of truth.

use crate::cost::arch::{
    ClusterSpec, ALL_CLUSTERS, A100_NVLINK, A100_PCIE, H800_NVLINK,
};
use crate::model::analysis::comm_portion;
use crate::model::configs::{GPT3_175B, LLAMA2_70B};
use crate::overlap::flux::{simulate as flux_sim, FluxConfig};
use crate::overlap::{baseline, medium, Problem};
use crate::parallel::{train_step_ns, Layout, Method};
use crate::serving::simulate::{decode_step_ns, prefill_ns};
use crate::tuner;
use crate::util::bench::table;

pub type Table = (&'static str, Vec<&'static str>, Vec<Vec<String>>);

const SEED: u64 = 7;

/// §5.1 op shapes from GPT-3 175B.
pub fn ag_problem(m: usize, n_tp: usize) -> Problem {
    Problem::ag(m, 49152, 12288, n_tp)
}
pub fn rs_problem(m: usize, n_tp: usize) -> Problem {
    Problem::rs(m, 12288, 49152, n_tp)
}

fn ms(ns: f64) -> String {
    format!("{:.3}", ns / 1e6)
}
fn pct(x: f64) -> String {
    format!("{:.0}%", x * 100.0)
}
fn sp(x: f64) -> String {
    format!("{x:.2}x")
}

/// Fig. 1: non-overlapped communication portion (training w/ bwd and
/// inference prefill) per cluster and model.
pub fn fig01() -> Table {
    let mut rows = Vec::new();
    for cl in ALL_CLUSTERS {
        for model in [&GPT3_175B, &LLAMA2_70B] {
            for (phase, m, bwd) in
                [("training", 2048usize, true), ("inference", 16384, false)]
            {
                let f = comm_portion(cl, model, m, 2048, 8, bwd).fraction();
                rows.push(vec![
                    cl.name.to_string(),
                    model.name.to_string(),
                    phase.to_string(),
                    pct(f),
                ]);
            }
        }
    }
    ("Fig 1: TP communication portion (non-overlapped)",
     vec!["cluster", "model", "phase", "comm portion"], rows)
}

/// Fig. 4: PyTorch vs TransformerEngine on 8xH800 NVLink, m=1024..8192.
pub fn fig04() -> Table {
    let mut rows = Vec::new();
    for m in [1024usize, 2048, 4096, 8192] {
        for (tag, p) in
            [("AllGather", ag_problem(m, 8)), ("ReduceScatter", rs_problem(m, 8))]
        {
            let base = baseline::simulate(&H800_NVLINK, &p);
            let te = medium::simulate(&H800_NVLINK, &p, SEED);
            rows.push(vec![
                tag.to_string(),
                m.to_string(),
                ms(base.gemm_nonsplit_ns),
                ms(base.ect_ns()),
                ms(te.ect_ns()),
                pct(te.overlap_efficiency(&base)),
            ]);
        }
    }
    ("Fig 4: PyTorch vs TransformerEngine, 8xH800 NVLink",
     vec!["op", "m", "GEMM ms", "Torch ECT ms", "TE ECT ms", "TE eff"],
     rows)
}

/// Fig. 8: tile-coordinate swizzling on/off, 8xA100 NVLink.
pub fn fig08() -> Table {
    let mut rows = Vec::new();
    for m in [1024usize, 8192] {
        for (tag, p) in
            [("AllGather", ag_problem(m, 8)), ("ReduceScatter", rs_problem(m, 8))]
        {
            let comm_rows = if tag == "AllGather" { 128 } else { 0 };
            let on = flux_sim(&A100_NVLINK, &p,
                &FluxConfig { comm_rows, ..FluxConfig::for_cluster(&A100_NVLINK) },
                SEED);
            let off = flux_sim(&A100_NVLINK, &p,
                &FluxConfig { swizzle: false, comm_rows,
                              ..FluxConfig::for_cluster(&A100_NVLINK) },
                SEED);
            rows.push(vec![
                tag.to_string(),
                m.to_string(),
                ms(off.overall_ns),
                ms(on.overall_ns),
                sp(off.overall_ns / on.overall_ns),
            ]);
        }
    }
    ("Fig 8: tile-coordinate swizzling, 8xA100 NVLink",
     vec!["op", "m", "naive ms", "swizzled ms", "gain"], rows)
}

/// Fig. 9: pull vs push AllGather transfers, A100 PCIe vs NVLink.
pub fn fig09() -> Table {
    let mut rows = Vec::new();
    for cl in [&A100_PCIE, &A100_NVLINK] {
        for m in [1024usize, 2048, 4096, 8192] {
            let p = ag_problem(m, 8);
            let mk = |pull| FluxConfig {
                pull,
                comm_rows: 256,
                ..Default::default()
            };
            let pull = flux_sim(cl, &p, &mk(true), SEED);
            let push = flux_sim(cl, &p, &mk(false), SEED);
            rows.push(vec![
                cl.name.to_string(),
                m.to_string(),
                ms(pull.overall_ns),
                ms(push.overall_ns),
                if pull.overall_ns <= push.overall_ns { "pull" } else { "push" }
                    .to_string(),
            ]);
        }
    }
    ("Fig 9: pull vs push AllGather transfers",
     vec!["cluster", "m", "pull ms", "push ms", "winner"], rows)
}

/// Fig. 10: communication tile size sweep, AG. The knob only bites
/// where communication is exposed, so both A100 clusters are shown:
/// the PCIe ring relay pipelines visibly, NVLink at large m is already
/// fully hidden (a finding, not a bug — see EXPERIMENTS.md).
pub fn fig10() -> Table {
    let mut rows = Vec::new();
    for cl in [&A100_PCIE, &A100_NVLINK] {
        for m in [2048usize, 4096, 8192] {
            let p = ag_problem(m, 8);
            let chunk = m / 8;
            let mut rows_opt = chunk;
            while rows_opt >= 128 {
                let t = flux_sim(cl, &p,
                    &FluxConfig { comm_rows: rows_opt,
                                  ..FluxConfig::for_cluster(cl) },
                    SEED);
                rows.push(vec![
                    cl.name.to_string(),
                    m.to_string(),
                    format!("{rows_opt}{}",
                            if rows_opt == chunk { " (chunk)" } else { "" }),
                    ms(t.overall_ns),
                    ms(t.ect_ns()),
                ]);
                rows_opt /= 2;
            }
        }
    }
    ("Fig 10: communication tile size sweep (AllGather)",
     vec!["cluster", "m", "comm rows", "overall ms", "ECT ms"], rows)
}

/// Figs. 11-13: op-level Torch vs TE vs Flux on one cluster.
pub fn fig11_13(cluster: &'static ClusterSpec) -> Table {
    let mut rows = Vec::new();
    let mut cache = tuner::TunerCache::new();
    for m in [1024usize, 2048, 4096, 8192] {
        for (tag, p) in
            [("AG", ag_problem(m, 8)), ("RS", rs_problem(m, 8))]
        {
            let base = baseline::simulate(cluster, &p);
            let te = medium::simulate(cluster, &p, SEED);
            let fx = cache.get(cluster, &p, SEED).timing;
            rows.push(vec![
                tag.to_string(),
                m.to_string(),
                ms(base.ect_ns()),
                ms(te.ect_ns()),
                ms(fx.ect_ns()),
                pct(te.overlap_efficiency(&base)),
                pct(fx.overlap_efficiency(&base)),
                sp(fx.speedup_over(&te)),
                sp(fx.speedup_over(&base)),
            ]);
        }
    }
    ("Fig 11-13: op-level comparison (ECT per Eq.1, eff per Eq.2)",
     vec!["op", "m", "Torch ECT", "TE ECT", "Flux ECT", "TE eff",
          "Flux eff", "vs TE", "vs Torch"],
     rows)
}

/// Fig. 14: small m (decoding shapes), all clusters.
pub fn fig14() -> Table {
    let mut rows = Vec::new();
    let mut cache = tuner::TunerCache::new();
    for cl in ALL_CLUSTERS {
        for m in [64usize, 512] {
            for (tag, p) in
                [("AG", ag_problem(m, 8)), ("RS", rs_problem(m, 8))]
            {
                let base = baseline::simulate(cl, &p);
                let te = medium::simulate(cl, &p, SEED);
                let fx = cache.get(cl, &p, SEED).timing;
                rows.push(vec![
                    cl.name.to_string(),
                    tag.to_string(),
                    m.to_string(),
                    ms(base.overall_ns),
                    ms(te.overall_ns),
                    ms(fx.overall_ns),
                    pct(fx.overlap_efficiency(&base)),
                    sp(fx.speedup_over(&te)),
                ]);
            }
        }
    }
    ("Fig 14: small m (decoding shapes)",
     vec!["cluster", "op", "m", "Torch ms", "TE ms", "Flux ms",
          "Flux eff", "vs TE"],
     rows)
}

/// Fig. 15: 16-way TP over two nodes, m=8192 (TE cannot run multi-node).
pub fn fig15() -> Table {
    let mut rows = Vec::new();
    for cl in ALL_CLUSTERS {
        for (tag, p) in [
            ("AG", Problem::ag(8192, 49152, 12288, 16)),
            ("RS", Problem::rs(8192, 12288, 49152, 16)),
        ] {
            let base = baseline::simulate(cl, &p);
            let fx = flux_sim(cl, &p, &FluxConfig::for_cluster(cl), SEED);
            rows.push(vec![
                cl.name.to_string(),
                tag.to_string(),
                ms(base.overall_ns),
                ms(fx.overall_ns),
                pct(fx.overlap_efficiency(&base)),
                sp(fx.speedup_over(&base)),
            ]);
        }
    }
    ("Fig 15: 16-way TP (2 nodes), m=8192, vs PyTorch",
     vec!["cluster", "op", "Torch ms", "Flux ms", "eff", "speedup"],
     rows)
}

/// Fig. 16: model-level training (128 GPUs) and prefill (8 GPUs).
pub fn fig16() -> Table {
    let mut rows = Vec::new();
    for cl in ALL_CLUSTERS {
        for model in [&GPT3_175B, &LLAMA2_70B] {
            let t = |m: Method| {
                train_step_ns(cl, model, &Layout::PAPER_TRAINING, 16,
                              2048, 2048, m, SEED)
            };
            let (b, te, fx) =
                (t(Method::NonOverlap), t(Method::Medium), t(Method::Flux));
            rows.push(vec![
                cl.name.to_string(), model.name.to_string(),
                "train step".to_string(),
                ms(b), ms(te), ms(fx),
                sp(b / fx), sp(te / fx),
            ]);
            let pf = |m: Method| prefill_ns(cl, model, 8, 2048, 8, m, SEED);
            let (b, te, fx) =
                (pf(Method::NonOverlap), pf(Method::Medium), pf(Method::Flux));
            rows.push(vec![
                cl.name.to_string(), model.name.to_string(),
                "prefill".to_string(),
                ms(b), ms(te), ms(fx),
                sp(b / fx), sp(te / fx),
            ]);
        }
    }
    ("Fig 16: model level — training (DP2xPP8xTP8, 128 GPUs) & prefill \
      (TP8, batch 8 x 2048)",
     vec!["cluster", "model", "phase", "Megatron/vLLM ms", "TE ms",
          "Flux ms", "vs base", "vs TE"],
     rows)
}

/// Fig. 16 (event-driven twin): the training rows re-derived by the
/// DES 1F1B simulator (`crate::training`) instead of the closed form —
/// per-topology step time, measured bubble, exposed DP tail, speedups.
pub fn fig16_des() -> Table {
    use crate::cost::arch::ALL_TRAIN_TOPOLOGIES;
    use crate::training::{compare_train, TrainScenario};
    let mut rows = Vec::new();
    for topo in ALL_TRAIN_TOPOLOGIES {
        let sc = TrainScenario::full(topo);
        let cmp = compare_train(&sc).expect("paper topology simulates");
        rows.push(vec![
            topo.name.to_string(),
            format!("{}x{}x{}", topo.dp, topo.pp, topo.tp),
            ms(cmp.megatron.step_ns),
            ms(cmp.te.step_ns),
            ms(cmp.flux.step_ns),
            pct(cmp.flux.bubble_fraction),
            ms(cmp.flux.dp_exposed_ns),
            sp(cmp.speedup()),
            sp(cmp.speedup_vs_te()),
        ]);
    }
    ("Fig 16 (event-driven): 1F1B training step via the DES \
      (DP2xPP8xTP8, 128 GPUs, GPT-3 175B)",
     vec!["topology", "dp x pp x tp", "Megatron ms", "TE ms", "Flux ms",
          "bubble", "dp tail ms", "vs Megatron", "vs TE"],
     rows)
}

/// Workload sweep (condensed): every workload preset on the two most
/// contrast-rich serving topologies — the A100 NVLink single replica
/// and the H800 DP4 cluster. The full preset x topology matrix is
/// `flux sweep-workloads`; this table is the figure-sized cut showing
/// where the Flux-vs-decoupled gap diverges: burst backlog widens it
/// (bursty- vs steady-decode on H800), closed-loop think pauses
/// compress it (closed- vs open-prefill), and prefill-heavy mixes gain
/// the most everywhere.
pub fn fig18_workloads() -> Table {
    use crate::cost::arch::{SCALE_H800_TP8_DP4, SCALE_TP8};
    use crate::serving::scale::{compare_scale, ScaleScenario};
    use crate::workload::all_presets;
    let mut rows = Vec::new();
    for wl in all_presets(true) {
        for topo in [&SCALE_TP8, &SCALE_H800_TP8_DP4] {
            let sc = ScaleScenario::with_workload(topo, wl.clone());
            let cmp = compare_scale(&sc).expect("preset simulates");
            let goodput = |r: &crate::serving::scale::ScaleReport| {
                r.slo
                    .map(|s| pct(s.goodput()))
                    .unwrap_or_else(|| "-".to_string())
            };
            rows.push(vec![
                wl.name.clone(),
                topo.name.to_string(),
                ms(cmp.flux.ttft.p99),
                format!("{:.1}", cmp.flux.tokens_per_sec),
                goodput(&cmp.flux),
                goodput(&cmp.decoupled),
                sp(cmp.speedup()),
                sp(cmp.latency_speedup()),
            ]);
        }
    }
    ("Fig 18: workload sweep (presets on TP8 NVLink / H800 DP4)",
     vec!["workload", "topology", "ttft p99 ms", "flux tok/s",
          "flux goodput", "dec goodput", "speedup", "lat speedup"],
     rows)
}

/// Churn figure: the `replica-churn` fault preset on the H800 DP4
/// serving cluster — goodput per fault intensity for both methods.
/// The full degradation matrix (every topology, every preset, train
/// mode) is `flux simulate --scale|--train --faults <preset>`; this
/// is the figure-sized cut showing the correlated-outage cliff and
/// the post-restart recovery gap between flux and the decoupled
/// baseline.
pub fn fig19_churn() -> Table {
    use crate::cost::arch::SCALE_H800_TP8_DP4;
    use crate::report::INTENSITIES;
    use crate::serving::scale::{
        run_scale, run_scale_faulted, ScaleScenario,
    };
    let mut rows = Vec::new();
    if let Some(spec) = crate::faults::preset("replica-churn") {
        let topo = &SCALE_H800_TP8_DP4;
        let sc = ScaleScenario::quick(topo);
        for m in Method::SERVE_SET {
            let mut row =
                vec![topo.name.to_string(), m.serve_label().to_string()];
            let mut last = None;
            for k in INTENSITIES {
                let tl = spec.expand(topo.dp, k);
                let rep = if tl.is_empty() {
                    run_scale(&sc, m)
                } else {
                    run_scale_faulted(&sc, m, &tl)
                };
                let Ok(rep) = rep else { continue };
                row.push(
                    rep.slo
                        .as_ref()
                        .map(|s| pct(s.goodput()))
                        .unwrap_or_else(|| "-".to_string()),
                );
                last = Some(rep);
            }
            if let Some(rep) = last {
                row.push(rep.failed.to_string());
                row.push(format!("{:.1}", rep.tokens_per_sec));
                rows.push(row);
            }
        }
    }
    ("Fig 19: replica churn (H800 DP4) — goodput per fault intensity",
     vec!["topology", "method", "k=0", "k=0.5", "k=1", "failed@1",
          "tok/s@1"],
     rows)
}

/// Time-series figure: the churn scenario observed through the obs
/// layer — per serve method, how replica-0 queue depth, replica-0 KV
/// occupancy and the routable-DP count evolve on the seeded
/// virtual-time sampling cadence. The full per-cell document is
/// `flux scenario artifacts/scenario_churn_h800.json --metrics <path>`
/// (schema flux-metrics-v1); this is the table-sized cut.
pub fn fig20_timeseries() -> Table {
    use crate::cost::arch::SCALE_H800_TP8_DP4;
    use crate::obs::Metrics;
    use crate::serving::scale::{run_scale_observed, ScaleScenario};
    let mut rows = Vec::new();
    if let Some(spec) = crate::faults::preset("replica-churn") {
        let topo = &SCALE_H800_TP8_DP4;
        let sc = ScaleScenario::quick(topo);
        let tl = spec.expand(topo.dp, 1.0);
        for m in Method::SERVE_SET {
            let mut metrics = Metrics::new(sc.seed);
            let faults = (!tl.is_empty()).then_some(&tl);
            if run_scale_observed(&sc, m, faults, None, Some(&mut metrics))
                .is_err()
            {
                continue;
            }
            for (metric, labels, pts) in metrics.series_iter() {
                let keep = match metric {
                    "serve.active_dp" => true,
                    "serve.queue_depth" | "serve.kv_used_blocks" => {
                        labels.get("replica").is_some_and(|r| r == "0")
                    }
                    _ => false,
                };
                if !keep || pts.is_empty() {
                    continue;
                }
                let peak = pts
                    .iter()
                    .map(|&(_, v)| v)
                    .fold(f64::NEG_INFINITY, f64::max);
                let (t_last, last) = pts[pts.len() - 1];
                rows.push(vec![
                    m.serve_label().to_string(),
                    metric.to_string(),
                    pts.len().to_string(),
                    format!("{peak:.0}"),
                    format!("{last:.0}"),
                    ms(t_last),
                ]);
            }
        }
    }
    ("Fig 20: churn time series (H800 DP4, sampled virtual-time gauges)",
     vec!["method", "metric", "samples", "peak", "last", "t_last ms"],
     rows)
}

/// Fig. 17: decoding, batch 64 / 512.
pub fn fig17() -> Table {
    let mut rows = Vec::new();
    for cl in ALL_CLUSTERS {
        for model in [&GPT3_175B, &LLAMA2_70B] {
            for batch in [64usize, 512] {
                let t = |m: Method| {
                    decode_step_ns(cl, model, batch, 1024, 8, m, SEED)
                };
                let (b, te, fx) = (
                    t(Method::NonOverlap),
                    t(Method::Medium),
                    t(Method::Flux),
                );
                rows.push(vec![
                    cl.name.to_string(),
                    model.name.to_string(),
                    batch.to_string(),
                    ms(b), ms(te), ms(fx),
                    sp(b / fx), sp(te / fx),
                ]);
            }
        }
    }
    ("Fig 17: decoding step (TP8)",
     vec!["cluster", "model", "batch", "vLLM ms", "TE ms", "Flux ms",
          "vs vLLM", "vs TE"],
     rows)
}

/// Print a Table via the shared renderer.
pub fn print_table(t: &Table) {
    table(t.0, &t.1, &t.2);
}

/// Serialize a Table to JSON (machine-readable reports).
pub fn table_json(t: &Table) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    obj(vec![
        ("title", Json::from(t.0)),
        (
            "header",
            Json::Arr(t.1.iter().map(|h| Json::from(*h)).collect()),
        ),
        (
            "rows",
            Json::Arr(
                t.2.iter()
                    .map(|r| {
                        Json::Arr(
                            r.iter()
                                .map(|c| Json::from(c.as_str()))
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write every figure to `path` as a JSON array (the `flux figures
/// --json <path>` output consumed by plotting scripts / CI diffs).
pub fn write_json_report(path: &std::path::Path) -> anyhow::Result<()> {
    let doc = crate::util::json::Json::Arr(
        all().iter().map(table_json).collect(),
    );
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// All figures in order (the `flux figures` subcommand).
pub fn all() -> Vec<Table> {
    vec![
        fig01(),
        fig04(),
        fig08(),
        fig09(),
        fig10(),
        fig11_13(&A100_PCIE),
        fig11_13(&A100_NVLINK),
        fig11_13(&H800_NVLINK),
        fig14(),
        fig15(),
        fig16(),
        fig16_des(),
        fig17(),
        fig18_workloads(),
        fig19_churn(),
        fig20_timeseries(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_produces_rows() {
        // Smoke: each generator yields a non-empty, rectangular table.
        // (fig16/17 are slow; this covers the cheap ones + one tuned.)
        for t in [fig01(), fig04(), fig08(), fig09(), fig10(), fig15()] {
            assert!(!t.2.is_empty(), "{}", t.0);
            assert!(t.2.iter().all(|r| r.len() == t.1.len()), "{}", t.0);
        }
    }

    #[test]
    fn churn_figure_has_both_methods_and_full_curves() {
        let t = fig19_churn();
        assert_eq!(t.2.len(), 2, "one row per serve method");
        for row in &t.2 {
            assert_eq!(row.len(), t.1.len(), "row {row:?}");
        }
    }

    #[test]
    fn timeseries_figure_samples_every_tracked_gauge() {
        let t = fig20_timeseries();
        assert_eq!(t.2.len(), 6, "3 series per serve method: {:?}", t.2);
        for row in &t.2 {
            assert_eq!(row.len(), t.1.len(), "row {row:?}");
            let samples: usize = row[2].parse().unwrap();
            assert!(samples > 3, "series under-sampled: {row:?}");
        }
    }

    #[test]
    fn table_json_round_trips() {
        let t = fig01();
        let j = table_json(&t);
        let parsed =
            crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("rows").unwrap().as_arr().unwrap().len(),
            t.2.len()
        );
        assert_eq!(
            parsed.get("title").unwrap().as_str().unwrap(),
            t.0
        );
    }

    #[test]
    fn fig11_headline_flux_wins() {
        let t = fig11_13(&A100_NVLINK);
        // Last two columns are speedups vs TE and vs Torch: Flux >= 1x
        // against TE on every row at these shapes.
        for row in &t.2 {
            let vs_te: f64 =
                row[7].trim_end_matches('x').parse().unwrap();
            assert!(vs_te >= 1.0, "row {row:?}");
        }
    }
}
