//! FLUX — fast software-based communication overlap through kernel
//! fusion: a Rust + JAX + Pallas reproduction of Chang et al. (2024).
//!
//! Layering (DESIGN.md):
//! * L1/L2 live in `python/` (Pallas fused kernels, TP transformer) and
//!   are AOT-lowered to HLO text in `artifacts/`.
//! * L3 is this crate: the cluster simulator standing in for the paper's
//!   GPU testbeds, the three overlap strategies (non-overlap,
//!   medium-grained TransformerEngine-style, fine-grained FLUX), the
//!   auto-tuner, the serving/training coordinators, and the PJRT runtime
//!   that executes the AOT artifacts on the CPU for real numerics.

pub mod cost {
    //! Calibrated cost models: GPU archs, GEMM timing, collectives.
    pub mod arch;
    pub mod comm;
    pub mod gemm;
}

pub mod collectives;
pub mod exp;
pub mod faults;
pub mod goldens;
pub mod obs;
pub mod overlap;
pub mod figures;
pub mod report;
pub mod model;
pub mod parallel;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod training;
pub mod tuner;
pub mod util;
pub mod workload;
