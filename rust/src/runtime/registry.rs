//! Artifact registry: manifest parsing, lazy compilation, execution.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Model config (tiny transformer served end-to-end).
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_tp: usize,
    pub batch: usize,
    pub seq: usize,
    pub smax: usize,
    pub hd_local: usize,
    pub ff_local: usize,
    /// Op-level kernel shapes.
    pub op_n_tp: usize,
    pub op_m: usize,
    pub op_k: usize,
    pub op_n: usize,
    /// artifact name -> hlo file (relative to artifacts dir).
    pub artifacts: BTreeMap<String, String>,
    /// weight name -> (bin file, shape).
    pub weights: BTreeMap<String, (String, Vec<usize>)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let j = Json::parse(&text)?;
        let cfg = j.get("config")?;
        let get = |k: &str| -> Result<usize> { cfg.get(k)?.as_usize() };
        let op = j.get("op_level")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            artifacts
                .insert(name.clone(), a.get("file")?.as_str()?.to_string());
        }
        let mut weights = BTreeMap::new();
        for (name, w) in j.get("weights")?.as_obj()? {
            weights.insert(
                name.clone(),
                (
                    w.get("file")?.as_str()?.to_string(),
                    w.get("shape")?.usize_vec()?,
                ),
            );
        }
        Ok(Manifest {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            n_layers: get("n_layers")?,
            d_ff: get("d_ff")?,
            n_tp: get("n_tp")?,
            batch: get("batch")?,
            seq: get("seq")?,
            smax: get("smax")?,
            hd_local: get("hd_local")?,
            ff_local: get("ff_local")?,
            op_n_tp: op.get("n_tp")?.as_usize()?,
            op_m: op.get("m")?.as_usize()?,
            op_k: op.get("k")?.as_usize()?,
            op_n: op.get("n")?.as_usize()?,
            artifacts,
            weights,
        })
    }
}

/// The runtime: PJRT CPU client + compiled-executable cache + weights.
pub struct Runtime {
    pub dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Compilation accounting (perf reporting).
    pub compile_ns: u128,
    pub execute_calls: u64,
}

impl Runtime {
    /// Default artifacts location: `$FLUX_ARTIFACTS` (pinned to the
    /// repo root by `.cargo/config.toml` for everything cargo launches),
    /// else `./artifacts`, else `../artifacts` — the latter so a binary
    /// invoked from `rust/` still finds the repo-root artifacts tree.
    pub fn artifacts_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("FLUX_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        let local = PathBuf::from("artifacts");
        if !local.is_dir() {
            let parent = PathBuf::from("../artifacts");
            if parent.is_dir() {
                return parent;
            }
        }
        local
    }

    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&Self::artifacts_dir())
    }

    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime {
            dir: dir.to_path_buf(),
            manifest,
            client,
            executables: BTreeMap::new(),
            compile_ns: 0,
            execute_calls: 0,
        })
    }

    /// Compile (or fetch cached) an artifact by manifest name.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let file = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let path = self.dir.join(file);
        // Wall clock on purpose: `compile_ns` is PJRT diagnostics, not
        // a deterministic report field (flux-lint D003 via Stopwatch).
        let t0 = crate::util::bench::Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.compile_ns += t0.elapsed().as_nanos();
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact. Inputs are borrowed literals (weights stay
    /// resident across calls — no per-call clones on the hot path); the
    /// (always tuple-shaped, `return_tuple=True`) output is decomposed.
    pub fn run(
        &mut self,
        name: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exe = self.executables.get(name).unwrap();
        self.execute_calls += 1;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{name}: empty result"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{name} to_literal: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow!("{name} tuple decompose: {e:?}"))
    }

    /// Load a weight tensor (f32 LE bin) as a Literal.
    pub fn weight(&self, name: &str) -> Result<xla::Literal> {
        let (file, shape) = self
            .manifest
            .weights
            .get(name)
            .ok_or_else(|| anyhow!("unknown weight {name:?}"))?;
        let bytes = std::fs::read(self.dir.join(file))?;
        if bytes.len() % 4 != 0 {
            bail!("{file}: length not a multiple of 4");
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        super::literal_f32(shape, &data)
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }
}
