//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and
//! execute them from the Rust hot path.
//!
//! This is the only place Python's output crosses into Rust: HLO *text*
//! (not serialized protos — see python/compile/aot.py and
//! /opt/xla-example/README.md) is parsed by the XLA text parser, compiled
//! once per artifact on the PJRT CPU client, and cached. Weights are raw
//! f32 little-endian `.bin` files indexed by `manifest.json`.

pub mod registry;

pub use registry::{Manifest, Runtime};

use anyhow::{anyhow, Result};

impl Runtime {
    /// Is a live PJRT backend linked into this build? `false` means the
    /// in-tree `xla` API stub is in use: manifests, goldens, the
    /// simulator and the bench pipeline all work, but nothing can
    /// compile/execute HLO artifacts — callers should skip those paths
    /// (the integration tests and examples do).
    pub fn pjrt_available() -> bool {
        xla::backend_available()
    }
}

/// Build an f32 literal of the given shape from host data.
pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(
        n == data.len(),
        "shape {dims:?} wants {n} elements, got {}",
        data.len()
    );
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Build an i32 literal of the given shape from host data.
pub fn literal_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape/element mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Extract a literal's f32 contents.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec<f32>: {e:?}"))
}
