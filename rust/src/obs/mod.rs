//! Deterministic telemetry on virtual DES time.
//!
//! A [`Metrics`] registry collects counters, gauges, fixed-bucket
//! histograms, sampled time series and instant markers, all keyed by
//! `(metric, labels)` with `BTreeMap` label sets so emission order is
//! total and byte-stable. Every timestamp is *simulated* nanoseconds —
//! wall clock never enters (flux-lint D003 stays law), and the
//! [`Sampler`] cadence jitter comes from the seeded `util::prng`
//! stream (D004), so two runs of the same scenario produce
//! byte-identical `flux-metrics-v1` documents at any `--threads`.
//!
//! The handle is threaded through the simulators as
//! `Option<&mut Metrics>`: when `None`, instrumentation collapses to a
//! branch per site and the simulation arithmetic is untouched — the
//! compat tests pin that report bytes do not move when metrics are on,
//! because the registry only ever *reads* simulator state.

use std::collections::BTreeMap;

use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// Label set: sorted, so `(metric, labels)` keys have a total order.
pub type Labels = BTreeMap<String, String>;

/// Build a label set from `(key, value)` pairs.
pub fn labels(pairs: &[(&str, &str)]) -> Labels {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// The one-label-set most metrics use: a replica index.
pub fn replica(r: usize) -> Labels {
    labels(&[("replica", &r.to_string())])
}

/// A stage index label (training pipeline).
pub fn stage(s: usize) -> Labels {
    labels(&[("stage", &s.to_string())])
}

/// Fixed histogram buckets for TTFT/latency observations, in ns.
/// Powers-of-4 from 1 µs to ~17 s: coarse, but scale-free across the
/// quick and full workloads.
pub const LATENCY_BOUNDS_NS: [f64; 13] = [
    1e3, 4e3, 1.6e4, 6.4e4, 2.56e5, 1.024e6, 4.096e6, 1.6384e7,
    6.5536e7, 2.62144e8, 1.048576e9, 4.194304e9, 1.6777216e10,
];

#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    metric: String,
    labels: Labels,
}

impl Key {
    fn new(metric: &str, labels: Labels) -> Self {
        Key { metric: metric.to_string(), labels }
    }

    fn json_fields(&self) -> Vec<(&'static str, Json)> {
        let lab = Json::Obj(
            self.labels
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        vec![
            ("labels", lab),
            ("metric", Json::Str(self.metric.clone())),
        ]
    }
}

/// Fixed-bucket histogram: `counts[i]` holds observations `<=
/// bounds[i]` (and above the previous bound); one overflow bucket at
/// the end. Bounds are fixed at the first observation.
#[derive(Clone, Debug)]
struct Hist {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Hist {
    fn new(bounds: &[f64]) -> Self {
        Hist {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            total: 0,
            sum: 0.0,
        }
    }

    fn observe(&mut self, v: f64) {
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i] += 1;
        self.total += 1;
        self.sum += v;
    }
}

/// Seeded-cadence sampler: fires roughly every `period` ns of virtual
/// time, with deterministic jitter in `[0.75, 1.25) * period` drawn
/// from the seeded PRNG, so sample trains never alias onto the
/// simulators' own periodic event patterns.
#[derive(Clone, Debug)]
pub struct Sampler {
    next: f64,
    period: f64,
    rng: Rng,
}

impl Sampler {
    pub fn new(seed: u64, period_ns: f64) -> Self {
        assert!(period_ns.is_finite() && period_ns > 0.0);
        Sampler { next: 0.0, period: period_ns, rng: Rng::new(seed) }
    }

    /// If a sample is due at virtual time `now`, return the sample
    /// timestamp (== `now`: DES state is only observable at event
    /// boundaries) and advance the cadence past `now`. Otherwise
    /// `None`. Monotone `now` in, strictly increasing timestamps out.
    pub fn due(&mut self, now: f64) -> Option<f64> {
        if now < self.next {
            return None;
        }
        while self.next <= now {
            self.next += self.period * (0.75 + 0.5 * self.rng.f64());
        }
        Some(now)
    }
}

/// The registry: every telemetry primitive the simulators record into.
///
/// All mutation is append/accumulate; emission sorts nothing at
/// write-time because the `BTreeMap` keys already carry the
/// `(metric, labels)` order and series points append in virtual-time
/// order.
#[derive(Debug)]
pub struct Metrics {
    counters: BTreeMap<Key, f64>,
    gauges: BTreeMap<Key, f64>,
    hists: BTreeMap<Key, Hist>,
    series: BTreeMap<Key, Vec<(f64, f64)>>,
    markers: Vec<(f64, String, Labels)>,
    sampler: Sampler,
}

/// Default sampling cadence: 10 ms of virtual time. The quick
/// scenarios span a few hundred ms, so a run yields tens of points per
/// series — enough for a time-series figure, small enough to check the
/// churn run's document into git.
pub const DEFAULT_PERIOD_NS: f64 = 1.0e7;

impl Metrics {
    pub fn new(seed: u64) -> Self {
        Metrics::with_period(seed, DEFAULT_PERIOD_NS)
    }

    pub fn with_period(seed: u64, period_ns: f64) -> Self {
        Metrics {
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            series: BTreeMap::new(),
            markers: Vec::new(),
            sampler: Sampler::new(seed, period_ns),
        }
    }

    /// Forward to the sampler: `Some(t)` when a gauge snapshot is due.
    pub fn sample_due(&mut self, now: f64) -> Option<f64> {
        self.sampler.due(now)
    }

    /// Add `v` to a monotone counter.
    pub fn add(&mut self, metric: &str, labels: Labels, v: f64) {
        *self.counters.entry(Key::new(metric, labels)).or_insert(0.0) +=
            v;
    }

    /// Increment a counter by one.
    pub fn inc(&mut self, metric: &str, labels: Labels) {
        self.add(metric, labels, 1.0);
    }

    /// Set a last-value gauge.
    pub fn gauge(&mut self, metric: &str, labels: Labels, v: f64) {
        self.gauges.insert(Key::new(metric, labels), v);
    }

    /// Observe `v` into the fixed-bucket histogram for this key;
    /// `bounds` only takes effect on the key's first observation.
    pub fn observe(
        &mut self,
        metric: &str,
        labels: Labels,
        bounds: &[f64],
        v: f64,
    ) {
        self.hists
            .entry(Key::new(metric, labels))
            .or_insert_with(|| Hist::new(bounds))
            .observe(v);
    }

    /// Append a `(t, v)` point to a sampled time series. Callers feed
    /// monotone `t` (the sampler guarantees it), keeping each series
    /// sorted by time without a sort at emission.
    pub fn point(&mut self, t: f64, metric: &str, labels: Labels, v: f64) {
        self.series
            .entry(Key::new(metric, labels))
            .or_default()
            .push((t, v));
    }

    /// Record an instant marker (fault activations).
    pub fn marker(&mut self, t: f64, name: &str, labels: Labels) {
        self.markers.push((t, name.to_string(), labels));
    }

    /// Iterate sampled series as `(metric, labels, points)` — the
    /// chrome-trace counter-track emission reads this.
    pub fn series_iter(
        &self,
    ) -> impl Iterator<Item = (&str, &Labels, &[(f64, f64)])> {
        self.series
            .iter()
            .map(|(k, pts)| (k.metric.as_str(), &k.labels, &pts[..]))
    }

    /// The registry as one `flux-metrics-v1` cell body: alphabetical
    /// keys, series sorted by `(metric, labels, t)`.
    pub fn to_json(&self) -> Json {
        obj(self.json_fields())
    }

    /// [`Self::to_json`] with extra top-level entries (the cell's
    /// `method`/`topology` stamps) merged in — alphabetical-key order
    /// comes out of the `obj` builder regardless.
    pub fn to_json_with(
        &self,
        mut extra: Vec<(&'static str, Json)>,
    ) -> Json {
        let mut fields = self.json_fields();
        fields.append(&mut extra);
        obj(fields)
    }

    fn json_fields(&self) -> Vec<(&'static str, Json)> {
        let counters: Vec<Json> = self
            .counters
            .iter()
            .map(|(k, v)| {
                let mut f = k.json_fields();
                f.push(("value", Json::Num(*v)));
                obj(f)
            })
            .collect();
        let gauges: Vec<Json> = self
            .gauges
            .iter()
            .map(|(k, v)| {
                let mut f = k.json_fields();
                f.push(("value", Json::Num(*v)));
                obj(f)
            })
            .collect();
        let hists: Vec<Json> = self
            .hists
            .iter()
            .map(|(k, h)| {
                let mut f = k.json_fields();
                f.push((
                    "bounds",
                    Json::Arr(
                        h.bounds.iter().map(|&b| Json::Num(b)).collect(),
                    ),
                ));
                f.push((
                    "counts",
                    Json::Arr(
                        h.counts
                            .iter()
                            .map(|&c| Json::Num(c as f64))
                            .collect(),
                    ),
                ));
                f.push(("sum", Json::Num(h.sum)));
                f.push(("total", Json::Num(h.total as f64)));
                obj(f)
            })
            .collect();
        let markers: Vec<Json> = self
            .markers
            .iter()
            .map(|(t, name, lab)| {
                obj(vec![
                    (
                        "labels",
                        Json::Obj(
                            lab.iter()
                                .map(|(k, v)| {
                                    (k.clone(), Json::Str(v.clone()))
                                })
                                .collect(),
                        ),
                    ),
                    ("name", Json::Str(name.clone())),
                    ("t", Json::Num(*t)),
                ])
            })
            .collect();
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|(k, pts)| {
                let mut f = k.json_fields();
                f.push((
                    "points",
                    Json::Arr(
                        pts.iter()
                            .map(|&(t, v)| {
                                Json::Arr(vec![
                                    Json::Num(t),
                                    Json::Num(v),
                                ])
                            })
                            .collect(),
                    ),
                ));
                obj(f)
            })
            .collect();
        vec![
            ("counters", Json::Arr(counters)),
            ("gauges", Json::Arr(gauges)),
            ("histograms", Json::Arr(hists)),
            ("markers", Json::Arr(markers)),
            ("series", Json::Arr(series)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_emit_in_metric_then_label_order() {
        let mut m = Metrics::new(1);
        m.inc("b.z", labels(&[]));
        m.inc("a.q", replica(1));
        m.inc("a.q", replica(0));
        let doc = m.to_json();
        let counters = doc.get("counters").unwrap().as_arr().unwrap();
        let names: Vec<String> = counters
            .iter()
            .map(|c| {
                format!(
                    "{}{}",
                    c.get("metric").unwrap().as_str().unwrap(),
                    c.get("labels").unwrap().to_string()
                )
            })
            .collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "emission must be pre-sorted");
        assert_eq!(counters.len(), 3);
    }

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut m = Metrics::new(1);
        m.add("c", labels(&[]), 2.0);
        m.inc("c", labels(&[]));
        m.gauge("g", labels(&[]), 5.0);
        m.gauge("g", labels(&[]), 7.0);
        let doc = m.to_json();
        let c = &doc.get("counters").unwrap().as_arr().unwrap()[0];
        assert_eq!(c.get("value").unwrap().as_f64().unwrap(), 3.0);
        let g = &doc.get("gauges").unwrap().as_arr().unwrap()[0];
        assert_eq!(g.get("value").unwrap().as_f64().unwrap(), 7.0);
    }

    #[test]
    fn histogram_buckets_are_cumulative_free_and_fixed() {
        let mut m = Metrics::new(1);
        let bounds = [10.0, 100.0];
        for v in [1.0, 5.0, 50.0, 500.0] {
            m.observe("h", labels(&[]), &bounds, v);
        }
        let doc = m.to_json();
        let h = &doc.get("histograms").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            h.get("counts").unwrap().f64_vec().unwrap(),
            vec![2.0, 1.0, 1.0]
        );
        assert_eq!(h.get("total").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(h.get("sum").unwrap().as_f64().unwrap(), 556.0);
    }

    #[test]
    fn sampler_is_seed_deterministic_and_monotone() {
        let run = |seed| {
            let mut s = Sampler::new(seed, 10.0);
            let mut out = Vec::new();
            let mut t = 0.0;
            while t < 200.0 {
                if let Some(at) = s.due(t) {
                    out.push(at);
                }
                t += 3.0;
            }
            out
        };
        let a = run(7);
        assert_eq!(a, run(7), "same seed, same cadence");
        assert!(a.len() > 5, "samples fired: {a:?}");
        assert!(
            a.windows(2).all(|w| w[0] < w[1]),
            "strictly increasing: {a:?}"
        );
        assert_ne!(a, run(8), "different seed, different jitter");
    }

    #[test]
    fn sampler_skips_past_large_time_jumps() {
        let mut s = Sampler::new(1, 10.0);
        assert!(s.due(0.0).is_some());
        // A jump over many periods yields ONE sample, not a backlog.
        assert_eq!(s.due(1000.0), Some(1000.0));
        assert_eq!(s.due(1000.0), None, "cadence advanced past now");
    }

    #[test]
    fn series_points_preserve_time_order_and_json_is_stable() {
        let mut m = Metrics::new(3);
        m.point(1.0, "s", replica(0), 4.0);
        m.point(2.0, "s", replica(0), 5.0);
        m.marker(1.5, "fault.kill", replica(0));
        let a = m.to_json().to_string();
        assert!(a.contains("\"points\":[[1,4],[2,5]]"), "{a}");
        assert!(a.contains("fault.kill"), "{a}");
        // Re-emission is byte-identical.
        assert_eq!(a, m.to_json().to_string());
    }
}
