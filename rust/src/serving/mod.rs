//! Serving runtime: the vLLM-shaped substrate FLUX plugs into for the
//! inference half of the evaluation (Fig. 16 prefill, Fig. 17 decoding).
//!
//! Two execution paths share the router/batcher/KV-cache machinery:
//! * [`engine`] — REAL numerics: the tiny TP transformer exported by
//!   aot.py, executed per-rank on the PJRT CPU client with host
//!   collectives between partials (examples/serve_e2e.rs).
//! * [`simulate`] — paper-scale timing: per-phase step times from the
//!   overlap strategies on the cluster simulator.
//!
//! [`scale`] stacks the DES on top of both: a multi-node TP×DP
//! coordinator that drives one batcher per DP replica for the
//! cluster-level Fig. 16/17 scenarios (`flux simulate --scale`).

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod request;
pub mod scale;
pub mod simulate;

pub use batcher::{Batcher, BatcherConfig};
pub use request::{Request, RequestState};
