//! Paper-scale serving timing (Fig. 16 prefill, Fig. 17 decoding) and a
//! DES-driven continuous-batching serving simulation for throughput /
//! latency reports.

use crate::cost::arch::ClusterSpec;
use crate::model::analysis::{layer_attention_extra_ns, layer_fwd_ops};
use crate::model::configs::TransformerConfig;
use crate::parallel::Method;
use crate::sim::engine::EventQueue;
use crate::util::prng::Rng;
use crate::util::stats::Summary;

/// Prefill step time: batch x seq tokens through every layer, TP ops
/// executed by `method` (Fig. 16 inference: batch 8, seq 2048).
pub fn prefill_ns(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    batch: usize,
    seq: usize,
    n_tp: usize,
    method: Method,
    seed: u64,
) -> f64 {
    let m = batch * seq;
    let mut t = 0.0;
    for p in layer_fwd_ops(model, m, n_tp) {
        t += method.op_ns(cluster, &p, seed);
    }
    t += layer_attention_extra_ns(cluster, model, m, seq, n_tp);
    t * model.n_layers as f64
}

/// One decode step for `batch` sequences (m = batch tokens). The
/// attention-over-cache cost is memory-bound reading the KV cache.
pub fn decode_step_ns(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    batch: usize,
    cache_len: usize,
    n_tp: usize,
    method: Method,
    seed: u64,
) -> f64 {
    let m = batch;
    let mut t = 0.0;
    for p in layer_fwd_ops(model, m, n_tp) {
        t += method.op_ns(cluster, &p, seed);
    }
    // KV-cache read per layer per rank: batch * cache_len * 2 (K and V)
    // * d/N * bf16 — bandwidth bound.
    let kv_bytes = batch as f64
        * cache_len as f64
        * 2.0
        * (model.d_model / n_tp) as f64
        * 2.0;
    t += kv_bytes / cluster.arch.hbm_gbps;
    t * model.n_layers as f64
}

/// The KV-cache length a decode step is costed at when a whole serving
/// run is summarized by one representative step: prompt plus half the
/// generation (the cache grows linearly from `prompt` to
/// `prompt + gen`, so the midpoint is the mean). Shared by this
/// single-group loop and the multi-replica coordinator
/// (`serving::scale`) so the two layers never drift.
pub fn decode_cache_len(prompt_len: usize, gen_len: usize) -> usize {
    prompt_len + gen_len / 2
}

/// Serving report from the DES loop.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    pub makespan_ns: f64,
    pub tokens_generated: usize,
    pub ttft: Summary,
    pub latency: Summary,
    /// Generated tokens per second.
    pub throughput: f64,
}

/// Open-loop serving simulation: Poisson arrivals, prefill-priority
/// continuous batching at paper scale, timed by the chosen method.
/// This is the end-to-end workload of examples/train_cluster &
/// the fig16_17 bench's latency rows.
#[allow(clippy::too_many_arguments)]
pub fn simulate_serving(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    n_tp: usize,
    method: Method,
    n_requests: usize,
    arrival_mean_ns: f64,
    prompt_len: usize,
    gen_len: usize,
    max_batch: usize,
    seed: u64,
) -> ServeReport {
    #[derive(Debug)]
    enum Ev {
        Arrive(usize),
        StepDone,
    }
    let mut rng = Rng::new(seed);
    let mut q = EventQueue::new();
    let mut t_arr = 0.0;
    for i in 0..n_requests {
        t_arr += rng.exponential(arrival_mean_ns);
        q.schedule(t_arr, Ev::Arrive(i));
    }
    let mut queued: Vec<usize> = Vec::new();
    let mut running: Vec<(usize, usize)> = Vec::new(); // (id, generated)
    let mut busy = false;
    let mut arrivals = vec![0.0f64; n_requests];
    let mut ttft = vec![f64::NAN; n_requests];
    let mut done = vec![f64::NAN; n_requests];
    let mut completed = 0usize;
    let mut tokens = 0usize;
    // Pending prefill batch being processed (ids), empty if decode step.
    let mut in_flight: Vec<usize> = Vec::new();
    let mut in_flight_is_prefill = false;

    macro_rules! maybe_start {
        ($q:expr, $now:expr) => {
            if !busy {
                if !queued.is_empty() && running.len() < max_batch {
                    let take = (max_batch - running.len())
                        .min(queued.len())
                        .min(8);
                    in_flight = queued.drain(..take).collect();
                    in_flight_is_prefill = true;
                    let t = prefill_ns(
                        cluster, model, in_flight.len(), prompt_len,
                        n_tp, method, seed,
                    );
                    busy = true;
                    $q.schedule($now + t, Ev::StepDone);
                } else if !running.is_empty() {
                    let b = running.len().min(max_batch);
                    in_flight = running.iter().take(b).map(|x| x.0).collect();
                    in_flight_is_prefill = false;
                    let avg_len = decode_cache_len(prompt_len, gen_len);
                    let t = decode_step_ns(
                        cluster, model, b, avg_len, n_tp, method, seed,
                    );
                    busy = true;
                    $q.schedule($now + t, Ev::StepDone);
                }
            }
        };
    }

    while let Some((now, ev)) = q.next() {
        match ev {
            Ev::Arrive(i) => {
                arrivals[i] = now;
                queued.push(i);
                maybe_start!(q, now);
            }
            Ev::StepDone => {
                busy = false;
                if in_flight_is_prefill {
                    for &id in &in_flight {
                        ttft[id] = now - arrivals[id];
                        running.push((id, 0));
                    }
                } else {
                    let step_ids: Vec<usize> = in_flight.clone();
                    for id in step_ids {
                        if let Some(e) =
                            running.iter_mut().find(|e| e.0 == id)
                        {
                            e.1 += 1;
                            tokens += 1;
                            if e.1 >= gen_len {
                                done[id] = now;
                                completed += 1;
                            }
                        }
                    }
                    running.retain(|e| e.1 < gen_len);
                    // Round-robin fairness.
                    if running.len() > max_batch {
                        let n = max_batch.min(running.len());
                        running.rotate_left(n);
                    }
                }
                in_flight.clear();
                maybe_start!(q, now);
            }
        }
        if completed == n_requests && q.is_empty() {
            break;
        }
    }
    let makespan = done
        .iter()
        .chain(arrivals.iter())
        .cloned()
        .filter(|x| x.is_finite())
        .fold(0.0, f64::max);
    let lat: Vec<f64> = done
        .iter()
        .zip(&arrivals)
        .filter(|(d, _)| d.is_finite())
        .map(|(d, a)| d - a)
        .collect();
    let ttfts: Vec<f64> =
        ttft.iter().cloned().filter(|x| x.is_finite()).collect();
    ServeReport {
        completed,
        makespan_ns: makespan,
        tokens_generated: tokens,
        ttft: Summary::of(if ttfts.is_empty() { &[0.0] } else { &ttfts }),
        latency: Summary::of(if lat.is_empty() { &[0.0] } else { &lat }),
        throughput: tokens as f64 / (makespan * 1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};
    use crate::model::configs::{GPT3_175B, LLAMA2_70B};

    #[test]
    fn fig16_prefill_speedups_shape() {
        // Fig. 16 prefill: Flux over vLLM ~1.46x (PCIe), ~1.45x (A100
        // NVLink), ~1.66x (H800). Loose shape bands.
        for (cl, lo, hi) in [
            (&A100_PCIE, 1.10, 1.9),
            (&A100_NVLINK, 1.02, 1.7),
            (&H800_NVLINK, 1.05, 2.0),
        ] {
            let base = prefill_ns(cl, &GPT3_175B, 8, 2048, 8,
                                  Method::NonOverlap, 3);
            let fx = prefill_ns(cl, &GPT3_175B, 8, 2048, 8,
                                Method::Flux, 3);
            let sp = base / fx;
            assert!(sp > lo && sp < hi, "{}: prefill speedup {sp}", cl.name);
        }
    }

    #[test]
    fn decode_batch512_beats_batch64_on_efficiency() {
        // §6: batch 512 amortizes better than 64.
        let per_tok = |b: usize| {
            decode_step_ns(&A100_NVLINK, &LLAMA2_70B, b, 1024, 8,
                           Method::Flux, 3) / b as f64
        };
        assert!(per_tok(512) < per_tok(64));
    }

    #[test]
    fn flux_decode_never_catastrophic() {
        // Fig. 17: Flux ≥ TE everywhere in decode.
        for cl in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
            for b in [64usize, 512] {
                let te = decode_step_ns(cl, &GPT3_175B, b, 1024, 8,
                                        Method::Medium, 3);
                let fx = decode_step_ns(cl, &GPT3_175B, b, 1024, 8,
                                        Method::Flux, 3);
                assert!(fx < te, "{} b={b}: flux {fx} te {te}", cl.name);
            }
        }
    }

    #[test]
    fn serving_des_completes_all_requests() {
        let r = simulate_serving(
            &A100_NVLINK, &LLAMA2_70B, 8, Method::Flux,
            20, 5.0e6, 512, 16, 8, 42,
        );
        assert_eq!(r.completed, 20);
        assert_eq!(r.tokens_generated, 20 * 16);
        assert!(r.throughput > 0.0);
        assert!(r.ttft.p50 > 0.0);
        assert!(r.latency.p50 >= r.ttft.p50);
    }

    #[test]
    fn serving_des_flux_beats_baseline_throughput() {
        let run = |m: Method| {
            simulate_serving(
                &A100_PCIE, &GPT3_175B, 8, m, 12, 1.0e6, 2048, 8, 8, 7,
            )
            .makespan_ns
        };
        assert!(run(Method::Flux) < run(Method::NonOverlap));
    }
}
