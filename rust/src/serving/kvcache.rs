//! KV-cache block manager (paged, vLLM-style).
//!
//! Tracks block allocation for every live sequence: the serving
//! coordinator admits a request only when enough blocks exist for its
//! prompt plus headroom, and frees them on completion. The real engine
//! additionally stores the per-(layer, rank) cache *contents* for the
//! tiny model; at paper scale only the accounting matters.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct KvCacheManager {
    pub block_tokens: usize,
    pub total_blocks: usize,
    free: Vec<usize>,
    /// seq id -> allocated block ids (in order).
    owned: BTreeMap<u64, Vec<usize>>,
    /// seq id -> current token count.
    lens: BTreeMap<u64, usize>,
    /// High-water mark for reports.
    pub peak_used: usize,
}

impl KvCacheManager {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0 && total_blocks > 0);
        KvCacheManager {
            block_tokens,
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            owned: BTreeMap::new(),
            lens: BTreeMap::new(),
            peak_used: 0,
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate blocks for a new sequence of `tokens` length.
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<()> {
        self.admit_with_budget(seq, tokens, tokens)
    }

    /// Allocate blocks for a new sequence of `tokens` length, reserving
    /// capacity up front for growth to `budget_tokens`. A continuous
    /// batcher with no preemption path MUST reserve the full generation
    /// budget at admission: reserving only the prompt lets N admitted
    /// sequences jointly over-commit the pool and deadlock mid-decode
    /// when `append_token` finds no free block.
    pub fn admit_with_budget(
        &mut self,
        seq: u64,
        tokens: usize,
        budget_tokens: usize,
    ) -> Result<()> {
        if self.owned.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        let need = self.blocks_for(budget_tokens.max(tokens));
        if need > self.free.len() {
            bail!(
                "OOM: need {need} blocks, {} free (seq {seq})",
                self.free.len()
            );
        }
        let blocks: Vec<usize> =
            (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.owned.insert(seq, blocks);
        self.lens.insert(seq, tokens);
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// Grow a sequence by one token (decode step); may allocate a block.
    pub fn append_token(&mut self, seq: u64) -> Result<()> {
        let len = match self.lens.get(&seq) {
            Some(&l) => l + 1,
            None => bail!("unknown sequence {seq}"),
        };
        self.lens.insert(seq, len);
        let need = self.blocks_for(len);
        let owned = self.owned.get_mut(&seq).unwrap();
        if need > owned.len() {
            match self.free.pop() {
                Some(b) => owned.push(b),
                None => {
                    *self.lens.get_mut(&seq).unwrap() -= 1;
                    bail!("OOM growing sequence {seq}");
                }
            }
        }
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// Release a finished sequence's blocks.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        let blocks = match self.owned.remove(&seq) {
            Some(b) => b,
            None => bail!("unknown sequence {seq}"),
        };
        self.lens.remove(&seq);
        self.free.extend(blocks);
        Ok(())
    }

    /// Invariant: every block is either free or owned by exactly one seq.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.total_blocks];
        for &b in &self.free {
            if seen[b] {
                bail!("block {b} duplicated in free list");
            }
            seen[b] = true;
        }
        for (seq, blocks) in &self.owned {
            for &b in blocks {
                if seen[b] {
                    bail!("block {b} of seq {seq} double-owned");
                }
                seen[b] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            bail!("leaked blocks");
        }
        // Used-block conservation across fail/restart cycles: the
        // ledgers must agree (every owned sequence has a length,
        // every length an owner) and each live sequence must still
        // hold at least the blocks its token count needs — a drain
        // that released blocks but forgot a ledger entry (or vice
        // versa) shows up here, not as a later phantom OOM.
        if self.owned.len() != self.lens.len() {
            bail!(
                "ledger mismatch: {} owned sequences vs {} lengths",
                self.owned.len(),
                self.lens.len()
            );
        }
        for (seq, &len) in &self.lens {
            let Some(blocks) = self.owned.get(seq) else {
                bail!("seq {seq} has a length but owns no blocks");
            };
            if blocks.len() < self.blocks_for(len) {
                bail!(
                    "seq {seq}: {} tokens need {} blocks, owns {}",
                    len,
                    self.blocks_for(len),
                    blocks.len()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn admit_grow_release() {
        let mut kv = KvCacheManager::new(10, 16);
        kv.admit(1, 40).unwrap(); // 3 blocks
        assert_eq!(kv.used_blocks(), 3);
        // Grow within the block: no new allocation.
        for _ in 0..8 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.used_blocks(), 3);
        // Crossing 48 tokens allocates block 4.
        kv.append_token(1).unwrap();
        assert_eq!(kv.used_blocks(), 4);
        kv.release(1).unwrap();
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn oom_is_reported_not_silent() {
        let mut kv = KvCacheManager::new(2, 16);
        kv.admit(1, 32).unwrap();
        assert!(!kv.can_admit(1));
        assert!(kv.admit(2, 1).is_err());
        assert!(kv.append_token(1).is_err(), "growth past capacity");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn budget_reservation_prevents_growth_oom() {
        let mut kv = KvCacheManager::new(4, 16);
        // Reserve the full 64-token budget up front: 4 blocks.
        kv.admit_with_budget(1, 16, 64).unwrap();
        assert_eq!(kv.used_blocks(), 4);
        // Another admission cannot over-commit the reserved pool.
        assert!(!kv.can_admit(16));
        // Growth up to the budget never needs a new block.
        for _ in 0..48 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.used_blocks(), 4);
        kv.release(1).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_rejected() {
        let mut kv = KvCacheManager::new(4, 16);
        kv.admit(7, 16).unwrap();
        assert!(kv.admit(7, 16).is_err());
    }

    #[test]
    fn random_workload_preserves_invariants() {
        forall(32, 0x5E0u64, |rng| {
            let mut kv = KvCacheManager::new(16, 8);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        let toks = rng.range(1, 40) as usize;
                        if kv.can_admit(toks) {
                            kv.admit(next_id, toks).unwrap();
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let _ = kv.append_token(live[i]);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            kv.release(live.swap_remove(i)).unwrap();
                        }
                    }
                }
                kv.check_invariants().unwrap();
            }
        });
    }
}
