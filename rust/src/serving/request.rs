//! Request model for the serving coordinator.

/// Lifecycle of a generation request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// In the admission queue, not yet prefetched.
    Queued,
    /// Prompt processed, KV cache resident, decoding.
    Decoding,
    /// Hit max_new_tokens (or a stop condition).
    Finished,
    /// Abandoned by a fault (replica kill or elastic resize drained
    /// it before completion); its KV blocks have been released.
    Failed,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Arrival time (ns in simulation time or wall-clock ns).
    pub arrival_ns: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub state: RequestState,
    pub generated: Vec<i32>,
    /// Timestamps for latency accounting.
    pub prefill_done_ns: Option<f64>,
    pub finished_ns: Option<f64>,
}

impl Request {
    pub fn new(id: u64, arrival_ns: f64, prompt: Vec<i32>,
               max_new_tokens: usize) -> Request {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0);
        Request {
            id,
            arrival_ns,
            prompt,
            max_new_tokens,
            state: RequestState::Queued,
            generated: Vec::new(),
            prefill_done_ns: None,
            finished_ns: None,
        }
    }

    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    pub fn is_done(&self) -> bool {
        self.generated.len() >= self.max_new_tokens
    }

    /// Time to first token, if prefill completed.
    pub fn ttft_ns(&self) -> Option<f64> {
        self.prefill_done_ns.map(|t| t - self.arrival_ns)
    }

    /// End-to-end latency, if finished.
    pub fn latency_ns(&self) -> Option<f64> {
        self.finished_ns.map(|t| t - self.arrival_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accounting() {
        let mut r = Request::new(1, 100.0, vec![1, 2, 3], 2);
        assert_eq!(r.state, RequestState::Queued);
        assert_eq!(r.total_len(), 3);
        r.prefill_done_ns = Some(400.0);
        assert_eq!(r.ttft_ns(), Some(300.0));
        r.generated.push(7);
        assert!(!r.is_done());
        r.generated.push(8);
        assert!(r.is_done());
        r.finished_ns = Some(900.0);
        assert_eq!(r.latency_ns(), Some(800.0));
        assert_eq!(r.total_len(), 5);
    }

    #[test]
    #[should_panic]
    fn rejects_empty_prompt() {
        Request::new(1, 0.0, vec![], 1);
    }
}
