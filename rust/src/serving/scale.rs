//! Multi-node TP×DP serving-at-scale coordinator.
//!
//! Scales the single-TP-group serving simulation up to a whole cluster:
//! `topo.dp` independent TP groups (replicas, Megatron-style TP within a
//! node / replicas across nodes) are driven through ONE shared DES event
//! queue ([`crate::sim::engine::EventQueue`]). The request source is a
//! declarative [`WorkloadSpec`] ([`crate::workload`]): an arrival
//! process (Poisson / bursty MMPP / diurnal / closed-loop), a length
//! mix, a routing policy and optional SLOs. Each replica runs its own
//! prefill-priority continuous batcher ([`Batcher`]) against its own
//! paged [`KvCacheManager`], and every scheduler step is timed by the
//! chosen overlap strategy ([`Method`]): `Method::Flux` is the fused
//! fine-grained kernel, `Method::NonOverlap` the decoupled
//! GEMM-then-NCCL execution the paper compares against (vLLM /
//! Megatron-LM serving).
//!
//! Routing: the default is round-robin — the request→replica assignment
//! is then identical for every `Method`, so a Flux-vs-decoupled
//! comparison measures execution speed, never routing luck.
//! [`Routing::LeastOutstanding`] is the opt-in alternative for tail
//! latency under bursty, skewed traffic; it reacts to queue state, so
//! its assignment legitimately depends on the method being timed (both
//! methods still run the same policy). Replicas never share links
//! (`ScaleTopology::validate` pins TP inside a node), so the only
//! coupling between them is the shared arrival process — which is what
//! makes tail latency (p99 TTFT) a cluster-level, not replica-level,
//! quantity.
//!
//! Everything is seeded and deterministic: the same [`ScaleScenario`]
//! produces byte-identical reports across reruns, which is what lets CI
//! diff the `flux simulate --scale --json` output. The default
//! `poisson-balanced` workload replays the PR-2 coordinator's PRNG
//! draw sequence exactly (one exponential per request, fixed lengths),
//! so its timings are bit-identical to the pre-workload reports — the
//! compat tests pin those f64s.

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::cost::arch::ScaleTopology;
use crate::faults::{FaultAction, FaultEvent, FaultTimeline};
use crate::model::analysis::{layer_attention_extra_ns, layer_fwd_ops};
use crate::model::configs::TransformerConfig;
use crate::obs::{self, Metrics};
use crate::overlap::Method;
use crate::serving::batcher::{Batcher, BatcherConfig, Work};
use crate::serving::kvcache::KvCacheManager;
use crate::serving::request::{Request, RequestState};
use crate::serving::simulate::{
    decode_cache_len, decode_step_ns, prefill_ns,
};
use crate::sim::engine::EventQueue;
use crate::sim::trace::Trace;
use crate::util::json::Json;
use crate::util::stats::{PercentileMode, Sketch, Streaming, Summary};
use crate::workload::{Routing, SloReport, WorkloadSpec};

/// One serving-at-scale experiment: a topology, a model, an engine
/// shape and a declarative workload.
#[derive(Clone, Debug)]
pub struct ScaleScenario {
    pub topo: &'static ScaleTopology,
    pub model: &'static TransformerConfig,
    pub workload: WorkloadSpec,
    pub max_prefill_batch: usize,
    pub max_decode_batch: usize,
    /// KV pool per replica, in worst-case sequences' worth of blocks
    /// (the decode concurrency cap).
    pub kv_seqs: usize,
    pub seed: u64,
    /// Percentile estimator for the latency summaries. `Exact`
    /// (default) buffers every sample; `Sketch` *additionally* folds
    /// each sample into constant-space fixed-boundary histograms and
    /// fills the additive `*_sketch` report fields — the exact fields
    /// stay populated either way, so report bytes never change on the
    /// default path.
    pub percentiles: PercentileMode,
}

impl ScaleScenario {
    /// The engine shape shared by every scenario (PR-2's values).
    pub fn with_workload(
        topo: &'static ScaleTopology,
        workload: WorkloadSpec,
    ) -> ScaleScenario {
        ScaleScenario {
            topo,
            model: &crate::model::configs::GPT3_175B,
            workload,
            max_prefill_batch: 4,
            max_decode_batch: 8,
            kv_seqs: 16,
            seed: 17,
            percentiles: PercentileMode::Exact,
        }
    }

    /// Same scenario with the given percentile estimator.
    pub fn with_percentiles(
        mut self,
        percentiles: PercentileMode,
    ) -> ScaleScenario {
        self.percentiles = percentiles;
        self
    }

    /// CI-sized scenario: the default workload preset, quick variant
    /// (saturating Poisson arrivals so queueing — and therefore the
    /// overlap speedup — is visible in the latency percentiles).
    pub fn quick(topo: &'static ScaleTopology) -> ScaleScenario {
        ScaleScenario::with_workload(
            topo,
            crate::workload::preset("poisson-balanced", true)
                .expect("default preset exists"),
        )
    }

    /// Paper-shaped scenario: more requests, longer generations.
    pub fn full(topo: &'static ScaleTopology) -> ScaleScenario {
        ScaleScenario::with_workload(
            topo,
            crate::workload::preset("poisson-balanced", false)
                .expect("default preset exists"),
        )
    }

    /// Total requests across the cluster.
    pub fn n_requests(&self) -> usize {
        self.workload.requests_per_replica * self.topo.dp
    }
}

/// Per-replica accounting for the report.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub completed: usize,
    pub tokens: usize,
    pub prefill_batches: u64,
    pub decode_steps: u64,
    /// Time this replica spent executing steps, ns.
    pub busy_ns: f64,
}

/// Cluster-level result of one (scenario, method) run.
#[derive(Clone, Debug)]
pub struct ScaleReport {
    pub method: Method,
    pub completed: usize,
    /// Requests abandoned by faults: drained mid-flight by a replica
    /// kill or elastic resize, or arriving while no replica was
    /// routable. Zero on every fault-free run.
    pub failed: usize,
    pub tokens: usize,
    pub makespan_ns: f64,
    /// Time to first token (arrival → prefill done), per request.
    pub ttft: Summary,
    /// Mean inter-token decode latency, per request.
    pub per_token: Summary,
    /// End-to-end latency, per request.
    pub latency: Summary,
    /// Constant-space sketch summaries (additive): `Some` only when
    /// the scenario opted into [`PercentileMode::Sketch`]. Scalar
    /// fields (`n`/`mean`/`min`/`max`) are exact; the percentiles are
    /// bucketed over [`obs::LATENCY_BOUNDS_NS`], each within one
    /// bucket of its exact counterpart above. `None` — and absent
    /// from every report byte — on the default exact path.
    pub ttft_sketch: Option<Summary>,
    pub per_token_sketch: Option<Summary>,
    pub latency_sketch: Option<Summary>,
    pub tokens_per_sec: f64,
    /// Step-level overlap efficiency of this method at the prefill
    /// reference batch (Eq. 2 applied at the model level).
    pub overlap_eff: f64,
    /// Goodput/abandonment accounting, when the workload defines SLOs.
    pub slo: Option<SloReport>,
    pub replicas: Vec<ReplicaReport>,
}

/// The communication-free lower bound of a prefill step: every TP op at
/// its monolithic-GEMM time (Eq. 1's `GEMM_non-split`), attention
/// included. Used as the denominator of the model-level Eq. 2.
pub fn ideal_prefill_ns(
    topo: &ScaleTopology,
    model: &TransformerConfig,
    batch: usize,
    seq: usize,
) -> f64 {
    let m = batch * seq;
    let mut t = 0.0;
    for p in layer_fwd_ops(model, m, topo.tp) {
        t += p.gemm_nonsplit_ns(topo.cluster);
    }
    t += layer_attention_extra_ns(topo.cluster, model, m, seq, topo.tp);
    t * model.n_layers as f64
}

/// Model-level overlap efficiency (Eq. 2): what fraction of the
/// decoupled execution's exposed communication time the method hides,
/// measured at the scenario's reference prefill batch (full prefill
/// batch of the mix's longest prompt — for a fixed mix, exactly the
/// pre-workload reference).
pub fn scale_overlap_efficiency(sc: &ScaleScenario, method: Method) -> f64 {
    let ref_seq = sc.workload.mix.max_prompt();
    let base = prefill_ns(
        sc.topo.cluster,
        sc.model,
        sc.max_prefill_batch,
        ref_seq,
        sc.topo.tp,
        Method::NonOverlap,
        sc.seed,
    );
    let ideal =
        ideal_prefill_ns(sc.topo, sc.model, sc.max_prefill_batch, ref_seq);
    let t = prefill_ns(
        sc.topo.cluster,
        sc.model,
        sc.max_prefill_batch,
        ref_seq,
        sc.topo.tp,
        method,
        sc.seed,
    );
    let exposed = base - ideal;
    if exposed <= 0.0 {
        return 0.0;
    }
    (base - t) / exposed
}

/// Per-replica runtime state, struct-of-arrays.
///
/// The DES hot loop touches one or two fields of one replica per event
/// (a routing scan reads only outstanding counts, a step completion
/// only that replica's batch bookkeeping); splitting the arrays keeps
/// each scan contiguous in memory instead of striding over whole
/// replica records. Index `r` across all vectors is one replica.
struct Replicas {
    batchers: Vec<Batcher>,
    kvs: Vec<KvCacheManager>,
    /// Ids of the batch currently executing (empty when idle).
    in_flight: Vec<Vec<u64>>,
    in_flight_is_prefill: Vec<bool>,
    busy_ns: Vec<f64>,
    /// False between a kill and its restart; dead replicas are
    /// unroutable and their in-flight step completions are stale.
    alive: Vec<bool>,
    /// Bumped on every drain (kill or resize): a `StepDone` stamped
    /// with an older epoch must not retire the replica's next batch.
    epoch: Vec<u64>,
}

impl Replicas {
    /// Abandon everything a replica holds: the executing batch, the
    /// running set and the admission queue. Every KV block comes back
    /// to the pool and every unfinished request flips to `Failed`.
    /// Returns the drained ids (queue order, then running order).
    fn drain(&mut self, r: usize) -> Result<Vec<u64>> {
        self.epoch[r] += 1;
        self.in_flight[r].clear();
        self.in_flight_is_prefill[r] = false;
        self.batchers[r].drain(&mut self.kvs[r])
    }
}

/// DES events. Arrivals carry the request index; step completions the
/// replica index and the epoch the step was scheduled under; faults
/// index the pre-expanded [`FaultTimeline::events`] list.
enum Ev {
    Arrive(usize),
    StepDone(usize, u64),
    Fault(usize),
}

/// Step-cost memo, shareable across replicas and whole method sets.
///
/// [`prefill_ns`]/[`decode_step_ns`] are pure functions of
/// `(cluster, model, batch, len, tp, method, seed)`, so within one
/// scenario a step's cost depends only on `(method, phase, batch,
/// len)`: replica-independent and method-keyed. Sharing one cache
/// across every replica and method of the same scenario is therefore
/// bit-safe by construction — the tests pin shared-vs-fresh equality.
/// A one-entry last-key memo fronts the `BTreeMap`: steady-state
/// decode repeats the previous step shape far more often than not, so
/// the hot path usually skips the tree walk entirely.
///
/// The keys deliberately omit the scenario, so a cache must only ever
/// be shared between runs of the SAME scenario — the caller owns that
/// contract ([`run_scale_methods`] is the in-tree example).
#[derive(Clone, Debug, Default)]
pub struct StepCostCache {
    map: BTreeMap<(&'static str, bool, usize, usize), f64>,
    last: Option<((&'static str, bool, usize, usize), f64)>,
}

impl StepCostCache {
    pub fn new() -> StepCostCache {
        StepCostCache::default()
    }

    /// Distinct step shapes costed so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Cost of one step, memoized. `len` is the padded prompt length
    /// for prefill, the mean representative KV length for decode.
    fn step_ns(
        &mut self,
        sc: &ScaleScenario,
        method: Method,
        is_prefill: bool,
        batch: usize,
        len: usize,
    ) -> f64 {
        let key = (method.name(), is_prefill, batch, len);
        if let Some((k, v)) = self.last {
            if k == key {
                return v;
            }
        }
        let v = *self.map.entry(key).or_insert_with(|| {
            if is_prefill {
                prefill_ns(
                    sc.topo.cluster,
                    sc.model,
                    batch,
                    len,
                    sc.topo.tp,
                    method,
                    sc.seed,
                )
            } else {
                decode_step_ns(
                    sc.topo.cluster,
                    sc.model,
                    batch,
                    len,
                    sc.topo.tp,
                    method,
                    sc.seed,
                )
            }
        });
        self.last = Some((key, v));
        v
    }
}

thread_local! {
    /// Per-worker event-queue arena: `run_scale_inner` checks the
    /// queue out at entry and returns it — reset, allocations intact —
    /// on the way out, so consecutive cells on one [`crate::exp`]
    /// worker thread reuse the event slab and bucket vectors instead
    /// of regrowing them from scratch. A reset queue is
    /// observationally identical to `EventQueue::new()` (the engine
    /// tests pin this), so reuse cannot perturb results.
    static QUEUE_ARENA: RefCell<Option<EventQueue<Ev>>> =
        const { RefCell::new(None) };
}

/// The all-zero summary of an empty percentile stream (total-churn
/// runs where every request failed).
fn empty_summary() -> Summary {
    Summary {
        n: 0,
        mean: 0.0,
        std: 0.0,
        min: 0.0,
        p50: 0.0,
        p95: 0.0,
        p99: 0.0,
        max: 0.0,
    }
}

/// Run one (scenario, method) serving simulation to completion.
pub fn run_scale(sc: &ScaleScenario, method: Method) -> Result<ScaleReport> {
    run_scale_inner(sc, method, None, None, None, None)
}

/// [`run_scale`] against a caller-owned [`StepCostCache`], so one
/// cache can serve a whole method set (or repeated runs) of the same
/// scenario. Bit-identical to [`run_scale`] by cost-function purity —
/// the tests pin it.
pub fn run_scale_cached(
    sc: &ScaleScenario,
    method: Method,
    cache: &mut StepCostCache,
) -> Result<ScaleReport> {
    run_scale_inner(sc, method, None, None, None, Some(cache))
}

/// The fully-instrumented entry: optional fault timeline, optional
/// chrome trace and optional [`Metrics`] registry in one call. The
/// telemetry side channels only *read* simulator state, so any
/// combination of `None`s is byte-identical to the plain
/// [`run_scale`]/[`run_scale_faulted`] paths — the compat tests pin
/// this.
pub fn run_scale_observed(
    sc: &ScaleScenario,
    method: Method,
    faults: Option<&FaultTimeline>,
    trace: Option<(&mut Trace, usize)>,
    metrics: Option<&mut Metrics>,
) -> Result<ScaleReport> {
    run_scale_inner(sc, method, trace, faults, metrics, None)
}

/// Like [`run_scale`], optionally recording the DES event stream into
/// a chrome trace: `(trace, pid0)` — replica `r` becomes process
/// `pid0 + r`, so method lanes stack side by side in one timeline.
pub fn run_scale_traced(
    sc: &ScaleScenario,
    method: Method,
    trace: Option<(&mut Trace, usize)>,
) -> Result<ScaleReport> {
    run_scale_inner(sc, method, trace, None, None, None)
}

/// [`run_scale`] under an expanded fault timeline: replica kills drain
/// their batcher (every KV block released, in-flight requests fail),
/// restarts rejoin the routing set after the seeded downtime, elastic
/// resizes shrink/grow the routable prefix, and straggler windows
/// inflate step times by their factor. NIC windows are a no-op here:
/// serving replicas never talk across nodes, so inter-node degradation
/// only matters to training. An empty timeline is byte-identical to
/// [`run_scale`].
pub fn run_scale_faulted(
    sc: &ScaleScenario,
    method: Method,
    faults: &FaultTimeline,
) -> Result<ScaleReport> {
    run_scale_inner(sc, method, None, Some(faults), None, None)
}

/// [`run_scale_faulted`] with the chrome-trace capture of
/// [`run_scale_traced`]: kills/restarts land as instants, downtime and
/// straggler windows as spans on the afflicted replica's lane.
pub fn run_scale_faulted_traced(
    sc: &ScaleScenario,
    method: Method,
    faults: &FaultTimeline,
    trace: Option<(&mut Trace, usize)>,
) -> Result<ScaleReport> {
    run_scale_inner(sc, method, trace, Some(faults), None, None)
}

fn run_scale_inner(
    sc: &ScaleScenario,
    method: Method,
    mut trace: Option<(&mut Trace, usize)>,
    faults: Option<&FaultTimeline>,
    mut metrics: Option<&mut Metrics>,
    cache: Option<&mut StepCostCache>,
) -> Result<ScaleReport> {
    sc.topo.validate()?;
    sc.workload.validate()?;
    let dp = sc.topo.dp;
    let gw = sc.workload.generate(sc.seed, dp);
    let n = gw.n_requests();
    ensure!(n > 0, "empty workload");
    let max_prompt = gw.max_prompt();
    let max_total = gw.max_total();
    let block_tokens = 64;
    let blocks_per_seq = max_total.div_ceil(block_tokens) + 1;
    let max_prefill_tokens = sc
        .workload
        .max_prefill_tokens
        .unwrap_or(max_prompt * sc.max_prefill_batch);

    // An empty timeline (intensity 0, or a fault-free spec) must take
    // the exact fault-free path: no fault events, no step-time
    // arithmetic, no extra branches that could perturb f64 results.
    let timeline = faults.filter(|tl| !tl.is_empty());

    if let Some((tr, pid0)) = trace.as_mut() {
        for r in 0..dp {
            tr.process_name(
                *pid0 + r,
                &format!("{}/replica{r}", method.name()),
            );
        }
        if let Some(tl) = timeline {
            for w in &tl.stragglers {
                if w.replica < dp {
                    tr.span(
                        *pid0 + w.replica,
                        1,
                        "straggler",
                        w.start_ns,
                        w.end_ns - w.start_ns,
                        vec![("factor", Json::from(w.factor))],
                    );
                }
            }
            for k in &tl.kills {
                if k.replica < dp {
                    tr.span(
                        *pid0 + k.replica,
                        1,
                        "down",
                        k.at_ns,
                        k.restart_ns - k.at_ns,
                        vec![],
                    );
                }
            }
        }
    }

    let mut reps = Replicas {
        batchers: (0..dp)
            .map(|_| {
                Batcher::new(BatcherConfig {
                    max_prefill_batch: sc.max_prefill_batch,
                    max_decode_batch: sc.max_decode_batch,
                    max_prompt,
                    max_seq: max_total + 1,
                    max_prefill_tokens,
                })
            })
            .collect(),
        kvs: (0..dp)
            .map(|_| {
                KvCacheManager::new(
                    sc.kv_seqs * blocks_per_seq,
                    block_tokens,
                )
            })
            .collect(),
        in_flight: vec![Vec::new(); dp],
        in_flight_is_prefill: vec![false; dp],
        busy_ns: vec![0.0; dp],
        alive: vec![true; dp],
        epoch: vec![0u64; dp],
    };
    // Replicas at or above this index are drained by an elastic
    // resize and unroutable until the restore raises it back.
    let mut active_dp = dp;
    // Requests that arrived while no replica was routable: they fail
    // at the gateway and never reach a batcher.
    let mut gateway_failures = 0usize;

    let fault_evs: Vec<FaultEvent> = match timeline {
        Some(tl) => tl.events(dp),
        None => Vec::new(),
    };

    // Step-time cache: (method, phase, batch, padded-seq | mean-
    // cache-len) → ns. Identical across replicas (same spec/model/
    // method/seed), so one cluster-wide memo — and shareable across
    // methods when the caller passes one in ([`run_scale_methods`]).
    // For a fixed mix the len key component is constant and the
    // cached values equal the pre-workload ones.
    let mut local_cache = StepCostCache::new();
    let cache: &mut StepCostCache = match cache {
        Some(c) => c,
        None => &mut local_cache,
    };

    // Open-loop arrivals are pre-drawn (identical for every method
    // under the same seed); the closed loop issues request `i` at
    // completion time + its pre-drawn think gap, so arrival times
    // legitimately depend on the execution being timed. The queue
    // comes from the per-worker arena (slab reuse across cells); the
    // open-loop pre-schedule batch-admits through `schedule_many`,
    // amortizing the calendar's grow checks over the whole stream.
    let mut q: EventQueue<Ev> = QUEUE_ARENA
        .with(|a| a.borrow_mut().take())
        .unwrap_or_default();
    let mut issued = 0usize;
    if gw.is_closed_loop() {
        let users = (gw.concurrency * dp).min(n);
        q.schedule_many(
            (0..users).map(|i| (gw.think_gaps[i], Ev::Arrive(i))),
        );
        issued = users;
    } else {
        q.schedule_many(
            gw.arrivals
                .iter()
                .enumerate()
                .map(|(i, &at)| (at, Ev::Arrive(i))),
        );
        issued = n;
    }
    for (fi, fe) in fault_evs.iter().enumerate() {
        q.schedule(fe.at_ns, Ev::Fault(fi));
    }

    // Round-robin position (arrival order, which for open-loop equals
    // request-index order — the PR-2 assignment).
    let mut rr_next = 0usize;

    // Scratch reused across step completions: the all-zero token
    // batch every completion feeds (the serving model never inspects
    // token values), sized to the largest batch seen instead of
    // allocated per step.
    let mut toks: Vec<i32> = Vec::new();

    while let Some((now, ev)) = q.next() {
        // Seeded-cadence gauge snapshot: queue depth, running set, KV
        // occupancy per replica and the routable-DP count — read-only,
        // so the fault-free f64 pins are untouched. The same samples
        // feed chrome-trace "C" counter tracks when a trace rides
        // along.
        if let Some(m) = metrics.as_deref_mut() {
            if let Some(t) = m.sample_due(now) {
                for r in 0..dp {
                    let queued = reps.batchers[r].queued() as f64;
                    let running = reps.batchers[r].running() as f64;
                    let used = reps.kvs[r].used_blocks() as f64;
                    let free = reps.kvs[r].free_blocks() as f64;
                    m.point(t, "serve.queue_depth", obs::replica(r), queued);
                    m.point(t, "serve.running", obs::replica(r), running);
                    m.point(t, "serve.kv_used_blocks", obs::replica(r), used);
                    m.point(t, "serve.kv_free_blocks", obs::replica(r), free);
                    if let Some((tr, pid0)) = trace.as_mut() {
                        tr.counter(
                            *pid0 + r,
                            "serve.queue_depth",
                            t,
                            vec![("value", Json::from(queued))],
                        );
                        tr.counter(
                            *pid0 + r,
                            "serve.kv_used_blocks",
                            t,
                            vec![("value", Json::from(used))],
                        );
                    }
                }
                let routable = (0..active_dp)
                    .filter(|&j| reps.alive[j])
                    .count() as f64;
                m.point(t, "serve.active_dp", obs::labels(&[]), routable);
                if let Some((tr, pid0)) = trace.as_mut() {
                    tr.counter(
                        *pid0,
                        "serve.active_dp",
                        t,
                        vec![("value", Json::from(routable))],
                    );
                }
            }
        }
        let r = match ev {
            Ev::Arrive(i) => {
                let routable =
                    |j: usize| reps.alive[j] && j < active_dp;
                let routed = match sc.workload.routing {
                    Routing::RoundRobin => {
                        // Probe forward from the rotation point past
                        // dead/resized-away replicas; with everything
                        // routable this reduces to the fault-free
                        // `rr_next % dp` assignment exactly.
                        let mut r = rr_next % dp;
                        let mut probes = 0;
                        while probes < dp && !routable(r) {
                            r = (r + 1) % dp;
                            probes += 1;
                        }
                        if routable(r) {
                            rr_next = r + 1;
                            Some(r)
                        } else {
                            None
                        }
                    }
                    // Fewest outstanding wins; ties to the lowest
                    // index for determinism.
                    Routing::LeastOutstanding => (0..dp)
                        .filter(|&j| routable(j))
                        .min_by_key(|&j| {
                            (reps.batchers[j].outstanding(), j)
                        }),
                };
                let Some(r) = routed else {
                    // Nothing routable: the request fails at the
                    // gateway. A closed-loop user still comes back
                    // after thinking.
                    gateway_failures += 1;
                    if let Some(m) = metrics.as_deref_mut() {
                        m.inc("serve.gateway_failures", obs::labels(&[]));
                    }
                    if let Some((tr, pid0)) = trace.as_mut() {
                        tr.instant(
                            *pid0,
                            0,
                            "arrive-failed",
                            now,
                            vec![("req", Json::from(i))],
                        );
                    }
                    if gw.is_closed_loop() && issued < n {
                        q.schedule(
                            now + gw.think_gaps[issued],
                            Ev::Arrive(issued),
                        );
                        issued += 1;
                    }
                    continue;
                };
                let len = gw.lengths[i];
                if let Some((tr, pid0)) = trace.as_mut() {
                    tr.instant(
                        *pid0 + r,
                        0,
                        "arrive",
                        now,
                        vec![("req", Json::from(i))],
                    );
                }
                reps.batchers[r].submit(Request::new(
                    i as u64,
                    now,
                    vec![1; len.prompt],
                    len.gen,
                ));
                if let Some(m) = metrics.as_deref_mut() {
                    m.inc("serve.admitted", obs::replica(r));
                }
                r
            }
            Ev::StepDone(r, epoch) => {
                if reps.epoch[r] != epoch {
                    // The step's batch was drained by a kill or
                    // resize after this completion was scheduled.
                    continue;
                }
                let ids = std::mem::take(&mut reps.in_flight[r]);
                if reps.in_flight_is_prefill[r] {
                    // Prefill emits each sequence's first token.
                    for &id in &ids {
                        reps.batchers[r].get_mut(id).prefill_done_ns =
                            Some(now);
                    }
                }
                toks.clear();
                toks.resize(ids.len(), 0);
                let finished = reps.batchers[r]
                    .complete_decode(&ids, &toks, &mut reps.kvs[r], now)
                    .with_context(|| format!("replica {r} step at {now}"))?;
                if let Some(m) = metrics.as_deref_mut() {
                    if !finished.is_empty() {
                        m.add(
                            "serve.completions",
                            obs::replica(r),
                            finished.len() as f64,
                        );
                    }
                }
                // Closed loop: each completion frees a user, who
                // thinks, then issues the next request.
                if gw.is_closed_loop() {
                    for _ in &finished {
                        if issued < n {
                            q.schedule(
                                now + gw.think_gaps[issued],
                                Ev::Arrive(issued),
                            );
                            issued += 1;
                        }
                    }
                }
                r
            }
            Ev::Fault(fi) => {
                let drained = match fault_evs[fi].action {
                    FaultAction::Kill(r) => {
                        if !reps.alive[r] {
                            continue;
                        }
                        reps.alive[r] = false;
                        if let Some((tr, pid0)) = trace.as_mut() {
                            tr.instant(*pid0 + r, 0, "kill", now, vec![]);
                        }
                        if let Some(m) = metrics.as_deref_mut() {
                            m.marker(now, "fault.kill", obs::replica(r));
                        }
                        reps.drain(r).with_context(|| {
                            format!("kill of replica {r} at {now}")
                        })?
                    }
                    FaultAction::Restart(r) => {
                        reps.alive[r] = true;
                        if let Some((tr, pid0)) = trace.as_mut() {
                            tr.instant(
                                *pid0 + r,
                                0,
                                "restart",
                                now,
                                vec![],
                            );
                        }
                        if let Some(m) = metrics.as_deref_mut() {
                            m.marker(now, "fault.restart", obs::replica(r));
                        }
                        continue;
                    }
                    FaultAction::SetDp(target) => {
                        let target = target.clamp(1, dp);
                        let mut drained = Vec::new();
                        for r in target..active_dp {
                            drained.extend(reps.drain(r).with_context(
                                || {
                                    format!(
                                        "resize drain of replica {r} \
                                         at {now}"
                                    )
                                },
                            )?);
                        }
                        active_dp = target;
                        if let Some((tr, pid0)) = trace.as_mut() {
                            tr.instant(
                                *pid0,
                                0,
                                "resize",
                                now,
                                vec![("dp", Json::from(target))],
                            );
                        }
                        if let Some(m) = metrics.as_deref_mut() {
                            m.marker(
                                now,
                                "fault.resize",
                                obs::labels(&[("dp", &target.to_string())]),
                            );
                        }
                        drained
                    }
                };
                if let Some(m) = metrics.as_deref_mut() {
                    if !drained.is_empty() {
                        m.add(
                            "serve.drained",
                            obs::labels(&[]),
                            drained.len() as f64,
                        );
                    }
                }
                // Every drained request frees its closed-loop user.
                if gw.is_closed_loop() {
                    for _ in &drained {
                        if issued < n {
                            q.schedule(
                                now + gw.think_gaps[issued],
                                Ev::Arrive(issued),
                            );
                            issued += 1;
                        }
                    }
                }
                continue;
            }
        };
        // Try to start the next step on the touched replica.
        if reps.in_flight[r].is_empty() {
            let work = reps.batchers[r].next_work(&mut reps.kvs[r])?;
            let (ids, is_prefill) = match work {
                Work::Prefill(ids) => (ids, true),
                Work::Decode(ids) => (ids, false),
                Work::Idle => continue,
            };
            // Prefill runs padded to the batch's longest prompt;
            // decode is costed at the batch's mean representative
            // KV length (prompt + gen/2 each, the same midpoint the
            // single-group loop uses).
            let len = if is_prefill {
                ids.iter()
                    .map(|&id| reps.batchers[r].get(id).prompt.len())
                    .max()
                    .expect("non-empty batch")
            } else {
                ids.iter()
                    .map(|&id| {
                        let req = reps.batchers[r].get(id);
                        decode_cache_len(
                            req.prompt.len(),
                            req.max_new_tokens,
                        )
                    })
                    .sum::<usize>()
                    / ids.len()
            };
            let t = match timeline {
                // Straggler windows inflate the step that STARTS
                // inside them; the fault-free arm keeps the cached
                // value untouched (not even a `* 1.0`).
                Some(tl) => {
                    cache.step_ns(sc, method, is_prefill, ids.len(), len)
                        * tl.step_factor(r, now)
                }
                None => {
                    cache.step_ns(sc, method, is_prefill, ids.len(), len)
                }
            };
            if let Some((tr, pid0)) = trace.as_mut() {
                tr.span(
                    *pid0 + r,
                    0,
                    if is_prefill { "prefill" } else { "decode" },
                    now,
                    t,
                    vec![
                        ("batch", Json::from(ids.len())),
                        (
                            if is_prefill { "seq" } else { "cache_len" },
                            Json::from(len),
                        ),
                    ],
                );
            }
            if let Some(m) = metrics.as_deref_mut() {
                m.inc(
                    if is_prefill {
                        "serve.prefill_steps"
                    } else {
                        "serve.decode_steps"
                    },
                    obs::replica(r),
                );
                m.add("serve.step_ns", obs::replica(r), t);
            }
            reps.in_flight[r] = ids;
            reps.in_flight_is_prefill[r] = is_prefill;
            reps.busy_ns[r] += t;
            q.schedule(now + t, Ev::StepDone(r, reps.epoch[r]));
        }
    }

    // All requests were issued and every generation is finite, so a
    // drained queue means a drained cluster.
    ensure!(issued == n, "closed loop stalled at {issued}/{n} issued");
    for (r, batcher) in reps.batchers.iter().enumerate() {
        ensure!(
            batcher.all_done(),
            "replica {r} stalled with work left (KV pool too small?)"
        );
    }

    // End-of-run telemetry: DES engine counters and per-replica
    // TTFT/latency histograms. A separate read-only pass, so the
    // Streaming finalization below stays bit-identical to the
    // metrics-off path.
    if let Some(m) = metrics.as_deref_mut() {
        let root = obs::labels(&[]);
        m.add("engine.events_popped", root.clone(), q.pops() as f64);
        m.add("engine.events_scheduled", root.clone(), q.scheduled() as f64);
        m.add("engine.calendar_rebuilds", root, q.rebuilds() as f64);
        for (r, batcher) in reps.batchers.iter().enumerate() {
            for req in &batcher.requests {
                if req.state == RequestState::Failed {
                    continue;
                }
                if let (Some(t), Some(l)) = (req.ttft_ns(), req.latency_ns()) {
                    m.observe(
                        "serve.ttft_ns",
                        obs::replica(r),
                        &obs::LATENCY_BOUNDS_NS,
                        t,
                    );
                    m.observe(
                        "serve.latency_ns",
                        obs::replica(r),
                        &obs::LATENCY_BOUNDS_NS,
                        l,
                    );
                }
            }
        }
    }

    // Return the drained queue to the worker arena: `reset()` keeps
    // the slab and bucket allocations for the next cell on this
    // thread while restoring new-queue state exactly.
    q.reset();
    QUEUE_ARENA.with(|a| *a.borrow_mut() = Some(q));

    // Streaming accumulators in the same replica-major visit order the
    // collected Vecs used: running sums in push order are bit-identical
    // to the old collect-then-`Summary::of` path. Failed requests have
    // no finite latencies — they are counted, SLO-observed with
    // infinite TTFT (missed deadlines, abandoned) and kept out of the
    // percentile streams. In sketch mode the same samples additionally
    // stream through constant-space fixed-boundary histograms.
    let mut ttft = Streaming::with_capacity(n);
    let mut per_token = Streaming::with_capacity(n);
    let mut latency = Streaming::with_capacity(n);
    let mut sketches = (sc.percentiles == PercentileMode::Sketch)
        .then(|| {
            [
                Sketch::new(&obs::LATENCY_BOUNDS_NS),
                Sketch::new(&obs::LATENCY_BOUNDS_NS),
                Sketch::new(&obs::LATENCY_BOUNDS_NS),
            ]
        });
    let mut makespan: f64 = 0.0;
    let mut failed = gateway_failures;
    let mut slo_report = sc.workload.slo.map(|_| SloReport::default());
    for batcher in &reps.batchers {
        for req in &batcher.requests {
            if req.state == RequestState::Failed {
                failed += 1;
                if let (Some(slo), Some(report)) =
                    (&sc.workload.slo, slo_report.as_mut())
                {
                    report.observe(
                        slo,
                        f64::INFINITY,
                        f64::INFINITY,
                        req.generated.len(),
                    );
                }
                continue;
            }
            let t = req
                .ttft_ns()
                .context("request finished without a prefill timestamp")?;
            let l = req.latency_ns().context("request not finished")?;
            ttft.push(t);
            latency.push(l);
            // First token lands with prefill; the rest are decode steps.
            let decode_tokens = (req.generated.len() - 1).max(1);
            let pt = (l - t) / decode_tokens as f64;
            per_token.push(pt);
            if let Some([st, sp, sl]) = sketches.as_mut() {
                st.observe(t);
                sp.observe(pt);
                sl.observe(l);
            }
            makespan = makespan.max(req.finished_ns.unwrap());
            if let (Some(slo), Some(report)) =
                (&sc.workload.slo, slo_report.as_mut())
            {
                report.observe(slo, t, pt, req.generated.len());
            }
        }
    }
    // Gateway failures never generated a token; they still count
    // against goodput and as abandoned.
    if let (Some(slo), Some(report)) =
        (&sc.workload.slo, slo_report.as_mut())
    {
        for _ in 0..gateway_failures {
            report.observe(slo, f64::INFINITY, f64::INFINITY, 0);
        }
    }

    let replica_reports: Vec<ReplicaReport> = reps
        .batchers
        .iter()
        .zip(&reps.busy_ns)
        .map(|(batcher, &busy_ns)| ReplicaReport {
            completed: batcher
                .requests
                .iter()
                .filter(|r| r.finished_ns.is_some())
                .count(),
            tokens: batcher
                .requests
                .iter()
                .filter(|r| r.finished_ns.is_some())
                .map(|r| r.generated.len())
                .sum(),
            prefill_batches: batcher.prefill_batches,
            decode_steps: batcher.decode_steps,
            busy_ns,
        })
        .collect();

    let completed: usize =
        replica_reports.iter().map(|r| r.completed).sum();
    ensure!(
        completed + failed == n,
        "request conservation violated: {completed} completed + \
         {failed} failed != {n} issued"
    );
    // Under total churn every request can fail: the percentile streams
    // are then empty and the summaries all-zero by construction — in
    // both modes.
    let summarize = |s: Streaming| -> Summary {
        if s.is_empty() {
            empty_summary()
        } else {
            s.finalize()
        }
    };
    let sketched = |s: &Sketch| -> Summary {
        if s.is_empty() {
            empty_summary()
        } else {
            s.summary()
        }
    };
    let [ttft_sketch, per_token_sketch, latency_sketch] = match &sketches
    {
        Some([st, sp, sl]) => {
            [Some(sketched(st)), Some(sketched(sp)), Some(sketched(sl))]
        }
        None => [None, None, None],
    };
    let tokens: usize = replica_reports.iter().map(|r| r.tokens).sum();
    Ok(ScaleReport {
        method,
        completed,
        failed,
        tokens,
        makespan_ns: makespan,
        ttft: summarize(ttft),
        per_token: summarize(per_token),
        latency: summarize(latency),
        ttft_sketch,
        per_token_sketch,
        latency_sketch,
        tokens_per_sec: if makespan > 0.0 {
            tokens as f64 / (makespan * 1e-9)
        } else {
            0.0
        },
        overlap_eff: scale_overlap_efficiency(sc, method),
        slo: slo_report,
        replicas: replica_reports,
    })
}

/// Run one scenario under every method in `methods`, sequentially and
/// in order — the uniform method-set entry for in-process callers
/// (comparisons, tests). The report layer reaches the same `run_scale`
/// runs through `exp::Runner::run_product` instead, so the method set
/// spreads across workers there.
pub fn run_scale_methods(
    sc: &ScaleScenario,
    methods: &[Method],
) -> Result<Vec<ScaleReport>> {
    // One step-cost cache across the whole set: the keys carry the
    // method, so sharing is bit-identical to per-run caches (pinned by
    // the tests) and the second method starts with the first method's
    // shapes already enumerated.
    let mut cache = StepCostCache::new();
    methods
        .iter()
        .map(|&m| run_scale_cached(sc, m, &mut cache))
        .collect()
}

/// The Fig. 16/17-shaped comparison: the same scenario under the
/// decoupled (vLLM-style) and Flux executions.
pub struct ScaleComparison {
    pub decoupled: ScaleReport,
    pub flux: ScaleReport,
}

impl ScaleComparison {
    /// Assemble the flux-vs-decoupled comparison out of a method-set
    /// run, when both reference methods are present.
    pub fn from_runs(runs: &[ScaleReport]) -> Option<ScaleComparison> {
        let find = |m: Method| {
            runs.iter().find(|r| r.method == m).cloned()
        };
        Some(ScaleComparison {
            decoupled: find(Method::NonOverlap)?,
            flux: find(Method::Flux)?,
        })
    }

    /// Throughput speedup of Flux over the decoupled execution.
    pub fn speedup(&self) -> f64 {
        self.decoupled.makespan_ns / self.flux.makespan_ns
    }

    /// Mean end-to-end latency speedup.
    pub fn latency_speedup(&self) -> f64 {
        self.decoupled.latency.mean / self.flux.latency.mean
    }

    /// Attained-goodput advantage (flux - decoupled), when SLOs are
    /// defined.
    pub fn goodput_delta(&self) -> Option<f64> {
        match (&self.flux.slo, &self.decoupled.slo) {
            (Some(f), Some(d)) => Some(f.goodput() - d.goodput()),
            _ => None,
        }
    }
}

pub fn compare_scale(sc: &ScaleScenario) -> Result<ScaleComparison> {
    let runs = run_scale_methods(sc, &Method::SERVE_SET)?;
    Ok(ScaleComparison::from_runs(&runs)
        .expect("SERVE_SET contains both reference methods"))
}

/// Both methods with the DES streams captured side by side in one
/// chrome trace: decoupled replicas on pids `[0, dp)`, flux on
/// `[dp, 2*dp)`.
pub fn compare_scale_traced(
    sc: &ScaleScenario,
    trace: &mut Trace,
) -> Result<ScaleComparison> {
    Ok(ScaleComparison {
        decoupled: run_scale_traced(
            sc,
            Method::NonOverlap,
            Some((&mut *trace, 0)),
        )?,
        flux: run_scale_traced(
            sc,
            Method::Flux,
            Some((&mut *trace, sc.topo.dp)),
        )?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{
        ALL_SCALE_TOPOLOGIES, SCALE_H800_TP8_DP4, SCALE_PCIE_TP8_DP2,
        SCALE_TP8, SCALE_TP8_DP2,
    };

    #[test]
    fn completes_every_request_on_every_topology() {
        for topo in ALL_SCALE_TOPOLOGIES {
            let sc = ScaleScenario::quick(topo);
            let rep = run_scale(&sc, Method::Flux).unwrap();
            assert_eq!(rep.completed, sc.n_requests(), "{}", topo.name);
            assert_eq!(rep.tokens, sc.n_requests() * 8, "quick gen = 8");
            assert!(rep.tokens_per_sec > 0.0);
            assert!(rep.ttft.p50 > 0.0);
            assert!(rep.latency.p50 >= rep.ttft.p50);
            assert!(rep.per_token.p50 > 0.0);
        }
    }

    #[test]
    fn default_path_is_bit_identical_to_pr2() {
        // THE compat contract of the workload refactor: the default
        // Poisson preset must reproduce the pre-workload coordinator's
        // timings to the last bit (pins generated by the validated
        // Python port of the PR-2 code). A drift here means the
        // refactor changed the PRNG draw order or the step costing.
        let pins = [
            (&SCALE_TP8, 1118032308.8980734f64, 881228300.1589197f64),
            (&SCALE_TP8_DP2, 1117549870.466751, 824933462.2074677),
            (&SCALE_PCIE_TP8_DP2, 3270362457.795217, 2903126006.4066467),
            (&SCALE_H800_TP8_DP4, 598347635.5413818, 326857533.4727859),
        ];
        for (topo, makespan, ttft_p99) in pins {
            let rep =
                run_scale(&ScaleScenario::quick(topo), Method::Flux)
                    .unwrap();
            assert_eq!(rep.makespan_ns, makespan, "{}", topo.name);
            assert_eq!(rep.ttft.p99, ttft_p99, "{}", topo.name);
        }
    }

    #[test]
    fn deterministic_across_reruns() {
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let a = run_scale(&sc, Method::Flux).unwrap();
        let b = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.ttft.p99, b.ttft.p99);
        assert_eq!(a.per_token.mean, b.per_token.mean);
        assert_eq!(a.slo, b.slo);
    }

    #[test]
    fn round_robin_router_balances_replicas() {
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let rep = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(rep.replicas.len(), 2);
        for r in &rep.replicas {
            assert_eq!(r.completed, sc.n_requests() / 2);
            assert!(r.prefill_batches > 0);
            assert!(r.decode_steps > 0);
            assert!(r.busy_ns > 0.0);
        }
    }

    #[test]
    fn flux_never_slower_than_decoupled_on_nvlink() {
        // The acceptance bar: on NVLink-intra topologies Flux must beat
        // (or match) the decoupled execution end to end.
        for topo in [&SCALE_TP8, &SCALE_TP8_DP2] {
            let sc = ScaleScenario::quick(topo);
            let cmp = compare_scale(&sc).unwrap();
            assert!(
                cmp.speedup() >= 1.0,
                "{}: speedup {}",
                topo.name,
                cmp.speedup()
            );
            assert!(cmp.latency_speedup() >= 1.0, "{}", topo.name);
        }
    }

    #[test]
    fn pcie_speedup_exceeds_nvlink_speedup() {
        // Fig. 16 shape: the communication-dominated PCIe cluster gains
        // the most from overlap.
        let nvl =
            compare_scale(&ScaleScenario::quick(&SCALE_TP8_DP2)).unwrap();
        let pcie =
            compare_scale(&ScaleScenario::quick(&SCALE_PCIE_TP8_DP2))
                .unwrap();
        assert!(
            pcie.speedup() > nvl.speedup(),
            "pcie {} nvl {}",
            pcie.speedup(),
            nvl.speedup()
        );
    }

    #[test]
    fn method_set_runs_match_the_pairwise_comparison() {
        // run_scale_methods is the uniform entry the experiment layer
        // iterates; the historical pairwise comparison must be exactly
        // its SERVE_SET projection.
        let sc = ScaleScenario::quick(&SCALE_TP8);
        let runs =
            run_scale_methods(&sc, &Method::SERVE_SET).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].method, Method::NonOverlap);
        assert_eq!(runs[1].method, Method::Flux);
        let cmp = compare_scale(&sc).unwrap();
        assert_eq!(cmp.decoupled.makespan_ns, runs[0].makespan_ns);
        assert_eq!(cmp.flux.makespan_ns, runs[1].makespan_ns);
        // from_runs needs both references.
        assert!(ScaleComparison::from_runs(&runs[..1]).is_none());
        // A wider set still projects to the same pair.
        let all = run_scale_methods(&sc, &Method::ALL).unwrap();
        let cmp2 = ScaleComparison::from_runs(&all).unwrap();
        assert_eq!(cmp2.speedup(), cmp.speedup());
    }

    #[test]
    fn overlap_efficiency_positive_for_flux_zero_for_decoupled() {
        let sc = ScaleScenario::quick(&SCALE_TP8);
        let fx = scale_overlap_efficiency(&sc, Method::Flux);
        let base = scale_overlap_efficiency(&sc, Method::NonOverlap);
        assert!(fx > 0.0 && fx <= 1.0, "flux eff {fx}");
        assert_eq!(base, 0.0);
    }

    #[test]
    fn dp2_outscales_dp1_in_throughput() {
        // Two replicas under the same per-replica load finish the
        // doubled workload at (near-)doubled throughput.
        let one = run_scale(&ScaleScenario::quick(&SCALE_TP8), Method::Flux)
            .unwrap();
        let two =
            run_scale(&ScaleScenario::quick(&SCALE_TP8_DP2), Method::Flux)
                .unwrap();
        assert!(
            two.tokens_per_sec > 1.5 * one.tokens_per_sec,
            "dp2 {} dp1 {}",
            two.tokens_per_sec,
            one.tokens_per_sec
        );
    }

    #[test]
    fn default_workload_carries_slo_accounting() {
        // The default preset defines SLOs, so the report carries the
        // goodput fields (quick tp8: 7 of 8 requests meet both).
        let rep =
            run_scale(&ScaleScenario::quick(&SCALE_TP8), Method::Flux)
                .unwrap();
        let slo = rep.slo.expect("default preset has SLOs");
        assert_eq!(slo.requests, 8);
        assert!(slo.met_both <= slo.met_ttft);
        assert!(slo.met_both <= slo.met_per_token);
        assert!(slo.goodput() > 0.0 && slo.goodput() <= 1.0);
    }

    #[test]
    fn closed_loop_workload_completes_and_spreads_prefills() {
        let wl = crate::workload::preset("closed-prefill", true).unwrap();
        let sc = ScaleScenario::with_workload(&SCALE_TP8_DP2, wl);
        let rep = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(rep.completed, sc.n_requests());
        // Think-gated arrivals rarely coincide, so prefills stay
        // narrow: port-calibrated, each replica runs one prefill per
        // request (6 of 6); assert the conservative half of that so
        // the band survives small preset retunes.
        for r in &rep.replicas {
            assert_eq!(r.completed, sc.workload.requests_per_replica);
            assert!(
                r.prefill_batches as usize * 2
                    >= sc.workload.requests_per_replica,
                "prefill batches {} for {} requests",
                r.prefill_batches,
                sc.workload.requests_per_replica
            );
        }
    }

    fn churn(
        topo: &'static ScaleTopology,
        method: Method,
        k: f64,
    ) -> ScaleReport {
        let spec = crate::faults::preset("replica-churn").unwrap();
        let tl = spec.expand(topo.dp, k);
        let sc = ScaleScenario::quick(topo);
        if tl.is_empty() {
            run_scale(&sc, method).unwrap()
        } else {
            run_scale_faulted(&sc, method, &tl).unwrap()
        }
    }

    fn goodput(rep: &ScaleReport) -> f64 {
        rep.slo.as_ref().expect("quick preset has SLOs").goodput()
    }

    #[test]
    fn empty_timeline_is_byte_identical_to_fault_free() {
        // The fault hook must cost nothing when unused: a zero-
        // intensity expansion takes the exact fault-free path.
        for topo in ALL_SCALE_TOPOLOGIES {
            let sc = ScaleScenario::quick(topo);
            let spec = crate::faults::preset("replica-churn").unwrap();
            let tl = spec.expand(topo.dp, 0.0);
            assert!(tl.is_empty());
            let base = run_scale(&sc, Method::Flux).unwrap();
            let faulted =
                run_scale_faulted(&sc, Method::Flux, &tl).unwrap();
            assert_eq!(base.makespan_ns, faulted.makespan_ns);
            assert_eq!(base.ttft.p99, faulted.ttft.p99);
            assert_eq!(base.per_token.mean, faulted.per_token.mean);
            assert_eq!(base.failed, 0);
            assert_eq!(faulted.failed, 0);
            assert_eq!(base.slo, faulted.slo);
        }
    }

    #[test]
    fn replica_churn_degrades_goodput_strictly_on_h800() {
        // The acceptance curve: on the 4-replica H800 cluster the
        // seeded arrival stream straddles both scaled downtimes
        // (restarts at 90ms and 150ms), so each intensity bump kills
        // strictly more goodput — for BOTH methods.
        for method in [Method::Flux, Method::NonOverlap] {
            let reps: Vec<ScaleReport> = [0.0, 0.5, 1.0]
                .iter()
                .map(|&k| churn(&SCALE_H800_TP8_DP4, method, k))
                .collect();
            for w in reps.windows(2) {
                assert!(
                    goodput(&w[0]) > goodput(&w[1]),
                    "{method:?}: goodput {} !> {}",
                    goodput(&w[0]),
                    goodput(&w[1])
                );
                assert!(w[0].failed < w[1].failed);
            }
            for rep in &reps {
                assert_eq!(rep.completed + rep.failed, 32);
            }
        }
    }

    #[test]
    fn replica_churn_degrades_goodput_strictly_on_nvlink_dp2() {
        let reps: Vec<ScaleReport> = [0.0, 0.5, 1.0]
            .iter()
            .map(|&k| churn(&SCALE_TP8_DP2, Method::Flux, k))
            .collect();
        for w in reps.windows(2) {
            assert!(goodput(&w[0]) > goodput(&w[1]));
            assert!(w[0].failed < w[1].failed);
        }
    }

    #[test]
    fn replica_churn_on_dp1_fails_everything_cleanly() {
        // One replica, arrivals all inside the first 33ms: the 30ms
        // kill eats the whole workload at any positive intensity.
        // This is the all-failed edge: empty percentile streams, zero
        // makespan, zero goodput — and clean conservation.
        let rep = churn(&SCALE_TP8, Method::Flux, 0.5);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.failed, 8);
        assert_eq!(rep.tokens, 0);
        assert_eq!(rep.makespan_ns, 0.0);
        assert_eq!(rep.tokens_per_sec, 0.0);
        assert_eq!(rep.ttft.n, 0);
        assert_eq!(goodput(&rep), 0.0);
        let slo = rep.slo.as_ref().unwrap();
        assert_eq!(slo.abandoned, 8, "failed requests are abandoned");
    }

    #[test]
    fn replica_churn_grows_failures_monotonically_on_pcie() {
        // PCIe's fault-free goodput is itself SLO-limited (queueing
        // blows the TTFT deadline), so goodput there is not a clean
        // monotone signal; the failure count is. Full intensity
        // spans every arrival: total loss.
        let reps: Vec<ScaleReport> = [0.0, 0.5, 1.0]
            .iter()
            .map(|&k| churn(&SCALE_PCIE_TP8_DP2, Method::Flux, k))
            .collect();
        for w in reps.windows(2) {
            assert!(w[0].failed < w[1].failed, "downtime grows with k");
        }
        for rep in &reps {
            assert_eq!(rep.completed + rep.failed, 16);
        }
        assert_eq!(reps[2].failed, 16, "full downtime spans all arrivals");
        assert_eq!(goodput(&reps[2]), 0.0);
    }

    #[test]
    fn straggler_storm_slows_steps_but_loses_nothing() {
        let spec = crate::faults::preset("straggler-storm").unwrap();
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let base = run_scale(&sc, Method::Flux).unwrap();
        let tl = spec.expand(sc.topo.dp, 1.0);
        let slow = run_scale_faulted(&sc, Method::Flux, &tl).unwrap();
        assert_eq!(slow.completed, sc.n_requests());
        assert_eq!(slow.failed, 0);
        assert!(
            slow.makespan_ns > base.makespan_ns,
            "inflated steps must stretch the makespan: {} !> {}",
            slow.makespan_ns,
            base.makespan_ns
        );
        assert!(goodput(&slow) <= goodput(&base));
    }

    #[test]
    fn elastic_resize_drains_then_rejoins() {
        use crate::faults::{FaultSpec, ResizeSpec};
        let spec = FaultSpec {
            name: "resize-test".into(),
            resizes: vec![ResizeSpec {
                at_ns: 30.0e6,
                target_dp: 1,
                dur_ns: 60.0e6,
            }],
            ..FaultSpec::none()
        };
        spec.validate().unwrap();
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let tl = spec.expand(sc.topo.dp, 1.0);
        let rep = run_scale_faulted(&sc, Method::Flux, &tl).unwrap();
        // Replica 1 is drained at 30ms (losing its in-system work),
        // sits out the [30ms, 90ms) window while replica 0 absorbs
        // the traffic, then rejoins for the post-90ms arrivals.
        assert!(rep.failed >= 1, "the resize must drain something");
        assert_eq!(rep.completed + rep.failed, sc.n_requests());
        for r in &rep.replicas {
            assert!(r.completed > 0, "both replicas serve traffic");
        }
        assert!(goodput(&rep) > 0.0);
    }

    #[test]
    fn shared_step_cache_is_bit_equal_to_fresh_caches() {
        // Sharing one StepCostCache across a whole method set must be
        // invisible in the results: the cost functions are pure and
        // the keys carry the method.
        for topo in [&SCALE_TP8_DP2, &SCALE_H800_TP8_DP4] {
            let sc = ScaleScenario::quick(topo);
            let mut cache = StepCostCache::new();
            for method in Method::ALL {
                let fresh = run_scale(&sc, method).unwrap();
                let shared =
                    run_scale_cached(&sc, method, &mut cache).unwrap();
                assert_eq!(fresh.makespan_ns, shared.makespan_ns);
                assert_eq!(fresh.ttft.p99, shared.ttft.p99);
                assert_eq!(fresh.per_token.mean, shared.per_token.mean);
                assert_eq!(fresh.latency.p50, shared.latency.p50);
                assert_eq!(fresh.slo, shared.slo);
            }
            assert!(!cache.is_empty());
        }
    }

    #[test]
    fn sketch_mode_is_additive_and_bucket_bracketed() {
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let exact = run_scale(&sc, Method::Flux).unwrap();
        assert!(exact.ttft_sketch.is_none(), "default stays exact");
        assert!(exact.per_token_sketch.is_none());
        assert!(exact.latency_sketch.is_none());

        let sk_sc =
            sc.clone().with_percentiles(PercentileMode::Sketch);
        let rep = run_scale(&sk_sc, Method::Flux).unwrap();
        // The exact fields are untouched by the mode switch: the
        // PR-2 pins hold bit-for-bit in sketch mode too.
        assert_eq!(rep.makespan_ns, exact.makespan_ns);
        assert_eq!(rep.ttft.p99, exact.ttft.p99);
        assert_eq!(rep.latency.p50, exact.latency.p50);

        // Scalar sketch stats are exact; percentiles land inside the
        // bucket holding the exact order statistic and stay ordered.
        let pairs = [
            (rep.ttft_sketch.as_ref().unwrap(), &rep.ttft),
            (rep.per_token_sketch.as_ref().unwrap(), &rep.per_token),
            (rep.latency_sketch.as_ref().unwrap(), &rep.latency),
        ];
        for (sk, ex) in pairs {
            assert_eq!(sk.n, ex.n);
            assert_eq!(sk.min, ex.min);
            assert_eq!(sk.max, ex.max);
            assert!((sk.mean - ex.mean).abs() <= 1e-9 * ex.mean.abs());
            assert!(sk.min <= sk.p50 && sk.p50 <= sk.p95);
            assert!(sk.p95 <= sk.p99 && sk.p99 <= sk.max);
            let idx = |x: f64| {
                obs::LATENCY_BOUNDS_NS.partition_point(|&b| b < x)
            };
            let mut probe = Sketch::new(&obs::LATENCY_BOUNDS_NS);
            probe.observe(ex.min);
            probe.observe(ex.max);
            for (sp, ep) in
                [(sk.p50, ex.p50), (sk.p95, ex.p95), (sk.p99, ex.p99)]
            {
                // The sketch estimate sits in the bucket of the exact
                // percentile's lower order statistic, so it can never
                // land in a HIGHER bucket than the exact value; when
                // both share a bucket it is within one bucket width.
                assert!(
                    idx(sp) <= idx(ep),
                    "sketch {sp} above exact {ep}'s bucket"
                );
                if idx(sp) == idx(ep) {
                    let (lo, hi) = probe.bucket_of(ep);
                    assert!(
                        (sp - ep).abs() <= (hi - lo).abs(),
                        "sketch {sp} vs exact {ep} in [{lo}, {hi}]"
                    );
                }
            }
        }
    }

    #[test]
    fn fleet_pool_completes_quick_traffic() {
        let topo = ScaleTopology::fleet(8, "nvlink").unwrap();
        let sc = ScaleScenario::quick(topo);
        let rep = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(rep.completed, sc.n_requests());
        assert_eq!(rep.replicas.len(), 8);
        for r in &rep.replicas {
            assert_eq!(r.completed, sc.workload.requests_per_replica);
        }
    }

    #[test]
    fn trace_capture_is_deterministic_and_shaped() {
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let mut a = Trace::new();
        let mut b = Trace::new();
        compare_scale_traced(&sc, &mut a).unwrap();
        compare_scale_traced(&sc, &mut b).unwrap();
        let text = a.to_json().to_string();
        assert_eq!(text, b.to_json().to_string(), "trace must replay");
        let doc = Json::parse(&text).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 methods x 2 replicas named + arrivals + steps.
        assert!(evs.len() > 4 + 2 * sc.n_requests(), "{}", evs.len());
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
            .map(|e| {
                e.get("args").unwrap().get("name").unwrap().as_str().unwrap()
            })
            .collect();
        assert!(names.contains(&"Flux/replica0"));
        assert!(names.contains(&"non-overlap/replica1"));
    }
}
