//! Multi-node TP×DP serving-at-scale coordinator.
//!
//! Scales the single-TP-group serving simulation up to a whole cluster:
//! `topo.dp` independent TP groups (replicas, Megatron-style TP within a
//! node / replicas across nodes) are driven through ONE shared DES event
//! queue ([`crate::sim::engine::EventQueue`]). Open-loop Poisson
//! arrivals hit a round-robin router; each replica runs its own
//! prefill-priority continuous batcher ([`Batcher`]) against its own
//! paged [`KvCacheManager`], and every scheduler step is timed by the
//! chosen overlap strategy ([`Method`]): `Method::Flux` is the fused
//! fine-grained kernel, `Method::NonOverlap` the decoupled
//! GEMM-then-NCCL execution the paper compares against (vLLM /
//! Megatron-LM serving).
//!
//! The router is deliberately round-robin rather than least-loaded: the
//! request→replica assignment is then identical for every `Method`, so a
//! Flux-vs-decoupled comparison measures execution speed, never routing
//! luck. Replicas never share links (`ScaleTopology::validate` pins TP
//! inside a node), so the only coupling between them is the shared
//! arrival process — which is what makes tail latency (p99 TTFT) a
//! cluster-level, not replica-level, quantity.
//!
//! Everything is seeded and deterministic: the same
//! [`ScaleScenario`] produces byte-identical reports across reruns,
//! which is what lets CI diff the `flux simulate --scale --json` output.

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::cost::arch::ScaleTopology;
use crate::model::analysis::{layer_attention_extra_ns, layer_fwd_ops};
use crate::model::configs::TransformerConfig;
use crate::parallel::Method;
use crate::serving::batcher::{Batcher, BatcherConfig, Work};
use crate::serving::kvcache::KvCacheManager;
use crate::serving::request::Request;
use crate::serving::simulate::{
    decode_cache_len, decode_step_ns, prefill_ns,
};
use crate::sim::engine::EventQueue;
use crate::util::prng::Rng;
use crate::util::stats::Summary;

/// One serving-at-scale experiment: a topology, a model and an open-loop
/// workload.
#[derive(Clone, Copy, Debug)]
pub struct ScaleScenario {
    pub topo: &'static ScaleTopology,
    pub model: &'static TransformerConfig,
    /// Total requests across the cluster (round-robined over replicas).
    pub n_requests: usize,
    /// Mean Poisson inter-arrival time for the whole cluster, ns.
    pub arrival_mean_ns: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub max_prefill_batch: usize,
    pub max_decode_batch: usize,
    /// KV pool per replica, in sequences' worth of blocks (the decode
    /// concurrency cap).
    pub kv_seqs: usize,
    pub seed: u64,
}

impl ScaleScenario {
    /// CI-sized scenario: small request count, short generations.
    pub fn quick(topo: &'static ScaleTopology) -> ScaleScenario {
        ScaleScenario {
            topo,
            model: &crate::model::configs::GPT3_175B,
            n_requests: 8 * topo.dp,
            // Saturating load: arrivals outpace one replica's service
            // rate so queueing (and therefore the overlap speedup) is
            // visible in the latency percentiles.
            arrival_mean_ns: 20.0e6 / topo.dp as f64,
            prompt_len: 512,
            gen_len: 8,
            max_prefill_batch: 4,
            max_decode_batch: 8,
            kv_seqs: 16,
            seed: 17,
        }
    }

    /// Paper-shaped scenario: more requests, longer generations.
    pub fn full(topo: &'static ScaleTopology) -> ScaleScenario {
        ScaleScenario {
            n_requests: 24 * topo.dp,
            gen_len: 16,
            ..ScaleScenario::quick(topo)
        }
    }
}

/// Per-replica accounting for the report.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub completed: usize,
    pub tokens: usize,
    pub prefill_batches: u64,
    pub decode_steps: u64,
    /// Time this replica spent executing steps, ns.
    pub busy_ns: f64,
}

/// Cluster-level result of one (scenario, method) run.
#[derive(Clone, Debug)]
pub struct ScaleReport {
    pub method: Method,
    pub completed: usize,
    pub tokens: usize,
    pub makespan_ns: f64,
    /// Time to first token (arrival → prefill done), per request.
    pub ttft: Summary,
    /// Mean inter-token decode latency, per request.
    pub per_token: Summary,
    /// End-to-end latency, per request.
    pub latency: Summary,
    pub tokens_per_sec: f64,
    /// Step-level overlap efficiency of this method at the prefill
    /// reference batch (Eq. 2 applied at the model level).
    pub overlap_eff: f64,
    pub replicas: Vec<ReplicaReport>,
}

/// The communication-free lower bound of a prefill step: every TP op at
/// its monolithic-GEMM time (Eq. 1's `GEMM_non-split`), attention
/// included. Used as the denominator of the model-level Eq. 2.
pub fn ideal_prefill_ns(
    topo: &ScaleTopology,
    model: &TransformerConfig,
    batch: usize,
    seq: usize,
) -> f64 {
    let m = batch * seq;
    let mut t = 0.0;
    for p in layer_fwd_ops(model, m, topo.tp) {
        t += p.gemm_nonsplit_ns(topo.cluster);
    }
    t += layer_attention_extra_ns(topo.cluster, model, m, seq, topo.tp);
    t * model.n_layers as f64
}

/// Model-level overlap efficiency (Eq. 2): what fraction of the
/// decoupled execution's exposed communication time the method hides,
/// measured at the scenario's reference prefill batch.
pub fn scale_overlap_efficiency(sc: &ScaleScenario, method: Method) -> f64 {
    let base = prefill_ns(
        sc.topo.cluster,
        sc.model,
        sc.max_prefill_batch,
        sc.prompt_len,
        sc.topo.tp,
        Method::NonOverlap,
        sc.seed,
    );
    let ideal = ideal_prefill_ns(
        sc.topo, sc.model, sc.max_prefill_batch, sc.prompt_len,
    );
    let t = prefill_ns(
        sc.topo.cluster,
        sc.model,
        sc.max_prefill_batch,
        sc.prompt_len,
        sc.topo.tp,
        method,
        sc.seed,
    );
    let exposed = base - ideal;
    if exposed <= 0.0 {
        return 0.0;
    }
    (base - t) / exposed
}

/// One replica's runtime state inside the coordinator.
struct Replica {
    batcher: Batcher,
    kv: KvCacheManager,
    /// Ids of the batch currently executing (empty when idle).
    in_flight: Vec<u64>,
    in_flight_is_prefill: bool,
    busy_ns: f64,
}

/// DES events. Arrivals carry the request index; step completions the
/// replica index.
enum Ev {
    Arrive(usize),
    StepDone(usize),
}

/// Run one (scenario, method) serving simulation to completion.
pub fn run_scale(sc: &ScaleScenario, method: Method) -> Result<ScaleReport> {
    sc.topo.validate()?;
    ensure!(sc.n_requests > 0, "empty workload");
    ensure!(sc.gen_len >= 1, "gen_len must be >= 1");
    let dp = sc.topo.dp;
    let block_tokens = 64;
    let blocks_per_seq =
        (sc.prompt_len + sc.gen_len).div_ceil(block_tokens) + 1;

    let mut replicas: Vec<Replica> = (0..dp)
        .map(|_| Replica {
            batcher: Batcher::new(BatcherConfig {
                max_prefill_batch: sc.max_prefill_batch,
                max_decode_batch: sc.max_decode_batch,
                max_prompt: sc.prompt_len,
                max_seq: sc.prompt_len + sc.gen_len + 1,
            }),
            kv: KvCacheManager::new(sc.kv_seqs * blocks_per_seq, block_tokens),
            in_flight: Vec::new(),
            in_flight_is_prefill: false,
            busy_ns: 0.0,
        })
        .collect();

    // Step-time cache: (replica-phase, batch) → ns. Identical across
    // replicas (same spec/model/method/seed), so one cluster-wide map.
    let mut step_cache: BTreeMap<(bool, usize), f64> = BTreeMap::new();
    let avg_cache_len = decode_cache_len(sc.prompt_len, sc.gen_len);
    let mut step_ns = |is_prefill: bool, batch: usize| -> f64 {
        *step_cache.entry((is_prefill, batch)).or_insert_with(|| {
            if is_prefill {
                prefill_ns(
                    sc.topo.cluster,
                    sc.model,
                    batch,
                    sc.prompt_len,
                    sc.topo.tp,
                    method,
                    sc.seed,
                )
            } else {
                decode_step_ns(
                    sc.topo.cluster,
                    sc.model,
                    batch,
                    avg_cache_len,
                    sc.topo.tp,
                    method,
                    sc.seed,
                )
            }
        })
    };

    // Open-loop Poisson arrivals, drawn up front so the arrival process
    // is identical for every method under the same seed.
    let mut q = EventQueue::new();
    let mut rng = Rng::new(sc.seed);
    let mut t_arr = 0.0;
    for i in 0..sc.n_requests {
        t_arr += rng.exponential(sc.arrival_mean_ns);
        q.schedule(t_arr, Ev::Arrive(i));
    }

    while let Some((now, ev)) = q.next() {
        let r = match ev {
            Ev::Arrive(i) => {
                // Round-robin router: method-independent assignment.
                let r = i % dp;
                let rep = &mut replicas[r];
                rep.batcher.submit(Request::new(
                    i as u64,
                    now,
                    vec![1; sc.prompt_len],
                    sc.gen_len,
                ));
                r
            }
            Ev::StepDone(r) => {
                let rep = &mut replicas[r];
                let ids = std::mem::take(&mut rep.in_flight);
                if rep.in_flight_is_prefill {
                    // Prefill emits each sequence's first token.
                    for &id in &ids {
                        rep.batcher.get_mut(id).prefill_done_ns = Some(now);
                    }
                }
                let toks = vec![0i32; ids.len()];
                rep.batcher
                    .complete_decode(&ids, &toks, &mut rep.kv, now)
                    .with_context(|| format!("replica {r} step at {now}"))?;
                r
            }
        };
        // Try to start the next step on the touched replica.
        let rep = &mut replicas[r];
        if rep.in_flight.is_empty() {
            match rep.batcher.next_work(&mut rep.kv)? {
                Work::Prefill(ids) => {
                    let t = step_ns(true, ids.len());
                    rep.in_flight = ids;
                    rep.in_flight_is_prefill = true;
                    rep.busy_ns += t;
                    q.schedule(now + t, Ev::StepDone(r));
                }
                Work::Decode(ids) => {
                    let t = step_ns(false, ids.len());
                    rep.in_flight = ids;
                    rep.in_flight_is_prefill = false;
                    rep.busy_ns += t;
                    q.schedule(now + t, Ev::StepDone(r));
                }
                Work::Idle => {}
            }
        }
    }

    // All arrivals were scheduled and every generation is finite, so a
    // drained queue means a drained cluster.
    for (r, rep) in replicas.iter().enumerate() {
        ensure!(
            rep.batcher.all_done(),
            "replica {r} stalled with work left (KV pool too small?)"
        );
    }

    let mut ttft = Vec::with_capacity(sc.n_requests);
    let mut per_token = Vec::with_capacity(sc.n_requests);
    let mut latency = Vec::with_capacity(sc.n_requests);
    let mut makespan: f64 = 0.0;
    for rep in &replicas {
        for req in &rep.batcher.requests {
            let t = req
                .ttft_ns()
                .context("request finished without a prefill timestamp")?;
            let l = req.latency_ns().context("request not finished")?;
            ttft.push(t);
            latency.push(l);
            // First token lands with prefill; the rest are decode steps.
            let decode_tokens = (req.generated.len() - 1).max(1);
            per_token.push((l - t) / decode_tokens as f64);
            makespan = makespan.max(req.finished_ns.unwrap());
        }
    }

    let replica_reports: Vec<ReplicaReport> = replicas
        .iter()
        .map(|rep| ReplicaReport {
            completed: rep
                .batcher
                .requests
                .iter()
                .filter(|r| r.finished_ns.is_some())
                .count(),
            tokens: rep
                .batcher
                .requests
                .iter()
                .map(|r| r.generated.len())
                .sum(),
            prefill_batches: rep.batcher.prefill_batches,
            decode_steps: rep.batcher.decode_steps,
            busy_ns: rep.busy_ns,
        })
        .collect();

    let tokens: usize = replica_reports.iter().map(|r| r.tokens).sum();
    Ok(ScaleReport {
        method,
        completed: replica_reports.iter().map(|r| r.completed).sum(),
        tokens,
        makespan_ns: makespan,
        ttft: Summary::of(&ttft),
        per_token: Summary::of(&per_token),
        latency: Summary::of(&latency),
        tokens_per_sec: tokens as f64 / (makespan * 1e-9),
        overlap_eff: scale_overlap_efficiency(sc, method),
        replicas: replica_reports,
    })
}

/// The Fig. 16/17-shaped comparison: the same scenario under the
/// decoupled (vLLM-style) and Flux executions.
pub struct ScaleComparison {
    pub decoupled: ScaleReport,
    pub flux: ScaleReport,
}

impl ScaleComparison {
    /// Throughput speedup of Flux over the decoupled execution.
    pub fn speedup(&self) -> f64 {
        self.decoupled.makespan_ns / self.flux.makespan_ns
    }

    /// Mean end-to-end latency speedup.
    pub fn latency_speedup(&self) -> f64 {
        self.decoupled.latency.mean / self.flux.latency.mean
    }
}

pub fn compare_scale(sc: &ScaleScenario) -> Result<ScaleComparison> {
    Ok(ScaleComparison {
        decoupled: run_scale(sc, Method::NonOverlap)?,
        flux: run_scale(sc, Method::Flux)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{
        ALL_SCALE_TOPOLOGIES, SCALE_PCIE_TP8_DP2, SCALE_TP8, SCALE_TP8_DP2,
    };

    #[test]
    fn completes_every_request_on_every_topology() {
        for topo in ALL_SCALE_TOPOLOGIES {
            let sc = ScaleScenario::quick(topo);
            let rep = run_scale(&sc, Method::Flux).unwrap();
            assert_eq!(rep.completed, sc.n_requests, "{}", topo.name);
            assert_eq!(rep.tokens, sc.n_requests * sc.gen_len);
            assert!(rep.tokens_per_sec > 0.0);
            assert!(rep.ttft.p50 > 0.0);
            assert!(rep.latency.p50 >= rep.ttft.p50);
            assert!(rep.per_token.p50 > 0.0);
        }
    }

    #[test]
    fn deterministic_across_reruns() {
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let a = run_scale(&sc, Method::Flux).unwrap();
        let b = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.ttft.p99, b.ttft.p99);
        assert_eq!(a.per_token.mean, b.per_token.mean);
    }

    #[test]
    fn round_robin_router_balances_replicas() {
        let sc = ScaleScenario::quick(&SCALE_TP8_DP2);
        let rep = run_scale(&sc, Method::Flux).unwrap();
        assert_eq!(rep.replicas.len(), 2);
        for r in &rep.replicas {
            assert_eq!(r.completed, sc.n_requests / 2);
            assert!(r.prefill_batches > 0);
            assert!(r.decode_steps > 0);
            assert!(r.busy_ns > 0.0);
        }
    }

    #[test]
    fn flux_never_slower_than_decoupled_on_nvlink() {
        // The acceptance bar: on NVLink-intra topologies Flux must beat
        // (or match) the decoupled execution end to end.
        for topo in [&SCALE_TP8, &SCALE_TP8_DP2] {
            let sc = ScaleScenario::quick(topo);
            let cmp = compare_scale(&sc).unwrap();
            assert!(
                cmp.speedup() >= 1.0,
                "{}: speedup {}",
                topo.name,
                cmp.speedup()
            );
            assert!(cmp.latency_speedup() >= 1.0, "{}", topo.name);
        }
    }

    #[test]
    fn pcie_speedup_exceeds_nvlink_speedup() {
        // Fig. 16 shape: the communication-dominated PCIe cluster gains
        // the most from overlap.
        let nvl =
            compare_scale(&ScaleScenario::quick(&SCALE_TP8_DP2)).unwrap();
        let pcie =
            compare_scale(&ScaleScenario::quick(&SCALE_PCIE_TP8_DP2))
                .unwrap();
        assert!(
            pcie.speedup() > nvl.speedup(),
            "pcie {} nvl {}",
            pcie.speedup(),
            nvl.speedup()
        );
    }

    #[test]
    fn overlap_efficiency_positive_for_flux_zero_for_decoupled() {
        let sc = ScaleScenario::quick(&SCALE_TP8);
        let fx = scale_overlap_efficiency(&sc, Method::Flux);
        let base = scale_overlap_efficiency(&sc, Method::NonOverlap);
        assert!(fx > 0.0 && fx <= 1.0, "flux eff {fx}");
        assert_eq!(base, 0.0);
    }

    #[test]
    fn dp2_outscales_dp1_in_throughput() {
        // Two replicas under the same per-replica load finish the
        // doubled workload at (near-)doubled throughput.
        let one = run_scale(&ScaleScenario::quick(&SCALE_TP8), Method::Flux)
            .unwrap();
        let two =
            run_scale(&ScaleScenario::quick(&SCALE_TP8_DP2), Method::Flux)
                .unwrap();
        assert!(
            two.tokens_per_sec > 1.5 * one.tokens_per_sec,
            "dp2 {} dp1 {}",
            two.tokens_per_sec,
            one.tokens_per_sec
        );
    }
}
