//! Continuous batcher: the request-level scheduler of the serving
//! coordinator (vLLM-style iteration-level scheduling).
//!
//! Policy: prefill-priority continuous batching. Each scheduler tick
//! produces either one PREFILL batch (queued requests, up to
//! `max_prefill_batch`, admitted only if the KV manager has blocks) or
//! one DECODE step over all running sequences (up to `max_decode_batch`;
//! beyond that, round-robin chunks). This is exactly the shape of the
//! paper's inference evaluation: prefill batches of 8 x 2048 tokens,
//! decode batches of 64/512 (Fig. 16/17).

use std::collections::VecDeque;

use anyhow::Result;

use crate::serving::kvcache::KvCacheManager;
use crate::serving::request::{Request, RequestState};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_prefill_batch: usize,
    pub max_decode_batch: usize,
    /// Cap on prompt length (artifact static shape at the tiny scale).
    pub max_prompt: usize,
    /// Cap on total sequence length.
    pub max_seq: usize,
    /// Token budget per prefill batch (vLLM's max_num_batched_tokens):
    /// admission stops before the summed prompt lengths exceed it,
    /// except that a batch always takes at least one request. The
    /// default never binds; variable-length workloads set it so one
    /// long-context prompt does not drag a whole padded batch along.
    pub max_prefill_tokens: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_prefill_batch: 4,
            max_decode_batch: 4,
            max_prompt: 64,
            max_seq: 128,
            max_prefill_tokens: usize::MAX,
        }
    }
}

/// What the engine should run next.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Work {
    /// Prefill these request ids together.
    Prefill(Vec<u64>),
    /// One decode step for these request ids.
    Decode(Vec<u64>),
    /// Nothing runnable (queue empty / all finished).
    Idle,
}

#[derive(Debug)]
pub struct Batcher {
    pub cfg: BatcherConfig,
    queue: VecDeque<u64>,
    running: Vec<u64>,
    pub requests: Vec<Request>,
    /// Scheduling decisions made (reporting).
    pub prefill_batches: u64,
    pub decode_steps: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            queue: VecDeque::new(),
            running: Vec::new(),
            requests: Vec::new(),
            prefill_batches: 0,
            decode_steps: 0,
        }
    }

    pub fn submit(&mut self, req: Request) -> u64 {
        assert!(
            req.prompt.len() <= self.cfg.max_prompt,
            "prompt {} exceeds max {}",
            req.prompt.len(),
            self.cfg.max_prompt
        );
        let id = req.id;
        // Hard assert (release builds too): a duplicate id would later
        // make the KV manager reject an admission mid-tick.
        assert!(
            self.requests.iter().all(|r| r.id != id),
            "duplicate request id {id}"
        );
        self.requests.push(req);
        self.queue.push_back(id);
        id
    }

    pub fn get(&self, id: u64) -> &Request {
        self.requests.iter().find(|r| r.id == id).unwrap()
    }

    pub fn get_mut(&mut self, id: u64) -> &mut Request {
        self.requests.iter_mut().find(|r| r.id == id).unwrap()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn all_done(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    /// Requests submitted but not yet finished (queued + running) —
    /// the load signal least-outstanding routing balances on.
    pub fn outstanding(&self) -> usize {
        self.queue.len() + self.running.len()
    }

    /// Pick the next work item. Prefill-priority: drain the admission
    /// queue whenever KV blocks allow; otherwise decode.
    ///
    /// A KV-manager rejection mid-tick (after `can_admit` said yes — a
    /// KV invariant violation, e.g. the id is already resident) rolls
    /// the whole tick back before surfacing the error: every request
    /// admitted earlier in the tick is released and returned to the
    /// queue in its original position. No queue slot is lost, no block
    /// leaks, and no request can reach decode without its prefill
    /// having been returned as work.
    pub fn next_work(&mut self, kv: &mut KvCacheManager) -> Result<Work> {
        // Admit as many queued requests as fit (up to the batch cap).
        // Admission reserves the request's whole generation budget
        // (prompt + max_new_tokens, capped by max_seq): with no
        // preemption path, reserving only the prompt would let admitted
        // sequences jointly over-commit the pool and OOM mid-decode.
        let mut batch = Vec::new();
        let mut batch_tokens = 0usize;
        let mut admit_err = None;
        while batch.len() < self.cfg.max_prefill_batch {
            let Some(&id) = self.queue.front() else { break };
            let req = self.get(id);
            let len = req.prompt.len();
            if !batch.is_empty()
                && batch_tokens + len > self.cfg.max_prefill_tokens
            {
                break; // token budget: leave the rest for the next tick
            }
            let budget =
                (len + req.max_new_tokens).min(self.cfg.max_seq).max(len);
            if !kv.can_admit(budget) {
                break; // backpressure: wait for blocks to free
            }
            if let Err(e) = kv.admit_with_budget(id, len, budget) {
                admit_err = Some(e.context(format!("admitting request {id}")));
                break;
            }
            self.queue.pop_front();
            self.get_mut(id).state = RequestState::Decoding;
            self.running.push(id);
            batch.push(id);
            batch_tokens += len;
        }
        if let Some(e) = admit_err {
            // Roll back this tick's admissions (reverse order restores
            // the original queue order in front of the failing id).
            for &id in batch.iter().rev() {
                kv.release(id)?;
                self.get_mut(id).state = RequestState::Queued;
                self.running.retain(|x| *x != id);
                self.queue.push_front(id);
            }
            return Err(e);
        }
        if !batch.is_empty() {
            self.prefill_batches += 1;
            return Ok(Work::Prefill(batch));
        }
        if !self.running.is_empty() {
            let step: Vec<u64> = self
                .running
                .iter()
                .copied()
                .take(self.cfg.max_decode_batch)
                .collect();
            self.decode_steps += 1;
            return Ok(Work::Decode(step));
        }
        Ok(Work::Idle)
    }

    /// Kill-path teardown: release every running request's KV blocks,
    /// mark every unfinished request [`RequestState::Failed`], and
    /// clear the queue and running set. Returns the drained ids in
    /// queue-then-running order so the coordinator can attribute the
    /// abandonment to the fault (and reissue closed-loop users).
    /// Queued requests hold no blocks, so only running ids release.
    pub fn drain(&mut self, kv: &mut KvCacheManager) -> Result<Vec<u64>> {
        let mut drained: Vec<u64> = self.queue.iter().copied().collect();
        for &id in &self.running {
            kv.release(id)?;
            drained.push(id);
        }
        self.queue.clear();
        self.running.clear();
        for &id in &drained {
            self.get_mut(id).state = RequestState::Failed;
        }
        Ok(drained)
    }

    /// Record one generated token for each id; retire finished requests
    /// (freeing KV) at `now`.
    pub fn complete_decode(
        &mut self,
        ids: &[u64],
        tokens: &[i32],
        kv: &mut KvCacheManager,
        now: f64,
    ) -> Result<Vec<u64>> {
        assert_eq!(ids.len(), tokens.len());
        let mut finished = Vec::new();
        for (&id, &tok) in ids.iter().zip(tokens) {
            kv.append_token(id)?;
            let cfg_max_seq = self.cfg.max_seq;
            let r = self.get_mut(id);
            r.generated.push(tok);
            if r.is_done() || r.total_len() >= cfg_max_seq {
                r.state = RequestState::Finished;
                r.finished_ns = Some(now);
                finished.push(id);
            }
        }
        for id in &finished {
            kv.release(*id)?;
            self.running.retain(|x| x != id);
        }
        // Fairness: rotate so decode chunks round-robin over running.
        if self.running.len() > self.cfg.max_decode_batch {
            let n = self.cfg.max_decode_batch.min(self.running.len());
            self.running.rotate_left(n);
        }
        Ok(finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, new: usize) -> Request {
        Request::new(id, 0.0, vec![1; prompt_len], new)
    }

    fn setup() -> (Batcher, KvCacheManager) {
        (Batcher::new(BatcherConfig::default()),
         KvCacheManager::new(32, 16))
    }

    #[test]
    fn prefill_has_priority_then_decode() {
        let (mut b, mut kv) = setup();
        b.submit(req(0, 10, 2));
        b.submit(req(1, 10, 2));
        assert_eq!(
            b.next_work(&mut kv).unwrap(),
            Work::Prefill(vec![0, 1])
        );
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Decode(vec![0, 1]));
    }

    #[test]
    fn prefill_batch_caps_at_config() {
        let (mut b, mut kv) = setup();
        for i in 0..6 {
            b.submit(req(i, 4, 1));
        }
        match b.next_work(&mut kv).unwrap() {
            Work::Prefill(ids) => assert_eq!(ids.len(), 4),
            w => panic!("expected prefill, got {w:?}"),
        }
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn kv_backpressure_blocks_admission() {
        let mut b = Batcher::new(BatcherConfig::default());
        let mut kv = KvCacheManager::new(3, 16); // tiny pool
        b.submit(req(0, 40, 1)); // needs all 3 blocks
        b.submit(req(1, 16, 1));
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![0]));
        // Request 1 cannot be admitted: decode instead.
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Decode(vec![0]));
        // Finish 0 -> blocks free -> 1 admits.
        let fin = b
            .complete_decode(&[0], &[9], &mut kv, 1.0)
            .unwrap();
        assert_eq!(fin, vec![0]);
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![1]));
    }

    #[test]
    fn finished_requests_free_blocks_and_leave_running() {
        let (mut b, mut kv) = setup();
        b.submit(req(0, 8, 1));
        b.next_work(&mut kv).unwrap();
        let fin = b.complete_decode(&[0], &[5], &mut kv, 2.0).unwrap();
        assert_eq!(fin, vec![0]);
        assert_eq!(b.running(), 0);
        assert!(b.all_done());
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(b.get(0).generated, vec![5]);
        assert_eq!(b.get(0).finished_ns, Some(2.0));
    }

    #[test]
    fn decode_round_robins_past_the_cap() {
        let mut b = Batcher::new(BatcherConfig {
            max_prefill_batch: 8,
            max_decode_batch: 2,
            ..Default::default()
        });
        let mut kv = KvCacheManager::new(64, 16);
        for i in 0..4 {
            b.submit(req(i, 4, 10));
        }
        b.next_work(&mut kv).unwrap(); // prefill all 4
        let w1 = b.next_work(&mut kv).unwrap();
        assert_eq!(w1, Work::Decode(vec![0, 1]));
        b.complete_decode(&[0, 1], &[1, 1], &mut kv, 1.0).unwrap();
        let w2 = b.next_work(&mut kv).unwrap();
        assert_eq!(w2, Work::Decode(vec![2, 3]), "round robin");
    }

    #[test]
    fn admission_reserves_generation_budget() {
        // 4 blocks of 16 tokens. Two requests, each prompt 16 + up to
        // 48 new tokens => budget 64 tokens = 4 blocks. Reserving only
        // the prompt (1 block) would admit both and OOM mid-decode with
        // no preemption path; budget admission serializes them and both
        // finish.
        let mut b = Batcher::new(BatcherConfig {
            max_prefill_batch: 4,
            max_decode_batch: 4,
            max_prompt: 64,
            max_seq: 64,
            ..Default::default()
        });
        let mut kv = KvCacheManager::new(4, 16);
        b.submit(req(0, 16, 48));
        b.submit(req(1, 16, 48));
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![0]));
        let mut prefills = Vec::new();
        let mut steps = 0;
        loop {
            match b.next_work(&mut kv).unwrap() {
                Work::Decode(ids) => {
                    let toks: Vec<i32> = ids.iter().map(|_| 1).collect();
                    b.complete_decode(&ids, &toks, &mut kv, 0.0).unwrap();
                }
                Work::Prefill(ids) => prefills.push(ids),
                Work::Idle => break,
            }
            steps += 1;
            assert!(steps < 500, "did not converge");
        }
        assert_eq!(prefills, vec![vec![1]], "1 admits only after 0 frees");
        assert!(b.all_done());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefill_token_budget_splits_batches() {
        // Cap 100 tokens: a 60-token prompt and a 50-token prompt do
        // not share a batch, but a lone over-budget prompt still runs
        // (the batch always takes at least one request).
        let mut b = Batcher::new(BatcherConfig {
            max_prefill_batch: 4,
            max_prompt: 256,
            max_seq: 512,
            max_prefill_tokens: 100,
            ..Default::default()
        });
        let mut kv = KvCacheManager::new(256, 16);
        b.submit(req(0, 60, 1));
        b.submit(req(1, 50, 1));
        b.submit(req(2, 200, 1)); // alone and over budget
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![0]));
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![1]));
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![2]));
    }

    #[test]
    fn default_token_budget_never_binds() {
        // The PR-2 compat contract: with the default (unbounded)
        // budget, batching is governed by max_prefill_batch alone.
        let (mut b, mut kv) = setup();
        for i in 0..4 {
            b.submit(req(i, 60, 1));
        }
        match b.next_work(&mut kv).unwrap() {
            Work::Prefill(ids) => assert_eq!(ids.len(), 4),
            w => panic!("expected full prefill, got {w:?}"),
        }
    }

    #[test]
    fn outstanding_counts_queued_plus_running() {
        let (mut b, mut kv) = setup();
        assert_eq!(b.outstanding(), 0);
        b.submit(req(0, 8, 2));
        b.submit(req(1, 8, 2));
        assert_eq!(b.outstanding(), 2);
        b.next_work(&mut kv).unwrap(); // both admitted to running
        assert_eq!(b.outstanding(), 2);
        b.complete_decode(&[0, 1], &[1, 1], &mut kv, 1.0).unwrap();
        b.complete_decode(&[0, 1], &[1, 1], &mut kv, 2.0).unwrap();
        assert_eq!(b.outstanding(), 0);
    }

    #[test]
    fn drain_releases_blocks_and_fails_unfinished() {
        let (mut b, mut kv) = setup();
        for i in 0..3 {
            b.submit(req(i, 8, 4));
        }
        b.next_work(&mut kv).unwrap(); // admit all three
        b.complete_decode(&[0, 1, 2], &[1, 1, 1], &mut kv, 1.0)
            .unwrap();
        b.submit(req(3, 8, 4)); // queued, holds no blocks
        assert!(kv.used_blocks() > 0);
        let drained = b.drain(&mut kv).unwrap();
        assert_eq!(drained, vec![3, 0, 1, 2], "queue then running");
        assert_eq!(kv.used_blocks(), 0, "drained KV must be released");
        kv.check_invariants().unwrap();
        assert!(b.all_done());
        for id in drained {
            assert_eq!(b.get(id).state, RequestState::Failed);
            assert!(b.get(id).finished_ns.is_none());
        }
        // Restart: the replica admits fresh work into a clean pool.
        b.submit(req(4, 8, 1));
        assert_eq!(b.next_work(&mut kv).unwrap(), Work::Prefill(vec![4]));
        b.complete_decode(&[4], &[1], &mut kv, 2.0).unwrap();
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn max_seq_terminates_long_generations() {
        let mut b = Batcher::new(BatcherConfig {
            max_seq: 6,
            ..Default::default()
        });
        let mut kv = KvCacheManager::new(8, 4);
        b.submit(req(0, 4, 100));
        b.next_work(&mut kv).unwrap();
        b.complete_decode(&[0], &[1], &mut kv, 1.0).unwrap();
        let fin = b.complete_decode(&[0], &[1], &mut kv, 2.0).unwrap();
        assert_eq!(fin, vec![0], "terminated at max_seq");
    }
}
