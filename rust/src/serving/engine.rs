//! REAL-numerics serving engine: executes the tiny TP transformer's
//! per-rank PJRT artifacts and combines partials with host collectives —
//! the end-to-end proof that the decomposed (FLUX-style) execution is
//! numerically the full model.
//!
//! Static shapes come from the artifacts (B=batch, S=seq, Smax): callers
//! pad to B slots. Per layer and rank the engine holds the KV cache
//! contents host-side and threads them through the functional
//! `attn_decode` artifact.

use anyhow::{ensure, Context, Result};

use crate::runtime::{literal_f32, literal_i32, to_f32_vec, Runtime};

/// Per-(layer, rank) weight literals, artifact argument order.
struct LayerShard {
    ln1_g: xla::Literal,
    ln1_b: xla::Literal,
    wqkv: xla::Literal,
    wo: xla::Literal,
    ln2_g: xla::Literal,
    ln2_b: xla::Literal,
    w1: xla::Literal,
    w2: xla::Literal,
}

/// Host-side KV cache for one (layer, rank): [B, Smax, hd_local] f32.
struct KvPair {
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct Engine {
    pub rt: Runtime,
    shards: Vec<Vec<LayerShard>>, // [layer][rank]
    embed: xla::Literal,
    ln_f_g: xla::Literal,
    ln_f_b: xla::Literal,
    caches: Vec<Vec<KvPair>>, // [layer][rank]
    pub cache_len: Vec<i32>,  // [B]
    // Shapes.
    pub b: usize,
    pub s: usize,
    pub smax: usize,
    pub d: usize,
    pub hd: usize,
    pub vocab: usize,
    n_layers: usize,
    n_tp: usize,
}

impl Engine {
    pub fn new(mut rt: Runtime) -> Result<Engine> {
        let m = rt.manifest.clone();
        let mut shards = Vec::new();
        for l in 0..m.n_layers {
            let mut ranks = Vec::new();
            for r in 0..m.n_tp {
                let w = |t: &str| rt.weight(&format!("l{l}.r{r}.{t}"));
                ranks.push(LayerShard {
                    ln1_g: w("ln1_g")?,
                    ln1_b: w("ln1_b")?,
                    wqkv: w("wqkv")?,
                    wo: w("wo")?,
                    ln2_g: w("ln2_g")?,
                    ln2_b: w("ln2_b")?,
                    w1: w("w1")?,
                    w2: w("w2")?,
                });
            }
            shards.push(ranks);
        }
        let embed = rt.weight("embed")?;
        let ln_f_g = rt.weight("ln_f_g")?;
        let ln_f_b = rt.weight("ln_f_b")?;
        let caches = (0..m.n_layers)
            .map(|_| {
                (0..m.n_tp)
                    .map(|_| KvPair {
                        k: vec![0.0; m.batch * m.smax * m.hd_local],
                        v: vec![0.0; m.batch * m.smax * m.hd_local],
                    })
                    .collect()
            })
            .collect();
        // Pre-compile the hot-path artifacts up front so the request
        // loop never pays compilation latency.
        for name in [
            "embed_prefill", "embed_decode", "attn_prefill",
            "attn_decode", "mlp_prefill", "mlp_decode", "lm_head",
        ] {
            rt.ensure_compiled(name)
                .with_context(|| format!("precompiling {name}"))?;
        }
        Ok(Engine {
            b: m.batch,
            s: m.seq,
            smax: m.smax,
            d: m.d_model,
            hd: m.hd_local,
            vocab: m.vocab,
            n_layers: m.n_layers,
            n_tp: m.n_tp,
            rt,
            shards,
            embed,
            ln_f_g,
            ln_f_b,
            caches,
            cache_len: vec![0; m.batch],
        })
    }

    /// Reset all KV state (new batch of sequences).
    pub fn reset(&mut self) {
        for layer in &mut self.caches {
            for kv in layer.iter_mut() {
                kv.k.iter_mut().for_each(|x| *x = 0.0);
                kv.v.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        self.cache_len.iter_mut().for_each(|x| *x = 0);
    }

    /// Prefill up to B prompts (padded to the static [B, S] shape).
    /// Returns logits at each sequence's last valid position: [B][vocab].
    pub fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        ensure!(
            !prompts.is_empty() && prompts.len() <= self.b,
            "1..={} prompts, got {}",
            self.b,
            prompts.len()
        );
        ensure!(
            prompts.iter().all(|p| !p.is_empty() && p.len() <= self.s),
            "prompt lengths must be in 1..={}",
            self.s
        );
        self.reset();
        let (b, s, d) = (self.b, self.s, self.d);
        let mut ids = vec![0i32; b * s];
        let mut mask = vec![0.0f32; b * s];
        let mut lens = vec![1usize; b]; // dummy rows: len 1
        for (i, p) in prompts.iter().enumerate() {
            lens[i] = p.len();
            ids[i * s..i * s + p.len()].copy_from_slice(p);
            mask[i * s..i * s + p.len()].iter_mut().for_each(|x| *x = 1.0);
        }
        for i in prompts.len()..b {
            mask[i * s] = 1.0; // keep softmax well-defined on dummy rows
        }
        let pos: Vec<i32> = (0..b)
            .flat_map(|_| (0..s as i32).collect::<Vec<_>>())
            .collect();

        let ids_lit = literal_i32(&[b, s], &ids)?;
        let pos_lit = literal_i32(&[b, s], &pos)?;
        let out = self.rt.run(
            "embed_prefill",
            &[&ids_lit, &pos_lit, &self.embed],
        )?;
        let mut x = to_f32_vec(&out[0])?;
        let mask_lit = literal_f32(&[b, s], &mask)?;

        for l in 0..self.n_layers {
            // Attention partials summed over ranks == the AllReduce
            // (RS+AG) the fused FLUX kernels perform at scale.
            let mut attn_sum = vec![0.0f32; b * s * d];
            for r in 0..self.n_tp {
                let sh = &self.shards[l][r];
                let x_lit = literal_f32(&[b, s, d], &x)?;
                let out = self.rt.run(
                    "attn_prefill",
                    &[&x_lit, &mask_lit, &sh.ln1_g, &sh.ln1_b,
                      &sh.wqkv, &sh.wo],
                )?;
                let partial = to_f32_vec(&out[0])?;
                for (a, p) in attn_sum.iter_mut().zip(&partial) {
                    *a += p;
                }
                // Stash K/V into the Smax-padded cache.
                let kk = to_f32_vec(&out[1])?;
                let vv = to_f32_vec(&out[2])?;
                let kv = &mut self.caches[l][r];
                for bi in 0..b {
                    for si in 0..s {
                        let src = (bi * s + si) * self.hd;
                        let dst = (bi * self.smax + si) * self.hd;
                        kv.k[dst..dst + self.hd]
                            .copy_from_slice(&kk[src..src + self.hd]);
                        kv.v[dst..dst + self.hd]
                            .copy_from_slice(&vv[src..src + self.hd]);
                    }
                }
            }
            for (xi, a) in x.iter_mut().zip(&attn_sum) {
                *xi += a;
            }
            let mut mlp_sum = vec![0.0f32; b * s * d];
            for r in 0..self.n_tp {
                let sh = &self.shards[l][r];
                let x_lit = literal_f32(&[b, s, d], &x)?;
                let out = self.rt.run(
                    "mlp_prefill",
                    &[&x_lit, &sh.ln2_g, &sh.ln2_b, &sh.w1, &sh.w2],
                )?;
                let partial = to_f32_vec(&out[0])?;
                for (a, p) in mlp_sum.iter_mut().zip(&partial) {
                    *a += p;
                }
            }
            for (xi, a) in x.iter_mut().zip(&mlp_sum) {
                *xi += a;
            }
        }

        for (i, &len) in lens.iter().enumerate() {
            self.cache_len[i] = len as i32;
        }
        // lm_head over each sequence's last valid hidden state.
        let mut last = vec![0.0f32; b * d];
        for (i, &len) in lens.iter().enumerate() {
            let src = (i * s + (len - 1)) * d;
            last[i * d..(i + 1) * d].copy_from_slice(&x[src..src + d]);
        }
        let last_lit = literal_f32(&[b, d], &last)?;
        let out = self.rt.run(
            "lm_head",
            &[&last_lit, &self.ln_f_g, &self.ln_f_b, &self.embed],
        )?;
        let logits = to_f32_vec(&out[0])?;
        Ok((0..b)
            .map(|i| logits[i * self.vocab..(i + 1) * self.vocab].to_vec())
            .collect())
    }

    /// One decode step: feed each slot's latest token, return logits for
    /// the next. Slots beyond the live batch carry dummy tokens.
    pub fn decode_step(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        ensure!(tokens.len() == self.b, "need exactly {} tokens", self.b);
        ensure!(
            self.cache_len.iter().all(|&l| (l as usize) < self.smax),
            "KV cache full"
        );
        let (b, d) = (self.b, self.d);
        let pos: Vec<i32> = self.cache_len.clone();
        let ids_lit = literal_i32(&[b], tokens)?;
        let pos_lit = literal_i32(&[b], &pos)?;
        let out = self.rt.run(
            "embed_decode",
            &[&ids_lit, &pos_lit, &self.embed],
        )?;
        let mut x = to_f32_vec(&out[0])?; // [B, 1, d]
        let cl = literal_i32(&[b], &self.cache_len)?;

        for l in 0..self.n_layers {
            let mut attn_sum = vec![0.0f32; b * d];
            for r in 0..self.n_tp {
                let sh = &self.shards[l][r];
                let kv = &self.caches[l][r];
                let x_lit = literal_f32(&[b, 1, d], &x)?;
                let k_lit =
                    literal_f32(&[b, self.smax, self.hd], &kv.k)?;
                let v_lit =
                    literal_f32(&[b, self.smax, self.hd], &kv.v)?;
                let out = self.rt.run(
                    "attn_decode",
                    &[&x_lit, &k_lit, &v_lit, &cl, &sh.ln1_g,
                      &sh.ln1_b, &sh.wqkv, &sh.wo],
                )?;
                let partial = to_f32_vec(&out[0])?;
                for (a, p) in attn_sum.iter_mut().zip(&partial) {
                    *a += p;
                }
                let kv = &mut self.caches[l][r];
                kv.k = to_f32_vec(&out[1])?;
                kv.v = to_f32_vec(&out[2])?;
            }
            for (xi, a) in x.iter_mut().zip(&attn_sum) {
                *xi += a;
            }
            let mut mlp_sum = vec![0.0f32; b * d];
            for r in 0..self.n_tp {
                let sh = &self.shards[l][r];
                let x_lit = literal_f32(&[b, 1, d], &x)?;
                let out = self.rt.run(
                    "mlp_decode",
                    &[&x_lit, &sh.ln2_g, &sh.ln2_b, &sh.w1, &sh.w2],
                )?;
                let partial = to_f32_vec(&out[0])?;
                for (a, p) in mlp_sum.iter_mut().zip(&partial) {
                    *a += p;
                }
            }
            for (xi, a) in x.iter_mut().zip(&mlp_sum) {
                *xi += a;
            }
        }
        for l in self.cache_len.iter_mut() {
            *l += 1;
        }
        let x_lit = literal_f32(&[b, d], &x)?;
        let out = self.rt.run(
            "lm_head",
            &[&x_lit, &self.ln_f_g, &self.ln_f_b, &self.embed],
        )?;
        let logits = to_f32_vec(&out[0])?;
        Ok((0..b)
            .map(|i| logits[i * self.vocab..(i + 1) * self.vocab].to_vec())
            .collect())
    }
}

/// Greedy sampling.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_the_peak() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
