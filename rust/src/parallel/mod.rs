//! Training orchestrator: DP x PP x TP composition with a 1F1B pipeline
//! schedule — the Megatron-LM-shaped substrate for the Fig. 16 training
//! rows (128 GPUs: 2-way data, 8-way pipeline, 8-way tensor parallel).
//!
//! Only the TP-op execution differs between the compared systems
//! (Megatron-LM = non-overlap, TransformerEngine = medium, Flux = fused);
//! pipeline and data parallel costs are common structure.

pub mod schedule;

use crate::cost::arch::ClusterSpec;
use crate::cost::comm::internode_exchange_ns;
use crate::cost::gemm::gemm_time_ns;
use crate::model::analysis::{
    layer_attention_extra_ns, layer_bwd_ops, layer_fwd_ops,
};
use crate::model::configs::TransformerConfig;

// `Method` — which overlap system executes the TP ops — lives in the
// overlap method registry now; re-exported here because every training
// call site (and the historical API) spells it `parallel::Method`.
pub use crate::overlap::Method;

/// The 128-GPU layout of §5.2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
}

impl Layout {
    pub const PAPER_TRAINING: Layout = Layout { dp: 2, pp: 8, tp: 8 };

    pub fn gpus(&self) -> usize {
        self.dp * self.pp * self.tp
    }
}

/// Per-microbatch stage times.
#[derive(Clone, Copy, Debug)]
pub struct StageTimes {
    pub fwd_ns: f64,
    pub bwd_ns: f64,
}

/// Every per-step cost the 1F1B composition needs, for one (cluster,
/// model, layout, method) point. This is the single stage-timing
/// substrate shared by the closed-form [`train_step_ns`] and the
/// event-driven `training::simulate_train` path: both consume exactly
/// these numbers, so the two can only diverge in *scheduling*, never in
/// per-item cost.
#[derive(Clone, Copy, Debug)]
pub struct StepCosts {
    /// Per-microbatch forward/backward time of one pipeline stage.
    pub stage: StageTimes,
    /// Activation payload per PP stage boundary per microbatch, bytes
    /// (the backward gradient hop carries the same shape).
    pub act_bytes: f64,
    /// Closed-form time of one PP hop (NIC path at this scale).
    pub hop_ns: f64,
    /// Full wire time of the DP ring all-reduce of one GPU's gradient
    /// shard (0 when dp == 1). How much of it is *exposed* is a
    /// scheduling question answered differently by the two paths.
    pub grad_wire_ns: f64,
    /// Adam over the local shard (memory-bound, never overlapped).
    pub opt_ns: f64,
}

/// Build the shared cost substrate for one training-step configuration.
pub fn step_costs(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    layout: &Layout,
    micro_tokens: usize,
    seq: usize,
    method: Method,
    seed: u64,
) -> StepCosts {
    let stage = stage_times(
        cluster, model, layout, micro_tokens, seq, method, seed,
    );
    // Inter-stage activation transfer per microbatch boundary (PP ranks
    // live on different nodes at this scale: NIC path).
    let act_bytes = micro_tokens as f64 * model.d_model as f64 * 2.0;
    let hop_ns = internode_exchange_ns(cluster, act_bytes);
    // DP gradient ring all-reduce of this GPU's parameter shard, bf16.
    let params_per_gpu = model.params() / (layout.pp * layout.tp) as f64;
    let grad_bytes = params_per_gpu * 2.0;
    let grad_wire_ns = 2.0 * (layout.dp - 1) as f64 / layout.dp as f64
        * grad_bytes
        / cluster.nic_gbps_per_gpu;
    // Optimizer: Adam over the shard, memory-bound (~6 passes over
    // params in fp32 master copies).
    let opt_ns = 6.0 * params_per_gpu * 4.0 / cluster.arch.hbm_gbps;
    StepCosts { stage, act_bytes, hop_ns, grad_wire_ns, opt_ns }
}

/// The communication-free twin of [`stage_times`]: every TP op priced
/// at its monolithic-GEMM time (Eq. 1's `GEMM_non-split`), wgrad and
/// attention included. The training-level Eq.-2 denominator.
pub fn ideal_stage_times(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    layout: &Layout,
    micro_tokens: usize,
    seq: usize,
) -> StageTimes {
    let layers = model.n_layers / layout.pp;
    let m = micro_tokens;
    let mut fwd = 0.0;
    for p in layer_fwd_ops(model, m, layout.tp) {
        fwd += p.gemm_nonsplit_ns(cluster);
    }
    fwd += layer_attention_extra_ns(cluster, model, m, seq, layout.tp);
    let mut bwd = 0.0;
    for p in layer_bwd_ops(model, m, layout.tp) {
        bwd += p.gemm_nonsplit_ns(cluster);
        bwd += gemm_time_ns(&cluster.arch, &p.local_gemm()); // wgrad
    }
    bwd += 2.0 * layer_attention_extra_ns(cluster, model, m, seq, layout.tp);
    StageTimes {
        fwd_ns: fwd * layers as f64,
        bwd_ns: bwd * layers as f64,
    }
}

/// Time of one pipeline stage's forward/backward for one microbatch.
pub fn stage_times(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    layout: &Layout,
    micro_tokens: usize,
    seq: usize,
    method: Method,
    seed: u64,
) -> StageTimes {
    let layers = model.n_layers / layout.pp;
    let m = micro_tokens;
    let mut fwd = 0.0;
    for p in layer_fwd_ops(model, m, layout.tp) {
        fwd += method.op_ns(cluster, &p, seed);
    }
    fwd += layer_attention_extra_ns(cluster, model, m, seq, layout.tp);
    // Backward: TP'd dgrad ops (collectives interchanged) + local wgrad
    // GEMMs (no TP collective) + attention backward (~2x fwd attn).
    let mut bwd = 0.0;
    for p in layer_bwd_ops(model, m, layout.tp) {
        bwd += method.op_ns(cluster, &p, seed);
        bwd += gemm_time_ns(&cluster.arch, &p.local_gemm()); // wgrad
    }
    bwd += 2.0 * layer_attention_extra_ns(cluster, model, m, seq, layout.tp);
    StageTimes {
        fwd_ns: fwd * layers as f64,
        bwd_ns: bwd * layers as f64,
    }
}

/// One full training step (Fig. 16 training): 1F1B pipeline over
/// `microbatches`, plus inter-stage activation sends, the DP gradient
/// all-reduce and the optimizer step.
pub fn train_step_ns(
    cluster: &ClusterSpec,
    model: &TransformerConfig,
    layout: &Layout,
    microbatches: usize,
    micro_tokens: usize,
    seq: usize,
    method: Method,
    seed: u64,
) -> f64 {
    let c = step_costs(
        cluster, model, layout, micro_tokens, seq, method, seed,
    );
    let pipe = schedule::one_f1b_ns(
        layout.pp,
        microbatches,
        c.stage.fwd_ns,
        c.stage.bwd_ns,
        c.hop_ns,
    );
    // Megatron buckets gradients and overlaps the all-reduce with the
    // remaining backward passes; only the tail past the backward work
    // is exposed.
    let dp_ar = if layout.dp > 1 {
        let bwd_window = 0.8 * microbatches as f64 * c.stage.bwd_ns;
        (c.grad_wire_ns - bwd_window).max(0.05 * c.grad_wire_ns)
    } else {
        0.0
    };
    pipe + dp_ar + c.opt_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};
    use crate::model::configs::GPT3_175B;

    const LAYOUT: Layout = Layout::PAPER_TRAINING;

    fn step(cluster: &ClusterSpec, method: Method) -> f64 {
        train_step_ns(
            cluster, &GPT3_175B, &LAYOUT, 16, 2048, 2048, method, 3,
        )
    }

    #[test]
    fn layout_is_128_gpus() {
        assert_eq!(LAYOUT.gpus(), 128);
    }

    #[test]
    fn flux_speedup_tracks_comm_portion() {
        // Fig. 16 training: ~1.24x on PCIe, ~1.04-1.05x on A100 NVLink,
        // ~1.10x on H800 over Megatron-LM. Shape check: the PCIe speedup
        // must dominate, NVLink stays modest.
        let sp = |c: &ClusterSpec| {
            step(c, Method::NonOverlap) / step(c, Method::Flux)
        };
        let pcie = sp(&A100_PCIE);
        let nvl = sp(&A100_NVLINK);
        let h800 = sp(&H800_NVLINK);
        assert!(pcie > 1.10 && pcie < 1.60, "pcie speedup {pcie}");
        assert!(nvl > 1.00 && nvl < 1.20, "nvlink speedup {nvl}");
        // H800 overshoots the paper's 1.10x here (see EXPERIMENTS.md:
        // the simulator exposes all baseline TP comm, the production
        // Megatron hides some behind PP/DP traffic).
        assert!(h800 > 1.00 && h800 < 1.45, "h800 speedup {h800}");
        assert!(pcie > nvl && h800 > nvl);
    }

    #[test]
    fn flux_beats_te_in_training() {
        for c in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
            assert!(
                step(c, Method::Flux) < step(c, Method::Medium),
                "{}", c.name
            );
        }
    }

    #[test]
    fn ideal_stage_floors_every_method() {
        // The comm-free stage is a lower bound on every method's stage
        // time: overlap hides communication, it cannot create compute.
        let ideal = ideal_stage_times(
            &A100_NVLINK, &GPT3_175B, &LAYOUT, 2048, 2048,
        );
        for m in Method::ALL {
            let st = stage_times(
                &A100_NVLINK, &GPT3_175B, &LAYOUT, 2048, 2048, m, 3,
            );
            assert!(st.fwd_ns >= ideal.fwd_ns * 0.999, "{}", m.name());
            assert!(st.bwd_ns >= ideal.bwd_ns * 0.999, "{}", m.name());
        }
    }

    #[test]
    fn step_costs_dp1_has_no_gradient_wire() {
        let solo = Layout { dp: 1, pp: 8, tp: 8 };
        let c = step_costs(
            &A100_NVLINK, &GPT3_175B, &solo, 2048, 2048,
            Method::NonOverlap, 3,
        );
        assert_eq!(c.grad_wire_ns, 0.0);
        assert!(c.opt_ns > 0.0 && c.hop_ns > 0.0 && c.act_bytes > 0.0);
    }

    #[test]
    fn step_time_plausible_absolute() {
        // GPT-3 175B, 16 microbatches of 2048 tokens on 128 A100s:
        // hundreds of ms to a few seconds per step.
        let t = step(&A100_NVLINK, Method::NonOverlap);
        assert!(t > 0.2e9 && t < 20.0e9, "step {t} ns");
    }
}
