//! 1F1B (one-forward-one-backward) pipeline schedule timing.
//!
//! The standard non-interleaved 1F1B of PipeDream-Flush / Megatron-LM:
//! warmup of (p - stage) forwards, steady-state alternation, cooldown.
//! We time the critical path of the whole pipeline: with per-microbatch
//! forward f, backward b and inter-stage hop h,
//!
//!   T = (p - 1) * (f + h)            // pipeline fill
//!     + m * (f + b)                  // steady state on the last stage
//!     + (p - 1) * (b + h)            // drain
//!
//! which is the familiar (m + p - 1) * (f + b) minus the overlap saved in
//! steady state, expressed directly.

/// Total 1F1B pipeline time for `m` microbatches over `p` stages.
pub fn one_f1b_ns(p: usize, m: usize, f: f64, b: f64, hop: f64) -> f64 {
    assert!(p >= 1 && m >= 1);
    let fill = (p - 1) as f64 * (f + hop);
    let steady = m as f64 * (f + b);
    let drain = (p - 1) as f64 * (b + hop);
    fill + steady + drain
}

/// Pipeline bubble fraction: wasted time / total.
pub fn bubble_fraction(p: usize, m: usize) -> f64 {
    (p - 1) as f64 / (m + p - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stage_has_no_bubble() {
        let t = one_f1b_ns(1, 4, 10.0, 20.0, 5.0);
        assert_eq!(t, 4.0 * 30.0);
        assert_eq!(bubble_fraction(1, 4), 0.0);
    }

    #[test]
    fn fill_and_drain_grow_with_stages() {
        let t2 = one_f1b_ns(2, 8, 10.0, 20.0, 1.0);
        let t8 = one_f1b_ns(8, 8, 10.0, 20.0, 1.0);
        assert!(t8 > t2);
        // 8 stages, 8 microbatches: t = 7*11 + 8*30 + 7*21 = 464.
        assert_eq!(t8, 7.0 * 11.0 + 240.0 + 7.0 * 21.0);
    }

    #[test]
    fn more_microbatches_amortize_the_bubble() {
        assert!(bubble_fraction(8, 64) < bubble_fraction(8, 8));
    }
}
