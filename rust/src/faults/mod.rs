//! Fault injection: seeded stragglers, NIC degradation, replica
//! churn and elastic DP resize as declarative, replayable specs
//! (ROADMAP item 5).
//!
//! A [`FaultSpec`] is data, like [`crate::workload::WorkloadSpec`]:
//! it names straggler windows (per-replica multiplicative step-time
//! inflation), NIC brownouts (scaled link bandwidth over a window),
//! replica kills (drain in-flight work, reject routing, rejoin after
//! a seeded downtime) and elastic DP resizes, parsed/serialized via
//! `util/json` with pointed parse-time rejection. [`FaultSpec::expand`]
//! turns the spec into a concrete [`FaultTimeline`] for one cluster
//! size and one *intensity* knob: every seeded draw happens once, in a
//! fixed documented order, **before** intensity scaling, so the
//! timelines at intensity 0.0, 0.5 and 1.0 nest — the same kill fires
//! at the same instant, only its downtime stretches. Intensity 0
//! expands to an empty timeline and callers take the structurally
//! identical fault-free path, which is what keeps the no-fault report
//! bytes bit-identical to the PR-5 documents.
//!
//! The serving coordinator consumes kills/resizes/stragglers as DES
//! events ([`crate::serving::scale::run_scale_faulted`]); the training
//! simulator consumes stragglers (replica index = pipeline stage) and
//! NIC windows ([`crate::training::run_train_with`]). The degradation
//! curves land in the byte-stable `flux-churn-v1` report
//! ([`crate::report::churn_doc_scenario`]).

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// Sanity cap on every spec time/duration (ns). 2^53 ns is ~104 days
/// of simulated time — far beyond any scenario here, and still exact
/// in an f64.
pub const MAX_TIME_NS: f64 = 9.0e15;

/// Per-replica multiplicative step-time inflation over a window.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerSpec {
    /// Target replica (serving) or pipeline stage (training);
    /// `None` = every replica, each with its own jitter draw.
    pub replica: Option<usize>,
    pub start_ns: f64,
    pub dur_ns: f64,
    /// Step-time multiplier at intensity 1.0 (>= 1.0).
    pub factor: f64,
    /// Uniform jitter added to `factor`: the drawn factor is
    /// `factor + jitter * u` with `u ~ U[0, 1)` from the spec seed.
    pub jitter: f64,
}

/// Scaled NIC/link bandwidth over a window: effective transfer time
/// is multiplied by `scale` (>= 1.0) while the window is open.
#[derive(Clone, Debug, PartialEq)]
pub struct NicSpec {
    pub start_ns: f64,
    pub dur_ns: f64,
    pub scale: f64,
}

/// Kill a replica at `at_ns`; it drains, rejects routing, and rejoins
/// after a seeded downtime.
#[derive(Clone, Debug, PartialEq)]
pub struct KillSpec {
    /// `None` = every replica (correlated outage), each with its own
    /// downtime jitter draw.
    pub replica: Option<usize>,
    pub at_ns: f64,
    /// Downtime at intensity 1.0; the drawn downtime is
    /// `downtime_ns + downtime_jitter_ns * u`, then scaled by the
    /// expansion intensity.
    pub downtime_ns: f64,
    pub downtime_jitter_ns: f64,
}

/// Elastic DP resize: cap the routable replica set at `target_dp`
/// from `at_ns`, restoring the full set after `dur_ns` (0 = never).
#[derive(Clone, Debug, PartialEq)]
pub struct ResizeSpec {
    pub at_ns: f64,
    pub target_dp: usize,
    pub dur_ns: f64,
}

/// One declarative, seeded fault scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub name: String,
    pub seed: u64,
    pub stragglers: Vec<StragglerSpec>,
    pub nic: Vec<NicSpec>,
    pub kills: Vec<KillSpec>,
    pub resizes: Vec<ResizeSpec>,
}

/// A concrete straggler window after expansion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerWindow {
    pub replica: usize,
    pub start_ns: f64,
    pub end_ns: f64,
    pub factor: f64,
}

/// A concrete NIC degradation window after expansion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NicWindow {
    pub start_ns: f64,
    pub end_ns: f64,
    pub scale: f64,
}

/// A concrete kill/restart pair after expansion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Kill {
    pub replica: usize,
    pub at_ns: f64,
    pub restart_ns: f64,
}

/// A concrete resize after expansion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Resize {
    pub at_ns: f64,
    pub target_dp: usize,
    /// When the full replica set comes back (`None` = permanent).
    pub restore_ns: Option<f64>,
}

/// The expanded, intensity-scaled timeline one simulation consumes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultTimeline {
    pub stragglers: Vec<StragglerWindow>,
    pub nic: Vec<NicWindow>,
    pub kills: Vec<Kill>,
    pub resizes: Vec<Resize>,
}

/// One scheduled fault transition for the serving DES (time-sorted).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    Kill(usize),
    Restart(usize),
    /// Cap (or restore) the routable replica set.
    SetDp(usize),
}

/// A fault transition with its firing time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at_ns: f64,
    pub action: FaultAction,
}

impl FaultTimeline {
    /// No windows, no kills, no resizes: callers must take the
    /// fault-free path (byte-identical to a run with no spec at all).
    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty()
            && self.nic.is_empty()
            && self.kills.is_empty()
            && self.resizes.is_empty()
    }

    /// Product of every straggler window covering (`replica`, `now`);
    /// 1.0 when none do. Windows are half-open `[start, end)`.
    pub fn step_factor(&self, replica: usize, now: f64) -> f64 {
        let mut f = 1.0;
        for w in &self.stragglers {
            if w.replica == replica
                && now >= w.start_ns
                && now < w.end_ns
            {
                f *= w.factor;
            }
        }
        f
    }

    /// Product of every NIC window covering `now`; 1.0 when none do.
    pub fn nic_scale(&self, now: f64) -> f64 {
        let mut s = 1.0;
        for w in &self.nic {
            if now >= w.start_ns && now < w.end_ns {
                s *= w.scale;
            }
        }
        s
    }

    /// Kill/restart/resize transitions as a time-sorted event list
    /// for the serving DES. `n_replicas` is the full DP width a
    /// resize restore returns to. The sort is stable (ties keep the
    /// kill-before-restart-before-resize construction order), so the
    /// schedule is deterministic.
    pub fn events(&self, n_replicas: usize) -> Vec<FaultEvent> {
        let mut evs = Vec::new();
        for k in &self.kills {
            evs.push(FaultEvent {
                at_ns: k.at_ns,
                action: FaultAction::Kill(k.replica),
            });
            evs.push(FaultEvent {
                at_ns: k.restart_ns,
                action: FaultAction::Restart(k.replica),
            });
        }
        for r in &self.resizes {
            evs.push(FaultEvent {
                at_ns: r.at_ns,
                action: FaultAction::SetDp(r.target_dp),
            });
            if let Some(restore) = r.restore_ns {
                evs.push(FaultEvent {
                    at_ns: restore,
                    action: FaultAction::SetDp(n_replicas),
                });
            }
        }
        evs.sort_by(|a, b| a.at_ns.total_cmp(&b.at_ns));
        evs
    }
}

fn time(name: &str, field: &str, v: f64, lo: f64) -> Result<()> {
    ensure!(
        v.is_finite() && v >= lo && v <= MAX_TIME_NS,
        "fault spec {name:?}: {field} must be a finite time in \
         [{lo}, {MAX_TIME_NS}] ns, got {v}"
    );
    Ok(())
}

impl FaultSpec {
    /// A named spec with no faults (expands empty at any intensity).
    pub fn none() -> FaultSpec {
        FaultSpec {
            name: "none".to_string(),
            seed: 0,
            stragglers: Vec::new(),
            nic: Vec::new(),
            kills: Vec::new(),
            resizes: Vec::new(),
        }
    }

    /// Whether the spec injects nothing at all — no kills,
    /// stragglers, NIC windows or resizes at any intensity.
    pub fn is_none(&self) -> bool {
        self.kills.is_empty()
            && self.stragglers.is_empty()
            && self.nic.is_empty()
            && self.resizes.is_empty()
    }

    pub fn validate(&self) -> Result<()> {
        let name = self.name.as_str();
        ensure!(!name.is_empty(), "fault spec name must be non-empty");
        for (i, s) in self.stragglers.iter().enumerate() {
            let f = |field: &str| format!("stragglers[{i}].{field}");
            time(name, &f("start_ns"), s.start_ns, 0.0)?;
            time(name, &f("dur_ns"), s.dur_ns, 0.0)?;
            ensure!(
                s.factor.is_finite() && s.factor >= 1.0,
                "fault spec {name:?}: {} must be >= 1.0 (a slowdown \
                 multiplier), got {}",
                f("factor"),
                s.factor
            );
            ensure!(
                s.jitter.is_finite() && s.jitter >= 0.0,
                "fault spec {name:?}: {} must be >= 0.0, got {}",
                f("jitter"),
                s.jitter
            );
        }
        for (i, w) in self.nic.iter().enumerate() {
            let f = |field: &str| format!("nic[{i}].{field}");
            time(name, &f("start_ns"), w.start_ns, 0.0)?;
            time(name, &f("dur_ns"), w.dur_ns, 0.0)?;
            ensure!(
                w.scale.is_finite() && w.scale >= 1.0,
                "fault spec {name:?}: {} must be >= 1.0 (a bandwidth \
                 slowdown), got {}",
                f("scale"),
                w.scale
            );
        }
        for (i, k) in self.kills.iter().enumerate() {
            let f = |field: &str| format!("kills[{i}].{field}");
            time(name, &f("at_ns"), k.at_ns, 0.0)?;
            ensure!(
                k.downtime_ns.is_finite()
                    && k.downtime_ns > 0.0
                    && k.downtime_ns <= MAX_TIME_NS,
                "fault spec {name:?}: {} must be a positive downtime \
                 in ns, got {}",
                f("downtime_ns"),
                k.downtime_ns
            );
            time(
                name,
                &f("downtime_jitter_ns"),
                k.downtime_jitter_ns,
                0.0,
            )?;
        }
        for (i, r) in self.resizes.iter().enumerate() {
            let f = |field: &str| format!("resizes[{i}].{field}");
            time(name, &f("at_ns"), r.at_ns, 0.0)?;
            time(name, &f("dur_ns"), r.dur_ns, 0.0)?;
            ensure!(
                r.target_dp >= 1,
                "fault spec {name:?}: {} must be >= 1 (resizing to 0 \
                 replicas deadlocks every arrival), got {}",
                f("target_dp"),
                r.target_dp
            );
        }
        Ok(())
    }

    /// Expand the spec for an `n_replicas`-wide cluster at one
    /// `intensity` in [0, 1].
    ///
    /// All seeded randomness is drawn here, from `Rng::new(seed)`, in
    /// one fixed order — kills first (spec order; `replica: None`
    /// draws once per replica `0..n`), then stragglers the same way —
    /// and only then scaled by `intensity`. Drawing before scaling is
    /// what makes the timelines nest: intensity only stretches
    /// downtimes and shrinks factors toward 1, it never re-rolls.
    /// Intensity 0 returns an empty timeline.
    pub fn expand(
        &self,
        n_replicas: usize,
        intensity: f64,
    ) -> FaultTimeline {
        let k = intensity.clamp(0.0, 1.0);
        let mut rng = Rng::new(self.seed);
        let mut tl = FaultTimeline::default();

        let targets = |r: Option<usize>| match r {
            Some(i) => (i, i + 1),
            None => (0, n_replicas),
        };
        for kill in &self.kills {
            let (lo, hi) = targets(kill.replica);
            for replica in lo..hi {
                let drawn = kill.downtime_ns
                    + kill.downtime_jitter_ns * rng.f64();
                if replica >= n_replicas || k == 0.0 {
                    continue;
                }
                tl.kills.push(Kill {
                    replica,
                    at_ns: kill.at_ns,
                    restart_ns: kill.at_ns + drawn * k,
                });
            }
        }
        for s in &self.stragglers {
            let (lo, hi) = targets(s.replica);
            for replica in lo..hi {
                let drawn = s.factor + s.jitter * rng.f64();
                let factor = 1.0 + (drawn - 1.0) * k;
                if replica >= n_replicas
                    || factor <= 1.0
                    || s.dur_ns <= 0.0
                {
                    continue;
                }
                tl.stragglers.push(StragglerWindow {
                    replica,
                    start_ns: s.start_ns,
                    end_ns: s.start_ns + s.dur_ns,
                    factor,
                });
            }
        }
        for w in &self.nic {
            let scale = 1.0 + (w.scale - 1.0) * k;
            if scale <= 1.0 || w.dur_ns <= 0.0 {
                continue;
            }
            tl.nic.push(NicWindow {
                start_ns: w.start_ns,
                end_ns: w.start_ns + w.dur_ns,
                scale,
            });
        }
        if k > 0.0 {
            for r in &self.resizes {
                tl.resizes.push(Resize {
                    at_ns: r.at_ns,
                    target_dp: r.target_dp,
                    restore_ns: (r.dur_ns > 0.0)
                        .then(|| r.at_ns + r.dur_ns * k),
                });
            }
        }
        tl
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::from(self.name.as_str())),
            ("seed", Json::from(self.seed as f64)),
        ];
        let replica = |r: Option<usize>, out: &mut Vec<(&str, Json)>| {
            if let Some(i) = r {
                out.push(("replica", Json::from(i)));
            }
        };
        if !self.stragglers.is_empty() {
            fields.push((
                "stragglers",
                Json::Arr(
                    self.stragglers
                        .iter()
                        .map(|s| {
                            let mut f = Vec::new();
                            replica(s.replica, &mut f);
                            f.push(("start_ns", Json::from(s.start_ns)));
                            f.push(("dur_ns", Json::from(s.dur_ns)));
                            f.push(("factor", Json::from(s.factor)));
                            if s.jitter != 0.0 {
                                f.push(("jitter", Json::from(s.jitter)));
                            }
                            obj(f)
                        })
                        .collect(),
                ),
            ));
        }
        if !self.nic.is_empty() {
            fields.push((
                "nic",
                Json::Arr(
                    self.nic
                        .iter()
                        .map(|w| {
                            obj(vec![
                                ("start_ns", Json::from(w.start_ns)),
                                ("dur_ns", Json::from(w.dur_ns)),
                                ("scale", Json::from(w.scale)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.kills.is_empty() {
            fields.push((
                "kills",
                Json::Arr(
                    self.kills
                        .iter()
                        .map(|kl| {
                            let mut f = Vec::new();
                            replica(kl.replica, &mut f);
                            f.push(("at_ns", Json::from(kl.at_ns)));
                            f.push((
                                "downtime_ns",
                                Json::from(kl.downtime_ns),
                            ));
                            if kl.downtime_jitter_ns != 0.0 {
                                f.push((
                                    "downtime_jitter_ns",
                                    Json::from(kl.downtime_jitter_ns),
                                ));
                            }
                            obj(f)
                        })
                        .collect(),
                ),
            ));
        }
        if !self.resizes.is_empty() {
            fields.push((
                "resizes",
                Json::Arr(
                    self.resizes
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("at_ns", Json::from(r.at_ns)),
                                ("target_dp", Json::from(r.target_dp)),
                                ("dur_ns", Json::from(r.dur_ns)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        obj(fields)
    }

    /// Parse (and validate) a fault document. Bad times, factors and
    /// targets are rejected here with pointed errors instead of
    /// producing a nonsense timeline mid-simulation.
    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        let name = j.get("name")?.as_str()?.to_string();
        let ctx = || format!("fault spec {name:?}");
        let arr = |key: &str| -> Result<Vec<Json>> {
            match j.opt(key) {
                Some(v) => Ok(v.as_arr()?.to_vec()),
                None => Ok(Vec::new()),
            }
        };
        let replica = |e: &Json| -> Result<Option<usize>> {
            match e.opt("replica") {
                Some(r) => Ok(Some(r.as_usize()?)),
                None => Ok(None),
            }
        };
        let opt_f64 = |e: &Json, key: &str| -> Result<f64> {
            match e.opt(key) {
                Some(v) => v.as_f64(),
                None => Ok(0.0),
            }
        };
        let spec = FaultSpec {
            seed: j.get("seed").with_context(ctx)?.as_i64()? as u64,
            stragglers: arr("stragglers")?
                .iter()
                .map(|e| {
                    Ok(StragglerSpec {
                        replica: replica(e)?,
                        start_ns: e.get("start_ns")?.as_f64()?,
                        dur_ns: e.get("dur_ns")?.as_f64()?,
                        factor: e.get("factor")?.as_f64()?,
                        jitter: opt_f64(e, "jitter")?,
                    })
                })
                .collect::<Result<_>>()
                .with_context(ctx)?,
            nic: arr("nic")?
                .iter()
                .map(|e| {
                    Ok(NicSpec {
                        start_ns: e.get("start_ns")?.as_f64()?,
                        dur_ns: e.get("dur_ns")?.as_f64()?,
                        scale: e.get("scale")?.as_f64()?,
                    })
                })
                .collect::<Result<_>>()
                .with_context(ctx)?,
            kills: arr("kills")?
                .iter()
                .map(|e| {
                    Ok(KillSpec {
                        replica: replica(e)?,
                        at_ns: e.get("at_ns")?.as_f64()?,
                        downtime_ns: e.get("downtime_ns")?.as_f64()?,
                        downtime_jitter_ns: opt_f64(
                            e,
                            "downtime_jitter_ns",
                        )?,
                    })
                })
                .collect::<Result<_>>()
                .with_context(ctx)?,
            resizes: arr("resizes")?
                .iter()
                .map(|e| {
                    Ok(ResizeSpec {
                        at_ns: e.get("at_ns")?.as_f64()?,
                        target_dp: e.get("target_dp")?.as_usize()?,
                        dur_ns: e.get("dur_ns")?.as_f64()?,
                    })
                })
                .collect::<Result<_>>()
                .with_context(ctx)?,
            name,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a fault scenario file from disk.
    pub fn load(path: &std::path::Path) -> Result<FaultSpec> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!("reading fault file {}", path.display())
        })?;
        let j = Json::parse(&text).with_context(|| {
            format!("parsing fault file {}", path.display())
        })?;
        FaultSpec::from_json(&j).with_context(|| {
            format!("validating fault file {}", path.display())
        })
    }

    /// Resolve `--faults <preset|file.json>`: a preset name first,
    /// else a path.
    pub fn resolve(arg: &str) -> Result<FaultSpec> {
        if let Some(spec) = preset(arg) {
            return Ok(spec);
        }
        if arg.ends_with(".json") || std::path::Path::new(arg).exists()
        {
            return FaultSpec::load(std::path::Path::new(arg));
        }
        bail!(
            "unknown fault preset {arg:?}; one of ({}) or a fault \
             .json file",
            PRESET_NAMES.join(" | ")
        )
    }
}

/// How a scenario names its faults: a preset, or an inline spec.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultsRef {
    Preset(String),
    Inline(FaultSpec),
}

impl FaultsRef {
    pub fn to_json(&self) -> Json {
        match self {
            FaultsRef::Preset(name) => Json::from(name.as_str()),
            FaultsRef::Inline(spec) => spec.to_json(),
        }
    }

    pub fn from_json(j: &Json) -> Result<FaultsRef> {
        match j {
            Json::Str(name) => Ok(FaultsRef::Preset(name.clone())),
            Json::Obj(_) => {
                Ok(FaultsRef::Inline(FaultSpec::from_json(j)?))
            }
            _ => bail!(
                "faults must be a preset name or an inline fault \
                 object"
            ),
        }
    }

    /// The concrete spec this reference names.
    pub fn resolved(&self) -> Result<FaultSpec> {
        match self {
            FaultsRef::Preset(name) => FaultSpec::resolve(name),
            FaultsRef::Inline(spec) => {
                spec.validate()?;
                Ok(spec.clone())
            }
        }
    }
}

/// The preset names `flux list` prints, in report order.
pub const PRESET_NAMES: [&str; 3] =
    ["replica-churn", "straggler-storm", "nic-brownout"];

/// Built-in fault presets. `replica-churn` is the CI byte-compared
/// scenario: a correlated outage kills every replica 30 ms in, each
/// rejoining after a 120 ms (intensity-scaled) downtime — the drain /
/// reject-routing / rejoin path end to end. `straggler-storm` inflates
/// every replica's step times (seeded per-replica jitter) with a NIC
/// brownout on top; `nic-brownout` degrades only the wire.
pub fn preset(name: &str) -> Option<FaultSpec> {
    let spec = match name {
        "replica-churn" => FaultSpec {
            name: name.to_string(),
            seed: 23,
            stragglers: Vec::new(),
            nic: Vec::new(),
            kills: vec![KillSpec {
                replica: None,
                at_ns: 30.0e6,
                downtime_ns: 120.0e6,
                downtime_jitter_ns: 0.0,
            }],
            resizes: Vec::new(),
        },
        "straggler-storm" => FaultSpec {
            name: name.to_string(),
            seed: 29,
            stragglers: vec![StragglerSpec {
                replica: None,
                start_ns: 0.0,
                dur_ns: 10.0e9,
                factor: 1.6,
                jitter: 0.25,
            }],
            nic: vec![NicSpec {
                start_ns: 0.0,
                dur_ns: 10.0e9,
                scale: 1.5,
            }],
            kills: Vec::new(),
            resizes: Vec::new(),
        },
        "nic-brownout" => FaultSpec {
            name: name.to_string(),
            seed: 31,
            stragglers: Vec::new(),
            nic: vec![NicSpec {
                start_ns: 0.0,
                dur_ns: 10.0e9,
                scale: 3.0,
            }],
            kills: Vec::new(),
            resizes: Vec::new(),
        },
        _ => return None,
    };
    debug_assert!(spec.validate().is_ok());
    Some(spec)
}

/// All presets in report order.
pub fn all_presets() -> Vec<FaultSpec> {
    PRESET_NAMES.iter().copied().filter_map(preset).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn storm() -> FaultSpec {
        preset("straggler-storm").unwrap()
    }

    #[test]
    fn presets_resolve_and_round_trip_byte_stably() {
        for spec in all_presets() {
            spec.validate().unwrap();
            let text = spec.to_json().to_string();
            let parsed =
                FaultSpec::from_json(&Json::parse(&text).unwrap())
                    .unwrap();
            assert_eq!(parsed, spec);
            assert_eq!(parsed.to_json().to_string(), text);
            assert_eq!(
                FaultSpec::resolve(&spec.name).unwrap(),
                spec
            );
        }
        let err =
            FaultSpec::resolve("mystery-outage").unwrap_err().to_string();
        assert!(err.contains("replica-churn"), "{err}");
    }

    #[test]
    fn zero_intensity_expands_empty() {
        for spec in all_presets() {
            let tl = spec.expand(4, 0.0);
            assert!(tl.is_empty(), "{}: {tl:?}", spec.name);
            assert_eq!(tl.events(4).len(), 0);
        }
        assert!(FaultSpec::none().expand(4, 1.0).is_empty());
    }

    #[test]
    fn timelines_nest_across_intensities() {
        // Same seed, same draws: half intensity halves the downtime
        // and pulls factors toward 1, but never moves a kill instant
        // or re-rolls jitter.
        let spec = preset("replica-churn").unwrap();
        let half = spec.expand(4, 0.5);
        let full = spec.expand(4, 1.0);
        assert_eq!(half.kills.len(), 4);
        assert_eq!(full.kills.len(), 4);
        for (h, f) in half.kills.iter().zip(&full.kills) {
            assert_eq!(h.replica, f.replica);
            assert_eq!(h.at_ns, f.at_ns);
            assert_eq!(h.at_ns, 30.0e6);
            // Zero jitter: the windows are exact.
            assert_eq!(h.restart_ns, 30.0e6 + 120.0e6 * 0.5);
            assert_eq!(f.restart_ns, 30.0e6 + 120.0e6);
        }
        let sh = storm().expand(4, 0.5);
        let sf = storm().expand(4, 1.0);
        for (h, f) in sh.stragglers.iter().zip(&sf.stragglers) {
            assert_eq!(h.replica, f.replica);
            assert_eq!((h.start_ns, h.end_ns), (f.start_ns, f.end_ns));
            assert!(h.factor > 1.0 && h.factor < f.factor);
            // h = 1 + (d-1)/2  <=>  d = 2h - 1 = f's draw.
            assert!((2.0 * (h.factor - 1.0)
                - (f.factor - 1.0))
                .abs()
                < 1e-12);
        }
        assert_eq!(sh.nic.len(), 1);
        assert_eq!(sh.nic[0].scale, 1.25);
        assert_eq!(sf.nic[0].scale, 1.5);
    }

    #[test]
    fn expansion_is_deterministic_and_replica_scoped() {
        let spec = storm();
        assert_eq!(spec.expand(4, 1.0), spec.expand(4, 1.0));
        // Per-replica jitter differs across replicas but each
        // replica's draw is fixed by position.
        let tl = spec.expand(4, 1.0);
        assert_eq!(tl.stragglers.len(), 4);
        assert!(tl.stragglers[0].factor != tl.stragglers[1].factor);
        // Out-of-range explicit targets are dropped.
        let mut narrow = spec.clone();
        narrow.stragglers[0].replica = Some(7);
        assert!(narrow.expand(2, 1.0).stragglers.is_empty());
    }

    #[test]
    fn step_factor_and_nic_scale_window_semantics() {
        let tl = FaultTimeline {
            stragglers: vec![
                StragglerWindow {
                    replica: 1,
                    start_ns: 10.0,
                    end_ns: 20.0,
                    factor: 2.0,
                },
                StragglerWindow {
                    replica: 1,
                    start_ns: 15.0,
                    end_ns: 25.0,
                    factor: 3.0,
                },
            ],
            nic: vec![NicWindow {
                start_ns: 5.0,
                end_ns: 6.0,
                scale: 4.0,
            }],
            kills: Vec::new(),
            resizes: Vec::new(),
        };
        assert_eq!(tl.step_factor(0, 12.0), 1.0);
        assert_eq!(tl.step_factor(1, 12.0), 2.0);
        assert_eq!(tl.step_factor(1, 17.0), 6.0);
        assert_eq!(tl.step_factor(1, 20.0), 3.0);
        assert_eq!(tl.step_factor(1, 25.0), 1.0);
        assert_eq!(tl.nic_scale(5.5), 4.0);
        assert_eq!(tl.nic_scale(6.0), 1.0);
    }

    #[test]
    fn event_list_is_time_sorted_with_restarts_and_restores() {
        let spec = FaultSpec {
            name: "mixed".into(),
            seed: 1,
            stragglers: Vec::new(),
            nic: Vec::new(),
            kills: vec![KillSpec {
                replica: Some(1),
                at_ns: 50.0,
                downtime_ns: 100.0,
                downtime_jitter_ns: 0.0,
            }],
            resizes: vec![ResizeSpec {
                at_ns: 10.0,
                target_dp: 2,
                dur_ns: 30.0,
            }],
        };
        spec.validate().unwrap();
        let evs = spec.expand(4, 1.0).events(4);
        assert_eq!(
            evs,
            vec![
                FaultEvent {
                    at_ns: 10.0,
                    action: FaultAction::SetDp(2)
                },
                FaultEvent {
                    at_ns: 40.0,
                    action: FaultAction::SetDp(4)
                },
                FaultEvent {
                    at_ns: 50.0,
                    action: FaultAction::Kill(1)
                },
                FaultEvent {
                    at_ns: 150.0,
                    action: FaultAction::Restart(1)
                },
            ]
        );
    }

    #[test]
    fn validation_rejects_bad_specs_with_pointed_errors() {
        let cases: Vec<(&str, FaultSpec)> = vec![
            ("factor", {
                let mut s = storm();
                s.stragglers[0].factor = 0.5;
                s
            }),
            ("downtime_ns", {
                let mut s = preset("replica-churn").unwrap();
                s.kills[0].downtime_ns = 0.0;
                s
            }),
            ("scale", {
                let mut s = preset("nic-brownout").unwrap();
                s.nic[0].scale = f64::NAN;
                s
            }),
            ("target_dp", FaultSpec {
                resizes: vec![ResizeSpec {
                    at_ns: 0.0,
                    target_dp: 0,
                    dur_ns: 0.0,
                }],
                ..FaultSpec::none()
            }),
            ("start_ns", {
                let mut s = storm();
                s.stragglers[0].start_ns = -1.0;
                s
            }),
        ];
        for (field, spec) in cases {
            let msg =
                format!("{:#}", spec.validate().unwrap_err());
            assert!(
                msg.contains(field) && msg.contains(&spec.name),
                "must name the spec and {field}: {msg}"
            );
        }
    }

    #[test]
    fn faults_ref_round_trips_both_shapes() {
        let p = FaultsRef::Preset("replica-churn".into());
        let parsed = FaultsRef::from_json(&p.to_json()).unwrap();
        assert_eq!(parsed, p);
        assert_eq!(
            parsed.resolved().unwrap().name,
            "replica-churn"
        );
        let inline = FaultsRef::Inline(storm());
        let text = inline.to_json().to_string();
        let back =
            FaultsRef::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, inline);
        assert_eq!(back.to_json().to_string(), text);
        assert!(FaultsRef::from_json(&Json::from(3.0)).is_err());
    }
}
