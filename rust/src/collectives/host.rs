//! Numeric collectives over per-rank host buffers.

use anyhow::{ensure, Result};

/// A row-major matrix on one simulated rank.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Rows [r0, r1) as a new matrix.
    pub fn row_slice(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Blocked matmul with f32 accumulation: C = A @ B.
/// The numeric GEMM substrate for tile-level twins and tests.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "inner dims");
    let mut c = Mat::zeros(a.rows, b.cols);
    // i-k-j loop order: streams B rows, vectorizes the j loop.
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let aik = a.at(i, kk);
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[kk * b.cols..(kk + 1) * b.cols];
            let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// AllGather along rows: every rank ends with the concatenation.
pub fn all_gather(shards: &[Mat]) -> Result<Vec<Mat>> {
    ensure!(!shards.is_empty());
    let cols = shards[0].cols;
    ensure!(shards.iter().all(|s| s.cols == cols), "ragged cols");
    let rows: usize = shards.iter().map(|s| s.rows).sum();
    let mut full = Mat::zeros(rows, cols);
    let mut r0 = 0;
    for s in shards {
        full.data[r0 * cols..(r0 + s.rows) * cols]
            .copy_from_slice(&s.data);
        r0 += s.rows;
    }
    Ok(vec![full; shards.len()])
}

/// ReduceScatter along rows: rank r gets the r-th row block of the sum.
pub fn reduce_scatter(partials: &[Mat]) -> Result<Vec<Mat>> {
    ensure!(!partials.is_empty());
    let n = partials.len();
    let (rows, cols) = (partials[0].rows, partials[0].cols);
    ensure!(
        partials.iter().all(|p| p.rows == rows && p.cols == cols),
        "ragged partials"
    );
    ensure!(rows % n == 0, "rows {rows} not divisible by n {n}");
    let block = rows / n;
    let mut out = Vec::with_capacity(n);
    for r in 0..n {
        let mut m = Mat::zeros(block, cols);
        for p in partials {
            for i in 0..block {
                for j in 0..cols {
                    *m.at_mut(i, j) += p.at(r * block + i, j);
                }
            }
        }
        out.push(m);
    }
    Ok(out)
}

/// AllReduce = ReduceScatter + AllGather.
pub fn all_reduce(partials: &[Mat]) -> Result<Vec<Mat>> {
    let rs = reduce_scatter(partials)?;
    all_gather(&rs)
}

/// AlltoAll of the §3.1 decoupling: `scattered[r][d]` is what rank r
/// computed for destination d; returns `received[d][s]` = slot from
/// source s.
pub fn all_to_all(scattered: &[Vec<Mat>]) -> Result<Vec<Vec<Mat>>> {
    let n = scattered.len();
    ensure!(scattered.iter().all(|s| s.len() == n), "ragged alltoall");
    Ok((0..n)
        .map(|d| (0..n).map(|s| scattered[s][d].clone()).collect())
        .collect())
}

/// The local-reduction half of the decoupled ReduceScatter.
pub fn local_reduce(received: &[Mat]) -> Mat {
    let mut acc = received[0].clone();
    for m in &received[1..] {
        for (a, b) in acc.data.iter_mut().zip(&m.data) {
            *a += b;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::prng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_vec(rows, cols, rng.normal_vec(rows * cols))
    }

    #[test]
    fn matmul_identity() {
        let mut eye = Mat::zeros(3, 3);
        for i in 0..3 {
            *eye.at_mut(i, i) = 1.0;
        }
        let mut rng = Rng::new(1);
        let a = rand_mat(&mut rng, 3, 3);
        assert_eq!(matmul(&a, &eye), a);
    }

    #[test]
    fn matmul_known_values() {
        // [[1,2],[3,4]] @ ones = [[3,3],[7,7]]
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ones = Mat::from_vec(2, 2, vec![1.0; 4]);
        assert_eq!(matmul(&a, &ones).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn rs_then_ag_is_allreduce() {
        forall(16, 0xAB, |rng| {
            let n = [2usize, 4][rng.below(2) as usize];
            let rows = n * rng.range(1, 4) as usize;
            let cols = rng.range(1, 6) as usize;
            let parts: Vec<Mat> =
                (0..n).map(|_| rand_mat(rng, rows, cols)).collect();
            let ar = all_reduce(&parts).unwrap();
            // Direct sum.
            let mut want = Mat::zeros(rows, cols);
            for p in &parts {
                for (w, v) in want.data.iter_mut().zip(&p.data) {
                    *w += v;
                }
            }
            for m in &ar {
                assert!(m.max_abs_diff(&want) < 1e-4);
            }
        });
    }

    #[test]
    fn alltoall_then_reduce_equals_reduce_scatter() {
        forall(16, 0xCD, |rng| {
            let n = [2usize, 4][rng.below(2) as usize];
            let block = rng.range(1, 4) as usize;
            let rows = n * block;
            let cols = rng.range(1, 5) as usize;
            let parts: Vec<Mat> =
                (0..n).map(|_| rand_mat(rng, rows, cols)).collect();
            // scattered[r][d] = rank r's rows owned by d.
            let scattered: Vec<Vec<Mat>> = parts
                .iter()
                .map(|p| {
                    (0..n)
                        .map(|d| p.row_slice(d * block, (d + 1) * block))
                        .collect()
                })
                .collect();
            let recv = all_to_all(&scattered).unwrap();
            let via = recv.iter().map(|r| local_reduce(r));
            let direct = reduce_scatter(&parts).unwrap();
            for (a, b) in via.zip(&direct) {
                assert!(a.max_abs_diff(b) < 1e-4);
            }
        });
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let shards = vec![
            Mat::from_vec(1, 2, vec![1.0, 2.0]),
            Mat::from_vec(1, 2, vec![3.0, 4.0]),
        ];
        let full = all_gather(&shards).unwrap();
        assert_eq!(full.len(), 2);
        assert_eq!(full[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(full[0], full[1]);
    }

    #[test]
    fn reduce_scatter_rejects_indivisible() {
        let parts = vec![Mat::zeros(3, 2), Mat::zeros(3, 2)];
        assert!(reduce_scatter(&parts).is_err());
    }
}
