//! Host-buffer collectives: the numeric substrate standing in for NCCL.
//!
//! Every collective operates on a `Vec` of per-rank row-major f32
//! matrices — "rank r's memory" is element r. The serving coordinator
//! uses these to combine per-rank PJRT partials, and the overlap numeric
//! twins are validated against them.

pub mod host;
pub mod timed;

pub use host::*;
