//! Step-level NCCL-style ring collectives simulated on the link graph.
//!
//! The closed forms in `cost::comm` price the non-overlapping baseline
//! cheaply; this module runs the *actual* ring schedule over
//! [`Net`](crate::sim::topology::Net) — every step's chunk transfer on
//! real link resources — and is cross-validated against the closed
//! forms (they must agree on contention-free topologies) and used where
//! link-level effects matter (PCIe NUMA crossings in rings).

use crate::sim::resources::Time;
use crate::sim::topology::Net;

/// Ring AllGather of a tensor of `total_bytes` across all `net.n` ranks:
/// (n-1) steps; at step s, rank r sends chunk ((r - s) mod n) to r+1.
/// Returns the completion time of the slowest rank.
pub fn ring_all_gather(net: &mut Net, total_bytes: f64, start: Time) -> Time {
    let n = net.n;
    if n == 1 {
        return start;
    }
    let chunk = total_bytes / n as f64;
    // have[r][c] = when rank r holds chunk c.
    let mut have = vec![vec![f64::INFINITY; n]; n];
    for (r, h) in have.iter_mut().enumerate() {
        h[r] = start;
    }
    let mut recv_free = vec![start; n];
    for s in 0..n - 1 {
        for r in 0..n {
            let src = (r + n - 1) % n;
            let c = (src + n - s) % n;
            let ready = have[src][c].max(recv_free[r]);
            debug_assert!(ready.is_finite(), "ring dependency violated");
            let (_, end) = net.transfer(src, r, chunk, ready);
            have[r][c] = end;
            recv_free[r] = end;
        }
    }
    (0..n)
        .map(|r| have[r].iter().cloned().fold(0.0, f64::max))
        .fold(0.0, f64::max)
}

/// Ring ReduceScatter: same wire pattern (reduction is free on the wire;
/// the add happens at line rate on arrival).
pub fn ring_reduce_scatter(
    net: &mut Net,
    total_bytes: f64,
    start: Time,
) -> Time {
    // The data-movement schedule is isomorphic to the AllGather ring
    // (each edge carries (n-1) chunks); reuse it.
    ring_all_gather(net, total_bytes, start)
}

/// Ring AllReduce = ReduceScatter then AllGather.
pub fn ring_all_reduce(net: &mut Net, total_bytes: f64, start: Time) -> Time {
    let t = ring_reduce_scatter(net, total_bytes, start);
    ring_all_gather(net, total_bytes, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE};
    use crate::cost::comm;
    use crate::sim::topology::Net;

    const MB: f64 = 1e6;

    #[test]
    fn nvlink_ring_matches_closed_form_shape() {
        // On a contention-free NVSwitch ring the step-level simulation
        // and the closed form agree within latency terms — but the
        // closed form uses the *measured NCCL bus bandwidth* (230 GB/s)
        // while the link-level ring rides raw 300 GB/s ports, so the
        // simulated ring is the faster of the two (ratio bounded).
        let mut net = Net::new(&A100_NVLINK, 8);
        let sim = ring_all_gather(&mut net, 200.0 * MB, 0.0);
        let closed = comm::ring_all_gather_ns(&A100_NVLINK, 8, 200.0 * MB);
        let ratio = closed / sim;
        assert!(
            (1.0..1.6).contains(&ratio),
            "sim {sim} vs closed {closed} (ratio {ratio})"
        );
    }

    #[test]
    fn ring_time_scales_linearly_in_bytes() {
        let t1 = {
            let mut net = Net::new(&A100_NVLINK, 8);
            ring_all_gather(&mut net, 100.0 * MB, 0.0)
        };
        let t2 = {
            let mut net = Net::new(&A100_NVLINK, 8);
            ring_all_gather(&mut net, 200.0 * MB, 0.0)
        };
        assert!(t2 > 1.7 * t1 && t2 < 2.3 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn pcie_ring_pays_the_numa_crossings() {
        // The ring's two NUMA-crossing edges are its bottleneck on the
        // PCIe box: slower than an NVLink ring by far more than the raw
        // port-bandwidth ratio alone.
        let pcie = {
            let mut net = Net::new(&A100_PCIE, 8);
            ring_all_gather(&mut net, 100.0 * MB, 0.0)
        };
        let nvl = {
            let mut net = Net::new(&A100_NVLINK, 8);
            ring_all_gather(&mut net, 100.0 * MB, 0.0)
        };
        assert!(pcie > 8.0 * nvl, "pcie {pcie} nvl {nvl}");
    }

    #[test]
    fn allreduce_is_two_phases() {
        let mut net = Net::new(&A100_NVLINK, 8);
        let ar = ring_all_reduce(&mut net, 64.0 * MB, 0.0);
        let mut net2 = Net::new(&A100_NVLINK, 8);
        let rs = ring_reduce_scatter(&mut net2, 64.0 * MB, 0.0);
        assert!(ar > 1.8 * rs && ar < 2.2 * rs);
    }

    #[test]
    fn single_rank_is_free() {
        let mut net = Net::new(&A100_NVLINK, 1);
        assert_eq!(ring_all_gather(&mut net, MB, 5.0), 5.0);
    }

    #[test]
    fn respects_start_time() {
        let mut net = Net::new(&A100_NVLINK, 4);
        let t = ring_all_gather(&mut net, MB, 1000.0);
        assert!(t > 1000.0);
    }
}
