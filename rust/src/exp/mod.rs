//! The experiment layer: declarative [`Scenario`]s executed by a
//! parallel, deterministic [`Runner`].
//!
//! Before this layer, the four end-to-end paths (`simulate --scale`,
//! `simulate --train`, `sweep-workloads`, `bench`) each hand-wired
//! topology x workload x method selection, report emission and CLI
//! plumbing. Now:
//!
//! * a [`Scenario`] *names* an experiment (mode, topology filter,
//!   workload, method set) and round-trips through JSON, so a new
//!   experiment is a checked-in `artifacts/scenario_*.json` file plus
//!   at most one registry line;
//! * [`Runner::run_matrix`] is the single execution substrate: every
//!   independent cell of the matrix runs on a `std::thread` worker and
//!   results merge in fixed scenario order, so every report is
//!   **byte-identical** to a sequential run at any `--threads` count;
//! * [`execute`] is the one CLI back end: it builds the report
//!   document through [`crate::report`], prints or writes it, and
//!   optionally re-simulates a single-topology scenario with DES
//!   tracing — `main.rs` only parses flags.

pub mod runner;
pub mod scenario;

pub use runner::{default_threads, Runner};
pub use scenario::{Mode, Scenario, WorkloadRef};

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use crate::report;
use crate::sim::trace::Trace;
use crate::util::json::{obj, Json};

/// Output plumbing shared by every experiment invocation.
#[derive(Clone, Debug, Default)]
pub struct ExecOpts {
    /// Write the JSON document instead of printing the table.
    pub json: bool,
    /// Report path (`--out`; implies `json` at the CLI).
    pub out: Option<PathBuf>,
    /// Chrome-trace path (single-topology scenarios only).
    pub trace: Option<PathBuf>,
    /// Telemetry path: also write a `flux-metrics-v1` document of the
    /// observed runs. Overrides the scenario's own `metrics` key.
    pub metrics: Option<PathBuf>,
    /// Worker threads for the cell matrix (`None` = one per core).
    pub threads: Option<usize>,
}

/// Execute a scenario end to end: build the report document (cells in
/// parallel), print or write it, then optionally capture the DES
/// trace of the single selected topology.
pub fn execute(sc: &Scenario, opts: &ExecOpts) -> Result<()> {
    if opts.trace.is_some() {
        // Check up front: a trace of a whole sweep would interleave
        // topologies into one meaningless timeline.
        ensure!(
            sc.topo_count()? == 1,
            "--trace needs --topo <name> (or a single-topology \
             scenario): a trace is one topology's event stream"
        );
    }
    let runner = Runner::from_flag(opts.threads);
    match (&sc.faults, sc.mode) {
        // Fault injection swaps the document wholesale: degradation
        // curves (flux-churn-v1) instead of the plain sweep.
        (Some(faults), _) => {
            let spec = faults.resolved()?;
            let doc = report::churn_doc_scenario(sc, &spec, &runner)?;
            emit(&doc, opts, report::print_churn, "churn")?;
        }
        (None, Mode::Serve) => {
            let doc = report::scale_doc_scenario(sc, &runner)?;
            emit(&doc, opts, report::print_scale, "scale")?;
        }
        (None, Mode::Train) => {
            let doc = report::train_doc_scenario(sc, &runner)?;
            emit(&doc, opts, report::print_train, "train")?;
        }
    }
    // `--metrics` beats the scenario's own `metrics` key; when both a
    // trace and metrics are requested, one combined capture serves
    // both files (so sampled gauges land in the trace as counters).
    let metrics_path = opts
        .metrics
        .clone()
        .or_else(|| sc.metrics.as_ref().map(PathBuf::from));
    match (&metrics_path, &opts.trace) {
        (Some(mp), tp) => {
            write_metrics(sc, mp, &runner, tp.as_deref())?;
        }
        (None, Some(tp)) => write_trace(sc, tp)?,
        (None, None) => {}
    }
    Ok(())
}

/// `flux sweep-workloads`: every workload preset on every serving
/// topology through the same runner.
pub fn execute_sweep(quick: bool, opts: &ExecOpts) -> Result<()> {
    let runner = Runner::from_flag(opts.threads);
    let doc = report::sweep_doc_with(quick, &runner)?;
    emit(&doc, opts, report::print_sweep, "workload sweep")
}

fn emit(
    doc: &Json,
    opts: &ExecOpts,
    print: fn(&Json) -> Result<()>,
    what: &str,
) -> Result<()> {
    if opts.json || opts.out.is_some() {
        let path = report::write_doc(doc, opts.out.as_deref())?;
        println!("wrote {what} report to {}", path.display());
    } else {
        print(doc)?;
    }
    Ok(())
}

/// Capture the DES stream of a single-topology scenario as a chrome
/// trace. Deliberately re-simulates the seeded comparison rather than
/// threading a `Trace` through the report emitters: the trace is
/// identical either way and the report path stays untangled from
/// tracing. The trace always records the mode's full standard
/// comparison (decoupled+flux / megatron+te+flux), independent of the
/// scenario's method set.
fn write_trace(sc: &Scenario, path: &Path) -> Result<()> {
    use crate::overlap::Method;
    let mut trace = Trace::new();
    // A faulted scenario traces the spec as written (intensity 1) —
    // the timeline the degradation curve's last point ran under.
    let spec = match &sc.faults {
        Some(f) => Some(f.resolved()?),
        None => None,
    };
    match sc.mode {
        Mode::Serve => {
            let cells = sc.serve_cells()?;
            match &spec {
                Some(spec) => {
                    let tl = spec.expand(cells[0].topo.dp, 1.0);
                    for (i, m) in Method::SERVE_SET.iter().enumerate() {
                        crate::serving::scale::run_scale_faulted_traced(
                            &cells[0],
                            *m,
                            &tl,
                            Some((&mut trace, i * cells[0].topo.dp)),
                        )?;
                    }
                }
                None => {
                    crate::serving::scale::compare_scale_traced(
                        &cells[0], &mut trace,
                    )?;
                }
            }
        }
        Mode::Train => {
            let cells = sc.train_cells()?;
            match &spec {
                Some(spec) => {
                    let tl = spec.expand(cells[0].topo.pp, 1.0);
                    let faults = (!tl.is_empty()).then_some(&tl);
                    for (i, m) in Method::TRAIN_SET.iter().enumerate() {
                        crate::training::run_train_with(
                            &cells[0],
                            *m,
                            faults,
                            Some((&mut trace, i * cells[0].topo.pp)),
                        )?;
                    }
                }
                None => {
                    crate::training::compare_train_traced(
                        &cells[0], &mut trace,
                    )?;
                }
            }
        }
    }
    trace.write(path)?;
    println!(
        "wrote chrome trace ({} events) to {}",
        trace.len(),
        path.display()
    );
    Ok(())
}

/// Build the scenario's telemetry as a `flux-metrics-v1` document:
/// one [`crate::obs::Metrics`] registry per (topology, method) cell,
/// filled by re-running the seeded simulations with the observer
/// attached — like [`write_trace`], the report emitters stay untangled
/// from the side channel. Cells run through the [`Runner`] and merge
/// in scenario order, so the document is byte-identical at any
/// `--threads` count. A faulted scenario observes the spec as written
/// (intensity 1), matching the trace semantics.
pub fn metrics_doc(sc: &Scenario, runner: &Runner) -> Result<Json> {
    let methods = sc.method_set();
    let spec = match &sc.faults {
        Some(f) => Some(f.resolved()?),
        None => None,
    };
    let cells_json: Vec<Json> = match sc.mode {
        Mode::Serve => runner
            .run_product(&sc.serve_cells()?, &methods, |c, m| {
                observe_serve_cell(spec.as_ref(), c, *m, None)
            })?
            .into_iter()
            .flatten()
            .collect(),
        Mode::Train => runner
            .run_product(&sc.train_cells()?, &methods, |c, m| {
                observe_train_cell(spec.as_ref(), c, *m, None)
            })?
            .into_iter()
            .flatten()
            .collect(),
    };
    Ok(metrics_doc_from_cells(sc, cells_json))
}

/// Assemble the document envelope around the observed cells
/// (alphabetical keys, `scenario` stamped only when named).
fn metrics_doc_from_cells(sc: &Scenario, cells: Vec<Json>) -> Json {
    let mut fields = vec![
        ("cells", Json::Arr(cells)),
        ("mode", Json::from(sc.mode.name())),
        ("quick", Json::from(sc.quick)),
        ("schema", Json::from(report::METRICS_SCHEMA)),
    ];
    if !sc.name.is_empty() {
        fields.push(("scenario", Json::from(sc.name.as_str())));
    }
    obj(fields)
}

/// Write the [`metrics_doc`] to `path`. When `trace_path` is also set
/// (the `--trace --metrics` combination, single-topology by the
/// [`execute`] check), the capture instead runs sequentially through
/// one [`Trace`] so the sampled gauges additionally emit chrome
/// counter (`"C"`) events, and both files come from the same runs.
fn write_metrics(
    sc: &Scenario,
    path: &Path,
    runner: &Runner,
    trace_path: Option<&Path>,
) -> Result<()> {
    let doc = match trace_path {
        None => metrics_doc(sc, runner)?,
        Some(tp) => {
            let methods = sc.method_set();
            let spec = match &sc.faults {
                Some(f) => Some(f.resolved()?),
                None => None,
            };
            let mut tr = Trace::new();
            let mut cells_json = Vec::new();
            match sc.mode {
                Mode::Serve => {
                    let cells = sc.serve_cells()?;
                    for (i, m) in methods.iter().enumerate() {
                        let pid0 = i * cells[0].topo.dp;
                        cells_json.push(observe_serve_cell(
                            spec.as_ref(),
                            &cells[0],
                            *m,
                            Some((&mut tr, pid0)),
                        )?);
                    }
                }
                Mode::Train => {
                    let cells = sc.train_cells()?;
                    for (i, m) in methods.iter().enumerate() {
                        let pid0 = i * cells[0].topo.pp;
                        cells_json.push(observe_train_cell(
                            spec.as_ref(),
                            &cells[0],
                            *m,
                            Some((&mut tr, pid0)),
                        )?);
                    }
                }
            }
            tr.write(tp)?;
            println!(
                "wrote chrome trace ({} events) to {}",
                tr.len(),
                tp.display()
            );
            metrics_doc_from_cells(sc, cells_json)
        }
    };
    let n_cells = doc.get("cells")?.as_arr()?.len();
    crate::util::fsio::write_text(path, &doc.to_string())?;
    println!("wrote metrics ({n_cells} cells) to {}", path.display());
    Ok(())
}

/// One observed serve cell of the metrics document: fresh registry
/// seeded by the cell's own seed, faulted scenarios at intensity 1.
fn observe_serve_cell(
    spec: Option<&crate::faults::FaultSpec>,
    cell: &crate::serving::scale::ScaleScenario,
    m: crate::overlap::Method,
    trace: Option<(&mut Trace, usize)>,
) -> Result<Json> {
    let tl = spec.map(|s| s.expand(cell.topo.dp, 1.0));
    let faults = tl.as_ref().filter(|t| !t.is_empty());
    let mut metrics = crate::obs::Metrics::new(cell.seed);
    crate::serving::scale::run_scale_observed(
        cell,
        m,
        faults,
        trace,
        Some(&mut metrics),
    )?;
    Ok(metrics.to_json_with(vec![
        ("method", Json::from(m.key())),
        ("topology", Json::from(cell.topo.name)),
    ]))
}

/// One observed train cell: like [`observe_serve_cell`] but the fault
/// spec expands over pipeline stages.
fn observe_train_cell(
    spec: Option<&crate::faults::FaultSpec>,
    cell: &crate::training::TrainScenario,
    m: crate::overlap::Method,
    trace: Option<(&mut Trace, usize)>,
) -> Result<Json> {
    let tl = spec.map(|s| s.expand(cell.topo.pp, 1.0));
    let faults = tl.as_ref().filter(|t| !t.is_empty());
    let mut metrics = crate::obs::Metrics::new(cell.seed);
    crate::training::run_train_observed(
        cell,
        m,
        faults,
        trace,
        Some(&mut metrics),
    )?;
    Ok(metrics.to_json_with(vec![
        ("method", Json::from(m.key())),
        ("topology", Json::from(cell.topo.name)),
    ]))
}
