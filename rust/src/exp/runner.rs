//! Deterministic parallel execution of independent experiment cells.
//!
//! Every cell of the method x topology x workload matrix is a seeded,
//! self-contained DES (or op-level) run: cells share no mutable state,
//! so they can execute on different threads and still produce the
//! exact f64s a sequential sweep produces. [`Runner::run_matrix`]
//! exploits that: `std::thread` workers (no external thread-pool
//! dependency) claim cells off an atomic counter, and results are
//! merged back **in input order** — so every report stays
//! byte-identical to the single-threaded emission no matter how the
//! OS schedules the workers. The flux-scale-v2 / flux-train-v1 /
//! flux-sweep-v1 / flux-bench-v1 compat tests are the safety net, and
//! `tests/exp.rs` pins parallel == sequential across thread counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// One result slot per cell, filled by whichever worker ran the cell.
type Slot<T> = Mutex<Option<Result<T>>>;

/// Executes experiment cells, in parallel when configured with more
/// than one worker. The worker count never changes *what* is computed
/// — only the wall-clock time of the matrix.
#[derive(Clone, Copy, Debug)]
pub struct Runner {
    threads: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Runner::new()
    }
}

impl Runner {
    /// One worker per core the OS reports (`--threads <n>` overrides;
    /// 1 forces the sequential path).
    pub fn new() -> Runner {
        Runner::with_threads(default_threads())
    }

    pub fn with_threads(threads: usize) -> Runner {
        Runner { threads: threads.max(1) }
    }

    /// Resolve the optional `--threads` CLI flag.
    pub fn from_flag(threads: Option<usize>) -> Runner {
        match threads {
            Some(n) => Runner::with_threads(n),
            None => Runner::new(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over the `cells` x `per` cross product at job grain
    /// (every pair is one worker job), handing back one `Vec<T>` per
    /// cell in cell order, `per` order within. The shared
    /// orchestration of the scale and train documents: even a
    /// single-cell scenario spreads its method set across workers.
    pub fn run_product<C, M, T>(
        &self,
        cells: &[C],
        per: &[M],
        f: impl Fn(&C, &M) -> Result<T> + Sync,
    ) -> Result<Vec<Vec<T>>>
    where
        C: Sync,
        M: Sync,
        T: Send,
    {
        let jobs: Vec<(usize, usize)> = (0..cells.len())
            .flat_map(|i| (0..per.len()).map(move |j| (i, j)))
            .collect();
        let flat =
            self.run_matrix(&jobs, |&(i, j)| f(&cells[i], &per[j]))?;
        let mut it = flat.into_iter();
        let mut out = Vec::with_capacity(cells.len());
        for _ in 0..cells.len() {
            out.push(it.by_ref().take(per.len()).collect());
        }
        Ok(out)
    }

    /// Map `f` over `cells`, in parallel when more than one worker is
    /// configured. Results come back in cell order regardless of which
    /// worker ran which cell, and on failure the first failing cell
    /// **by input order** wins — errors are as deterministic as
    /// successes. A cell that *panics* (rather than returning `Err`)
    /// is caught and reported the same way, naming the cell index: a
    /// bug in one cell must not tear down the whole matrix with an
    /// unordered worker-thread abort.
    pub fn run_matrix<C, T>(
        &self,
        cells: &[C],
        f: impl Fn(&C) -> Result<T> + Sync,
    ) -> Result<Vec<T>>
    where
        C: Sync,
        T: Send,
    {
        let run = |i: usize| -> Result<T> {
            let caught = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| f(&cells[i])),
            );
            match caught {
                Ok(out) => out,
                Err(payload) => Err(anyhow::anyhow!(
                    "cell {i} panicked: {}",
                    panic_text(payload.as_ref())
                )),
            }
        };
        let workers = self.threads.min(cells.len());
        if workers <= 1 {
            return (0..cells.len()).map(run).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Slot<T>> =
            cells.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let out = run(i);
                    *slots[i].lock().expect("cell slot poisoned") =
                        Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .expect("cell slot poisoned")
                    .expect("every cell below len is claimed exactly once")
            })
            .collect()
    }
}

/// Best-effort text of a caught panic payload (`panic!` with a string
/// literal or a formatted message covers virtually every real panic).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Default worker count: one per core the OS reports.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_cell_order() {
        let cells: Vec<usize> = (0..33).collect();
        for threads in [1, 2, 8, 64] {
            let out = Runner::with_threads(threads)
                .run_matrix(&cells, |&i| Ok(i * i))
                .unwrap();
            let want: Vec<usize> = cells.iter().map(|i| i * i).collect();
            assert_eq!(out, want, "{threads} threads");
        }
    }

    #[test]
    fn first_failing_cell_by_input_order_wins() {
        let cells: Vec<usize> = (0..64).collect();
        for threads in [1, 7] {
            let err = Runner::with_threads(threads)
                .run_matrix(&cells, |&i| {
                    if i >= 10 {
                        anyhow::bail!("cell {i} failed")
                    }
                    Ok(i)
                })
                .unwrap_err();
            assert_eq!(err.to_string(), "cell 10 failed", "{threads}");
        }
    }

    #[test]
    fn worker_panics_surface_as_the_first_failing_cell() {
        // A panicking cell used to abort the worker thread and tear
        // down the whole scope with an unordered re-panic; now it is
        // an ordinary error, merged by input order like any `Err`.
        let cells: Vec<usize> = (0..64).collect();
        for threads in [1, 7] {
            let err = Runner::with_threads(threads)
                .run_matrix(&cells, |&i| {
                    if i == 12 || i == 40 {
                        panic!("boom in cell {i}");
                    }
                    Ok(i)
                })
                .unwrap_err();
            assert_eq!(
                err.to_string(),
                "cell 12 panicked: boom in cell 12",
                "{threads} threads"
            );
        }
    }

    #[test]
    fn run_product_chunks_per_cell_in_order() {
        let cells = [10usize, 20, 30];
        let per = ["a", "b"];
        for threads in [1, 4] {
            let out = Runner::with_threads(threads)
                .run_product(&cells, &per, |&c, &m| {
                    Ok(format!("{c}{m}"))
                })
                .unwrap();
            assert_eq!(
                out,
                vec![
                    vec!["10a".to_string(), "10b".to_string()],
                    vec!["20a".to_string(), "20b".to_string()],
                    vec!["30a".to_string(), "30b".to_string()],
                ],
                "{threads} threads"
            );
        }
    }

    #[test]
    fn zero_threads_clamps_to_sequential_and_empty_is_fine() {
        let r = Runner::with_threads(0);
        assert_eq!(r.threads(), 1);
        let out: Vec<usize> =
            r.run_matrix(&Vec::<usize>::new(), |&i| Ok(i)).unwrap();
        assert!(out.is_empty());
        assert!(Runner::from_flag(None).threads() >= 1);
        assert_eq!(Runner::from_flag(Some(3)).threads(), 3);
        assert!(default_threads() >= 1);
    }
}
