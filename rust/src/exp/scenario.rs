//! Declarative experiment scenarios.
//!
//! A [`Scenario`] is pure data: a mode (serve/train), a topology
//! filter over the [`crate::cost::arch`] registries, a request source
//! (serve mode: a workload preset name or an inline
//! [`WorkloadSpec`]), and an overlap [`Method`] set. It
//! parses/serializes through `util/json` exactly like `WorkloadSpec`,
//! so a scenario is a checked-in JSON file (`flux scenario
//! artifacts/scenario_*.json`) instead of a 5-file code edit; the
//! `simulate --scale` / `--train` CLI paths build anonymous scenarios
//! from their flags and go through the same expansion.
//!
//! Expansion is deliberately dumb: [`Scenario::serve_cells`] /
//! [`Scenario::train_cells`] produce the concrete per-topology DES
//! scenarios in **topology-registry order** (the order every report
//! has always emitted), and the [`crate::exp::Runner`] executes them
//! — the single place a scenario becomes a DES run.

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cost::arch::{
    ScaleTopology, TrainTopology, ALL_FLEET_TOPOLOGIES,
    ALL_SCALE_TOPOLOGIES, ALL_TRAIN_TOPOLOGIES,
};
use crate::faults::FaultsRef;
use crate::overlap::Method;
use crate::serving::scale::ScaleScenario;
use crate::training::TrainScenario;
use crate::util::json::{obj, Json};
use crate::util::stats::PercentileMode;
use crate::workload::{self, WorkloadSpec};

/// Which end-to-end path a scenario drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Multi-node TP x DP serving (`flux-scale-v2` documents).
    Serve,
    /// Event-driven DP x PP x TP training (`flux-train-v1` documents).
    Train,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Serve => "serve",
            Mode::Train => "train",
        }
    }

    pub fn from_name(name: &str) -> Result<Mode> {
        match name {
            "serve" => Ok(Mode::Serve),
            "train" => Ok(Mode::Train),
            _ => bail!("unknown mode {name:?} (serve|train)"),
        }
    }
}

/// The request source of a serve scenario: a preset by name (resolved
/// at expansion time, so `quick` picks the preset's CI-sized variant)
/// or an inline spec.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadRef {
    Preset(String),
    Inline(WorkloadSpec),
}

/// One declarative experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Scenario files carry a name (stamped into the report as
    /// `"scenario"`); CLI-built anonymous scenarios leave it empty and
    /// the report stays exactly its historical shape.
    pub name: String,
    pub mode: Mode,
    /// Topology filter (registry names, any spelling
    /// [`ScaleTopology::by_name`] accepts); `None` runs every topology
    /// of the mode and the report carries no `topo_filter`.
    pub topos: Option<Vec<String>>,
    /// Serve-mode request source; `None` = each topology's default
    /// preset (quick or full per [`Scenario::quick`]). Note `quick`
    /// resizes *presets* only — an inline spec carries explicit counts
    /// and runs as written (the historical `--workload file.json
    /// --quick` semantics), while the document's `quick` flag keeps
    /// recording the requested trim.
    pub workload: Option<WorkloadRef>,
    /// Overlap methods to run; `None` = the mode's default set
    /// ([`Method::SERVE_SET`] / [`Method::TRAIN_SET`]).
    pub methods: Option<Vec<Method>>,
    /// Optional fault injection: a preset name or an inline
    /// [`crate::faults::FaultSpec`]. Presence switches the report to
    /// the `flux-churn-v1` degradation document; absence keeps every
    /// historical document byte-identical.
    pub faults: Option<FaultsRef>,
    /// Optional telemetry output path: when set, executing the
    /// scenario also writes a `flux-metrics-v1` document there (the
    /// `--metrics <path>` CLI flag overrides it). Absence keeps every
    /// run byte-identical to the pre-observability binary.
    pub metrics: Option<String>,
    /// Serve-mode percentile accounting (`percentiles` key:
    /// `"exact"` | `"sketch"`). `Exact` (the default, omitted from
    /// JSON) buffers every sample and keeps all pinned report bytes;
    /// `Sketch` additionally folds samples into a constant-space
    /// fixed-boundary sketch surfaced as additive `*_sketch` fields.
    pub percentiles: PercentileMode,
    pub quick: bool,
}

impl Scenario {
    /// The `simulate --scale` CLI invocation as an anonymous scenario.
    pub fn serve(
        only: Option<&'static ScaleTopology>,
        workload: Option<WorkloadSpec>,
        quick: bool,
    ) -> Scenario {
        Scenario {
            name: String::new(),
            mode: Mode::Serve,
            topos: only.map(|t| vec![t.name.to_string()]),
            workload: workload.map(WorkloadRef::Inline),
            methods: None,
            faults: None,
            metrics: None,
            percentiles: PercentileMode::Exact,
            quick,
        }
    }

    /// The `simulate --train` CLI invocation as an anonymous scenario.
    pub fn train(
        only: Option<&'static TrainTopology>,
        quick: bool,
    ) -> Scenario {
        Scenario {
            name: String::new(),
            mode: Mode::Train,
            topos: only.map(|t| vec![t.name.to_string()]),
            workload: None,
            methods: None,
            faults: None,
            metrics: None,
            percentiles: PercentileMode::Exact,
            quick,
        }
    }

    /// [`Scenario::serve`] with the topology still a CLI string;
    /// unknown names fail with the registry listing.
    pub fn serve_cli(
        topo: Option<&str>,
        workload: Option<WorkloadSpec>,
        quick: bool,
    ) -> Result<Scenario> {
        let only = match topo {
            Some(name) => Some(scale_topo(name)?),
            None => None,
        };
        Ok(Scenario::serve(only, workload, quick))
    }

    /// [`Scenario::train`] with the topology still a CLI string.
    pub fn train_cli(topo: Option<&str>, quick: bool) -> Result<Scenario> {
        let only = match topo {
            Some(name) => Some(train_topo(name)?),
            None => None,
        };
        Ok(Scenario::train(only, quick))
    }

    /// The method set to execute (mode default when unspecified).
    pub fn method_set(&self) -> Vec<Method> {
        match &self.methods {
            Some(ms) => ms.clone(),
            None => match self.mode {
                Mode::Serve => Method::SERVE_SET.to_vec(),
                Mode::Train => Method::TRAIN_SET.to_vec(),
            },
        }
    }

    /// The serve-mode topology selection, in `ALL_SCALE_TOPOLOGIES`
    /// order (report order is registry order regardless of how the
    /// filter lists names).
    pub fn scale_topos(&self) -> Result<Vec<&'static ScaleTopology>> {
        ensure!(
            self.mode == Mode::Serve,
            "scenario {:?}: not a serve scenario",
            self.name
        );
        match &self.topos {
            None => Ok(ALL_SCALE_TOPOLOGIES.to_vec()),
            Some(filter) => {
                // `resolve_filter` intersects the picks with `all` to
                // impose registry order, so the fleet pools must be in
                // the slice — otherwise a filtered fleet selection
                // would resolve and then silently vanish.
                let mut all = ALL_SCALE_TOPOLOGIES.to_vec();
                all.extend(ALL_FLEET_TOPOLOGIES);
                resolve_filter(&self.name, filter, &all, scale_topo, |t| {
                    t.name
                })
            }
        }
    }

    /// The train-mode topology selection, in `ALL_TRAIN_TOPOLOGIES`
    /// order.
    pub fn train_topos(&self) -> Result<Vec<&'static TrainTopology>> {
        ensure!(
            self.mode == Mode::Train,
            "scenario {:?}: not a train scenario",
            self.name
        );
        match &self.topos {
            None => Ok(ALL_TRAIN_TOPOLOGIES.to_vec()),
            Some(filter) => resolve_filter(
                &self.name,
                filter,
                &ALL_TRAIN_TOPOLOGIES,
                train_topo,
                |t| t.name,
            ),
        }
    }

    /// How many topologies the scenario selects (any mode).
    pub fn topo_count(&self) -> Result<usize> {
        Ok(match self.mode {
            Mode::Serve => self.scale_topos()?.len(),
            Mode::Train => self.train_topos()?.len(),
        })
    }

    /// Canonical registry names of the topology filter, `None` when
    /// the scenario runs every topology (reports emit `topo_filter`
    /// only for filtered runs — the trajectory-diffing contract).
    pub fn topo_filter_names(&self) -> Result<Option<Vec<&'static str>>> {
        if self.topos.is_none() {
            return Ok(None);
        }
        Ok(Some(match self.mode {
            Mode::Serve => {
                self.scale_topos()?.iter().map(|t| t.name).collect()
            }
            Mode::Train => {
                self.train_topos()?.iter().map(|t| t.name).collect()
            }
        }))
    }

    /// The `workload_filter` value the report carries (`None` when the
    /// scenario runs each topology's default workload).
    pub fn workload_name(&self) -> Option<&str> {
        match &self.workload {
            Some(WorkloadRef::Preset(n)) => Some(n),
            Some(WorkloadRef::Inline(s)) => Some(&s.name),
            None => None,
        }
    }

    /// Resolve the request source to a concrete spec (serve mode);
    /// `None` means "each topology's default preset".
    fn resolved_workload(&self) -> Result<Option<WorkloadSpec>> {
        match &self.workload {
            Some(WorkloadRef::Preset(name)) => Ok(Some(
                workload::preset(name, self.quick).ok_or_else(|| {
                    anyhow!(
                        "scenario {:?}: unknown workload preset {name:?} \
                         (one of: {})",
                        self.name,
                        workload::PRESET_NAMES.join(" | ")
                    )
                })?,
            )),
            Some(WorkloadRef::Inline(spec)) => Ok(Some(spec.clone())),
            None => Ok(None),
        }
    }

    /// Expand into the per-topology serving scenarios, registry order.
    pub fn serve_cells(&self) -> Result<Vec<ScaleScenario>> {
        let wl = self.resolved_workload()?;
        Ok(self
            .scale_topos()?
            .into_iter()
            .map(|topo| {
                let cell = match &wl {
                    Some(wl) => {
                        ScaleScenario::with_workload(topo, wl.clone())
                    }
                    None if self.quick => ScaleScenario::quick(topo),
                    None => ScaleScenario::full(topo),
                };
                cell.with_percentiles(self.percentiles)
            })
            .collect())
    }

    /// Expand into the per-topology training scenarios, registry order.
    pub fn train_cells(&self) -> Result<Vec<TrainScenario>> {
        Ok(self
            .train_topos()?
            .into_iter()
            .map(|topo| {
                if self.quick {
                    TrainScenario::quick(topo)
                } else {
                    TrainScenario::full(topo)
                }
            })
            .collect())
    }

    /// Check everything a scenario file can get wrong: mode/workload
    /// consistency, method-set shape, topology and preset names.
    pub fn validate(&self) -> Result<()> {
        if self.mode == Mode::Train {
            ensure!(
                self.workload.is_none(),
                "scenario {:?}: train mode takes no workload",
                self.name
            );
            ensure!(
                self.percentiles == PercentileMode::Exact,
                "scenario {:?}: \"percentiles\" applies to serve mode \
                 only (train reports carry no percentile blocks)",
                self.name
            );
        }
        let ms = self.method_set();
        ensure!(
            !ms.is_empty(),
            "scenario {:?}: empty method set",
            self.name
        );
        ensure!(
            ms.contains(&Method::NonOverlap),
            "scenario {:?}: the method set must include \"baseline\" \
             (the reference the speedup and efficiency fields divide by)",
            self.name
        );
        match self.mode {
            // The serve table/speedup fields read the decoupled and
            // flux blocks; the train table reads all three. Scenario
            // sets may only extend these, never drop them.
            Mode::Serve => ensure!(
                ms.contains(&Method::Flux),
                "scenario {:?}: serve method sets must include \
                 \"flux\" (the table and speedup fields read it)",
                self.name
            ),
            Mode::Train => {
                for m in Method::TRAIN_SET {
                    ensure!(
                        ms.contains(&m),
                        "scenario {:?}: train method sets must \
                         include {:?} (the table reads all three)",
                        self.name,
                        m.key()
                    );
                }
            }
        }
        for (i, m) in ms.iter().enumerate() {
            ensure!(
                !ms[..i].contains(m),
                "scenario {:?}: duplicate method {:?}",
                self.name,
                m.key()
            );
        }
        match self.mode {
            Mode::Serve => {
                self.scale_topos()?;
                self.resolved_workload()?;
            }
            Mode::Train => {
                self.train_topos()?;
            }
        }
        if let Some(f) = &self.faults {
            // Unknown presets and malformed inline specs fail here
            // with the fault layer's pointed errors, not mid-run.
            f.resolved().with_context(|| {
                format!("scenario {:?}", self.name)
            })?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::from(self.name.as_str())),
            ("mode", Json::from(self.mode.name())),
            ("quick", Json::from(self.quick)),
        ];
        if let Some(topos) = &self.topos {
            fields.push((
                "topologies",
                Json::Arr(
                    topos.iter().map(|t| Json::from(t.as_str())).collect(),
                ),
            ));
        }
        match &self.workload {
            Some(WorkloadRef::Preset(n)) => {
                fields.push(("workload", Json::from(n.as_str())));
            }
            Some(WorkloadRef::Inline(s)) => {
                fields.push(("workload", s.to_json()));
            }
            None => {}
        }
        if let Some(ms) = &self.methods {
            fields.push((
                "methods",
                Json::Arr(ms.iter().map(|m| Json::from(m.key())).collect()),
            ));
        }
        if let Some(f) = &self.faults {
            fields.push(("faults", f.to_json()));
        }
        if let Some(p) = &self.metrics {
            fields.push(("metrics", Json::from(p.as_str())));
        }
        // `exact` is the default and stays implicit: existing files
        // (and their byte-stable round trips) never see the key.
        if self.percentiles == PercentileMode::Sketch {
            fields.push(("percentiles", Json::from("sketch")));
        }
        obj(fields)
    }

    /// Parse (and validate) a scenario document. Bad modes, methods,
    /// topology and preset names are rejected here with pointed errors
    /// instead of surfacing mid-run.
    pub fn from_json(j: &Json) -> Result<Scenario> {
        let name = j.get("name")?.as_str()?.to_string();
        ensure!(!name.is_empty(), "scenario name must be non-empty");
        let ctx = || format!("scenario {name:?}");
        let sc = Scenario {
            mode: Mode::from_name(j.get("mode")?.as_str()?)
                .with_context(ctx)?,
            quick: match j.opt("quick") {
                Some(q) => q.as_bool().with_context(ctx)?,
                None => false,
            },
            topos: match j.opt("topologies") {
                Some(t) => {
                    let mut names = Vec::new();
                    for x in t.as_arr().with_context(ctx)? {
                        names.push(
                            x.as_str().with_context(ctx)?.to_string(),
                        );
                    }
                    Some(names)
                }
                None => None,
            },
            workload: match j.opt("workload") {
                Some(Json::Str(s)) => Some(WorkloadRef::Preset(s.clone())),
                Some(w) => Some(WorkloadRef::Inline(
                    WorkloadSpec::from_json(w).with_context(ctx)?,
                )),
                None => None,
            },
            faults: match j.opt("faults") {
                Some(f) => Some(
                    FaultsRef::from_json(f).with_context(ctx)?,
                ),
                None => None,
            },
            metrics: match j.opt("metrics") {
                Some(p) => {
                    let p = p.as_str().with_context(ctx)?;
                    ensure!(
                        !p.is_empty(),
                        "{}: \"metrics\" path must be non-empty",
                        ctx()
                    );
                    Some(p.to_string())
                }
                None => None,
            },
            percentiles: match j.opt("percentiles") {
                Some(p) => {
                    PercentileMode::from_name(p.as_str().with_context(ctx)?)
                        .with_context(ctx)?
                }
                None => PercentileMode::Exact,
            },
            methods: match j.opt("methods") {
                Some(ms) => {
                    let mut out = Vec::new();
                    for m in ms.as_arr().with_context(ctx)? {
                        let key = m.as_str().with_context(ctx)?;
                        out.push(Method::by_key(key).ok_or_else(|| {
                            anyhow!(
                                "{}: unknown method {key:?} (one of: {})",
                                ctx(),
                                Method::keys().join(" | ")
                            )
                        })?);
                    }
                    Some(out)
                }
                None => None,
            },
            name,
        };
        sc.validate()?;
        Ok(sc)
    }

    /// Parse a scenario file from disk.
    pub fn load(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!("reading scenario file {}", path.display())
        })?;
        let j = Json::parse(&text).with_context(|| {
            format!("parsing scenario file {}", path.display())
        })?;
        Scenario::from_json(&j).with_context(|| {
            format!("validating scenario file {}", path.display())
        })
    }
}

/// Resolve a topology filter against one registry: every name must
/// look up, duplicates collapse, and the selection comes back in
/// **registry order** (the order every report has always emitted),
/// not filter order.
fn resolve_filter<T>(
    scenario: &str,
    filter: &[String],
    all: &[&'static T],
    by_name: impl Fn(&str) -> Result<&'static T>,
    name_of: impl Fn(&'static T) -> &'static str,
) -> Result<Vec<&'static T>> {
    ensure!(
        !filter.is_empty(),
        "scenario {scenario:?}: empty topology filter"
    );
    let mut picked: Vec<&'static T> = Vec::new();
    for name in filter {
        let t = by_name(name)
            .with_context(|| format!("scenario {scenario:?}"))?;
        if !picked.iter().any(|p| name_of(p) == name_of(t)) {
            picked.push(t);
        }
    }
    Ok(all
        .iter()
        .copied()
        .filter(|t| picked.iter().any(|p| name_of(p) == name_of(*t)))
        .collect())
}

fn scale_topo(name: &str) -> Result<&'static ScaleTopology> {
    ScaleTopology::by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown topology {name:?}; one of: {} | fleet \
             <nvlink|pcie|h800> tp8 dp<8|16|32|64|128|256>",
            ALL_SCALE_TOPOLOGIES
                .iter()
                .map(|t| t.name)
                .collect::<Vec<_>>()
                .join(" | ")
        )
    })
}

fn train_topo(name: &str) -> Result<&'static TrainTopology> {
    TrainTopology::by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown topology {name:?}; one of: {}",
            ALL_TRAIN_TOPOLOGIES
                .iter()
                .map(|t| t.name)
                .collect::<Vec<_>>()
                .join(" | ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{SCALE_TP8, TRAIN_PCIE_128};

    fn named() -> Scenario {
        Scenario {
            name: "demo".into(),
            mode: Mode::Serve,
            topos: Some(vec!["1-node-tp8".into()]),
            workload: Some(WorkloadRef::Preset("bursty-decode".into())),
            methods: Some(vec![
                Method::NonOverlap,
                Method::Medium,
                Method::Flux,
            ]),
            faults: None,
            metrics: None,
            percentiles: PercentileMode::Exact,
            quick: true,
        }
    }

    #[test]
    fn json_round_trips_byte_stably() {
        for sc in [
            named(),
            Scenario {
                name: "sketchy".into(),
                percentiles: PercentileMode::Sketch,
                ..named()
            },
            Scenario {
                name: "inline".into(),
                workload: Some(WorkloadRef::Inline(
                    crate::workload::preset("steady-decode", true).unwrap(),
                )),
                topos: None,
                methods: None,
                ..named()
            },
            Scenario {
                name: "churny".into(),
                faults: Some(FaultsRef::Preset("replica-churn".into())),
                ..named()
            },
            Scenario {
                name: "churny-inline".into(),
                faults: Some(FaultsRef::Inline(
                    crate::faults::preset("straggler-storm").unwrap(),
                )),
                ..named()
            },
            Scenario {
                name: "train".into(),
                mode: Mode::Train,
                topos: Some(vec![TRAIN_PCIE_128.name.to_string()]),
                workload: None,
                methods: None,
                faults: None,
                metrics: Some("out/metrics.json".into()),
                percentiles: PercentileMode::Exact,
                quick: false,
            },
        ] {
            let text = sc.to_json().to_string();
            let parsed =
                Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(parsed, sc);
            assert_eq!(parsed.to_json().to_string(), text);
        }
    }

    #[test]
    fn cells_expand_in_registry_order_with_quick_sizing() {
        let all = Scenario::serve(None, None, true);
        let cells = all.serve_cells().unwrap();
        assert_eq!(cells.len(), ALL_SCALE_TOPOLOGIES.len());
        for (cell, topo) in cells.iter().zip(ALL_SCALE_TOPOLOGIES) {
            assert_eq!(cell.topo.name, topo.name);
            assert_eq!(cell.workload.name, "poisson-balanced");
        }
        // Filter: one topology, preset resolved at the quick size.
        let one = named();
        let cells = one.serve_cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].topo.name, SCALE_TP8.name);
        assert_eq!(cells[0].workload.name, "bursty-decode");
        assert_eq!(
            cells[0].workload,
            crate::workload::preset("bursty-decode", true).unwrap()
        );
        assert_eq!(
            one.topo_filter_names().unwrap().unwrap(),
            vec![SCALE_TP8.name]
        );
        assert_eq!(all.topo_filter_names().unwrap(), None);
        // Train cells honor quick/full.
        let tr = Scenario::train(Some(&TRAIN_PCIE_128), false);
        let cells = tr.train_cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].microbatches, 16, "full plan");
    }

    #[test]
    fn fleet_topologies_resolve_through_the_filter() {
        // Fleet pools are addressable by scenario files and `--topo`
        // without living in the default registry: a mixed filter
        // resolves both, built-ins first (registry order), and the
        // expansion carries the fleet DP width into the cell.
        let sc = Scenario {
            name: "fleet".into(),
            topos: Some(vec![
                "fleet-nvlink-tp8-dp64".into(),
                "1-node tp8".into(),
            ]),
            ..named()
        };
        sc.validate().unwrap();
        let names: Vec<&str> =
            sc.scale_topos().unwrap().iter().map(|t| t.name).collect();
        assert_eq!(names, vec!["1-node tp8", "fleet nvlink tp8 dp64"]);
        let cells = sc.serve_cells().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[1].topo.dp, 64);
        assert_eq!(
            sc.topo_filter_names().unwrap().unwrap(),
            vec!["1-node tp8", "fleet nvlink tp8 dp64"]
        );
    }

    #[test]
    fn percentile_mode_reaches_the_expanded_cells() {
        let mut sc = named();
        assert_eq!(
            sc.serve_cells().unwrap()[0].percentiles,
            PercentileMode::Exact
        );
        sc.percentiles = PercentileMode::Sketch;
        sc.validate().unwrap();
        assert_eq!(
            sc.serve_cells().unwrap()[0].percentiles,
            PercentileMode::Sketch
        );
        // The explicit spelling of the default parses too (and stays
        // implicit on re-serialization).
        let text = r#"{"name": "ok", "mode": "serve",
                       "percentiles": "exact"}"#;
        let parsed =
            Scenario::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(parsed.percentiles, PercentileMode::Exact);
        assert!(!parsed.to_json().to_string().contains("percentiles"));
    }

    #[test]
    fn default_method_sets_follow_the_mode() {
        assert_eq!(
            Scenario::serve(None, None, true).method_set(),
            Method::SERVE_SET.to_vec()
        );
        assert_eq!(
            Scenario::train(None, true).method_set(),
            Method::TRAIN_SET.to_vec()
        );
    }

    #[test]
    fn from_json_rejects_bad_scenarios_with_pointed_errors() {
        let bad = |patch: &str, needle: &str| {
            let text = format!(
                r#"{{"name": "bad", "mode": "serve", {patch}}}"#
            );
            let err = Scenario::from_json(&Json::parse(&text).unwrap())
                .map(|_| ())
                .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{patch}: {msg}");
        };
        bad(r#""methods": ["warp"]"#, "unknown method");
        bad(r#""methods": ["flux"]"#, "baseline");
        bad(
            r#""methods": ["baseline", "flux", "baseline"]"#,
            "duplicate",
        );
        bad(r#""methods": ["baseline", "medium"]"#, "flux");
        bad(r#""topologies": ["warp-drive"]"#, "unknown topology");
        bad(r#""topologies": []"#, "empty topology filter");
        bad(r#""workload": "mystery""#, "unknown workload preset");
        bad(r#""faults": "mystery""#, "unknown fault preset");
        bad(r#""percentiles": "tdigest""#, "unknown percentile mode");
        bad(r#""faults": 7"#, "preset name or an inline fault");
        bad(
            r#""faults": {"name": "bad", "seed": 1,
                "kills": [{"at_ns": -1.0, "downtime_ns": 5.0}]}"#,
            "at_ns",
        );
        // Train mode takes no workload.
        let text = r#"{"name": "bad", "mode": "train",
                       "workload": "bursty-decode"}"#;
        let err = Scenario::from_json(&Json::parse(text).unwrap())
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("no workload"));
        // ... and no sketch percentiles (nothing to sketch).
        let text = r#"{"name": "bad", "mode": "train",
                       "percentiles": "sketch"}"#;
        let err = Scenario::from_json(&Json::parse(text).unwrap())
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err:#}").contains("serve mode only"));
        // Unknown mode.
        let text = r#"{"name": "bad", "mode": "dream"}"#;
        assert!(Scenario::from_json(&Json::parse(text).unwrap()).is_err());
    }
}
