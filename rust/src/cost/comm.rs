//! Communication cost closed-forms: NCCL-style collectives and P2P
//! transfers. These price the *non-overlapping baseline* (PyTorch +
//! NCCL in the paper) and the medium-grained chunk transfers.

use crate::cost::arch::ClusterSpec;

/// Time (ns) to move `bytes` point-to-point inside a node.
pub fn p2p_ns(cluster: &ClusterSpec, bytes: f64) -> f64 {
    cluster.p2p_latency_us * 1e3 + bytes / cluster.p2p_gbps()
}

/// NCCL ring AllGather over n ranks of a tensor of `total_bytes`
/// (the gathered size): each rank sends its shard around the ring,
/// (n-1) steps of (total/n) bytes at bus bandwidth.
pub fn ring_all_gather_ns(
    cluster: &ClusterSpec,
    n: usize,
    total_bytes: f64,
) -> f64 {
    assert!(n >= 1);
    if n == 1 {
        return 0.0;
    }
    // Multi-node rings are bottlenecked by the NIC share per GPU.
    let bus = if n > cluster.gpus_per_node {
        cluster.nccl_bus_gbps.min(cluster.nic_gbps_per_gpu)
    } else {
        cluster.nccl_bus_gbps
    };
    let step_bytes = total_bytes / n as f64;
    let steps = (n - 1) as f64;
    steps * (cluster.p2p_latency_us * 1e3 + step_bytes / bus)
}

/// NCCL ring ReduceScatter: same wire pattern as AllGather.
pub fn ring_reduce_scatter_ns(
    cluster: &ClusterSpec,
    n: usize,
    total_bytes: f64,
) -> f64 {
    ring_all_gather_ns(cluster, n, total_bytes)
}

/// AllReduce = ReduceScatter + AllGather (ring).
pub fn ring_all_reduce_ns(
    cluster: &ClusterSpec,
    n: usize,
    total_bytes: f64,
) -> f64 {
    ring_reduce_scatter_ns(cluster, n, total_bytes)
        + ring_all_gather_ns(cluster, n, total_bytes)
}

/// Inter-node portion for multi-node TP (Fig. 15): the slowest path is
/// each GPU exchanging its shard with its peer GPU on the other node
/// through its NIC share.
pub fn internode_exchange_ns(
    cluster: &ClusterSpec,
    bytes_per_gpu: f64,
) -> f64 {
    // NIC latency is substantially higher than NVLink's.
    let nic_latency_ns = 10.0 * 1e3;
    nic_latency_ns + bytes_per_gpu / cluster.nic_gbps_per_gpu
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};

    const MB: f64 = 1e6;

    #[test]
    fn p2p_scales_with_bytes() {
        let t1 = p2p_ns(&A100_NVLINK, 10.0 * MB);
        let t2 = p2p_ns(&A100_NVLINK, 20.0 * MB);
        assert!(t2 > t1);
        // 10MB at 300GB/s ≈ 33us + 2us latency.
        assert!((t1 - (2.0e3 + 10.0 * MB / 300.0)).abs() < 1.0);
    }

    #[test]
    fn ring_allgather_matches_formula() {
        // 8 ranks, 201MB gathered on A100 NVLink bus 230GB/s:
        // 7 * 25.1MB / 230GB/s ≈ 765us (+latency).
        let t = ring_all_gather_ns(&A100_NVLINK, 8, 201.0 * MB);
        let ideal = 7.0 * (201.0 * MB / 8.0) / 230.0;
        assert!((t - ideal) < 20.0e3, "t={t} ideal={ideal}");
        assert!(t > ideal);
    }

    #[test]
    fn single_rank_collectives_are_free() {
        assert_eq!(ring_all_gather_ns(&A100_PCIE, 1, MB), 0.0);
    }

    #[test]
    fn pcie_much_slower_than_nvlink() {
        let pcie = ring_all_gather_ns(&A100_PCIE, 8, 100.0 * MB);
        let nvl = ring_all_gather_ns(&A100_NVLINK, 8, 100.0 * MB);
        assert!(pcie > 15.0 * nvl, "pcie {pcie} nvl {nvl}");
    }

    #[test]
    fn h800_nic_is_fat() {
        // 400Gb/s per GPU: 50 GB/s => 100MB exchange ≈ 2ms.
        let t = internode_exchange_ns(&H800_NVLINK, 100.0 * MB);
        assert!(t > 1.9e6 && t < 2.4e6, "t={t}");
    }

    #[test]
    fn allreduce_is_twice_reduce_scatter() {
        let rs = ring_reduce_scatter_ns(&A100_NVLINK, 8, 64.0 * MB);
        let ar = ring_all_reduce_ns(&A100_NVLINK, 8, 64.0 * MB);
        assert!((ar - 2.0 * rs).abs() < 1e-6);
    }
}
