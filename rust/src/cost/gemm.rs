//! GEMM cost model: tile-level timing + closed-form kernel times.
//!
//! Everything the paper measures about GEMM efficiency falls out of two
//! mechanisms, both modeled here:
//!
//! 1. **Wave quantization.** A GEMM kernel is `ceil(M/bm)*ceil(N/bn)`
//!    thread-block tiles scheduled over `SMs * blocks_per_sm` slots in
//!    waves; the last partial wave wastes slots. Splitting one GEMM into
//!    N_TP chunk kernels multiplies the number of partial waves — the
//!    §2.2 "poor GPU utilization" of medium-grained overlap.
//! 2. **Latency-hiding loss at small m.** Tiles with few rows have too
//!    few warps to hide memory/MMA latency (§6's small-m discussion).
//!
//! The per-tile duration here is the *same* number the DES feeds to the
//! SM [`Pool`](crate::sim::resources::Pool), so the closed-form and the
//! simulated paths agree by construction.

use crate::cost::arch::GpuArch;

pub const BF16_BYTES: f64 = 2.0;
pub const F32_BYTES: f64 = 4.0;

/// A (possibly rank-local) GEMM problem: C[m,n] = A[m,k] @ B[k,n].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        GemmShape { m, n, k }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

/// Thread-block tile geometry chosen by the (auto-tuned) GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileShape {
    pub bm: usize,
    pub bn: usize,
}

/// Pick the tile the way CUTLASS heuristics would: big square-ish tiles,
/// shrunk when m is small so the kernel still has >1 tile of parallelism.
pub fn pick_tile(shape: &GemmShape) -> TileShape {
    let bm = [128usize, 64, 32, 16, 8]
        .into_iter()
        .find(|&b| shape.m >= b)
        .unwrap_or(8);
    let bn = [128usize, 64, 32]
        .into_iter()
        .find(|&b| shape.n >= b)
        .unwrap_or(32);
    TileShape { bm, bn }
}

/// Duration (ns) of one thread-block tile of `rows x cols` output with a
/// full k-loop of depth `k`.
///
/// `rows`/`cols` may be smaller than the tile shape at edges; the tile
/// still *occupies* a slot for its full duration but does less work at
/// lower efficiency — this is where small-m pain comes from.
pub fn tile_time_ns(
    arch: &GpuArch,
    tile: TileShape,
    rows: usize,
    cols: usize,
    k: usize,
) -> f64 {
    debug_assert!(rows > 0 && cols > 0 && k > 0);
    let flops = 2.0 * rows as f64 * cols as f64 * k as f64;

    // Per-slot share of peak compute.
    let slots = (arch.sms * arch.blocks_per_sm) as f64;
    let per_slot_flops_per_ns = arch.peak_bf16_tflops * 1e12 / 1e9 / slots;

    // Latency-hiding efficiency: tiles with few rows have few warps.
    // Full tiles run at arch.gemm_eff; an 8-row sliver runs at ~40% of
    // that (calibrated to the paper's small-m observations).
    let fill = (rows as f64 / tile.bm as f64).min(1.0);
    let eff = arch.gemm_eff * (0.35 + 0.65 * fill);

    let t_compute = flops / (per_slot_flops_per_ns * eff);

    // Memory floor: the tile streams its A/B slices from HBM, but the L2
    // serves a large fraction of B (shared across the row-tiles resident
    // in the same wave) and of A (shared across col-tiles). A constant
    // reuse factor of 4 calibrates large-GEMM times to the observed
    // ~0.75-0.85 of peak on A100/H800.
    const L2_REUSE: f64 = 4.0;
    let bytes = (rows * k + k * cols) as f64 * BF16_BYTES / L2_REUSE
        + (rows * cols) as f64 * F32_BYTES;
    let per_slot_bw = arch.hbm_gbps / slots; // GB/s == bytes/ns
    let t_mem = bytes / per_slot_bw;

    t_compute.max(t_mem)
}

/// One tile task for the DES: output coordinates + duration.
#[derive(Clone, Copy, Debug)]
pub struct TileTask {
    /// Row-tile index (along m).
    pub ti: usize,
    /// Col-tile index (along n).
    pub tj: usize,
    pub rows: usize,
    pub cols: usize,
    pub dur_ns: f64,
}

/// Enumerate the tile grid of a GEMM in row-major (ti, tj) order.
pub fn tile_grid(arch: &GpuArch, shape: &GemmShape) -> (TileShape, Vec<TileTask>) {
    let tile = pick_tile(shape);
    let tm = shape.m.div_ceil(tile.bm);
    let tn = shape.n.div_ceil(tile.bn);
    let mut tasks = Vec::with_capacity(tm * tn);
    for ti in 0..tm {
        let rows = (shape.m - ti * tile.bm).min(tile.bm);
        for tj in 0..tn {
            let cols = (shape.n - tj * tile.bn).min(tile.bn);
            tasks.push(TileTask {
                ti,
                tj,
                rows,
                cols,
                dur_ns: tile_time_ns(arch, tile, rows, cols, shape.k),
            });
        }
    }
    (tile, tasks)
}

/// Closed-form kernel time: wave-scheduled tiles + launch overhead.
/// Matches simulating `tile_grid` through a Pool of `sm_slots` exactly
/// when all tiles have equal duration.
pub fn gemm_time_ns(arch: &GpuArch, shape: &GemmShape) -> f64 {
    let (_, tasks) = tile_grid(arch, shape);
    let slots = arch.sms * arch.blocks_per_sm;
    // Identical-duration fast path (the common case: uniform grid).
    let d0 = tasks[0].dur_ns;
    let uniform = tasks.iter().all(|t| (t.dur_ns - d0).abs() < 1e-9);
    let body = if uniform {
        let waves = tasks.len().div_ceil(slots);
        waves as f64 * d0
    } else {
        // List-schedule heterogeneous tiles.
        let mut pool = crate::sim::resources::Pool::new(slots);
        tasks
            .iter()
            .map(|t| pool.acquire(0.0, t.dur_ns).1)
            .fold(0.0, f64::max)
    };
    arch.launch_us * 1e3 + body
}

/// Achieved fraction of peak for a full (non-split) GEMM — used for
/// roofline reporting in EXPERIMENTS.md.
pub fn achieved_fraction(arch: &GpuArch, shape: &GemmShape) -> f64 {
    let t = gemm_time_ns(arch, shape);
    shape.flops() / (t * 1e-9) / (arch.peak_bf16_tflops * 1e12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100, H800};

    #[test]
    fn large_gemm_hits_calibrated_efficiency() {
        // GPT-3 per-rank GEMM at m=8192 should run near arch.gemm_eff.
        let s = GemmShape::new(8192, 6144, 12288);
        let f = achieved_fraction(&A100, &s);
        assert!(f > 0.70 && f <= 0.85, "achieved fraction {f}");
    }

    #[test]
    fn absolute_time_sanity() {
        // 8192x6144x12288 = 1.24 PFLOP; at ~250 TF/s ≈ 5 ms.
        let s = GemmShape::new(8192, 6144, 12288);
        let t_ms = gemm_time_ns(&A100, &s) / 1e6;
        assert!(t_ms > 3.0 && t_ms < 8.0, "t = {t_ms} ms");
    }

    #[test]
    fn splitting_is_slower_than_whole() {
        // sum of N chunk GEMMs (each m/N) > one full GEMM: the §2.2 loss.
        let full = GemmShape::new(1024, 6144, 12288);
        let t_full = gemm_time_ns(&A100, &full);
        let chunk = GemmShape::new(1024 / 8, 6144, 12288);
        let t_chunks = 8.0 * gemm_time_ns(&A100, &chunk);
        assert!(
            t_chunks > 1.15 * t_full,
            "split {t_chunks} vs full {t_full}"
        );
    }

    #[test]
    fn small_m_runs_at_lower_efficiency() {
        let big = achieved_fraction(&A100, &GemmShape::new(8192, 12288, 6144));
        let small = achieved_fraction(&A100, &GemmShape::new(64, 12288, 6144));
        assert!(small < 0.6 * big, "small {small} vs big {big}");
    }

    #[test]
    fn h800_faster_than_a100() {
        let s = GemmShape::new(4096, 6144, 12288);
        assert!(gemm_time_ns(&H800, &s) < 0.5 * gemm_time_ns(&A100, &s));
    }

    #[test]
    fn tile_pick_adapts_to_small_m() {
        assert_eq!(pick_tile(&GemmShape::new(8192, 6144, 1)).bm, 128);
        assert_eq!(pick_tile(&GemmShape::new(64, 6144, 1)).bm, 64);
        assert_eq!(pick_tile(&GemmShape::new(8, 6144, 1)).bm, 8);
    }

    #[test]
    fn grid_covers_output_exactly() {
        let (tile, tasks) = tile_grid(&A100, &GemmShape::new(100, 200, 64));
        let area: usize = tasks.iter().map(|t| t.rows * t.cols).sum();
        assert_eq!(area, 100 * 200);
        assert!(tasks.iter().all(|t| t.rows <= tile.bm && t.cols <= tile.bn));
    }

    #[test]
    fn memory_bound_floor_engages_for_skinny_k() {
        // k=32 GEMM is bandwidth bound; time must exceed pure-compute.
        let arch = &A100;
        let tile = pick_tile(&GemmShape::new(128, 128, 32));
        let t = tile_time_ns(arch, tile, 128, 128, 32);
        let slots = (arch.sms * arch.blocks_per_sm) as f64;
        let pure_compute = 2.0 * 128.0 * 128.0 * 32.0
            / (arch.peak_bf16_tflops * 1e12 / 1e9 / slots * arch.gemm_eff);
        assert!(t > pure_compute, "{t} vs {pure_compute}");
    }
}
