//! GPU architecture + cluster constants (§5 of the paper).
//!
//! The three evaluation clusters, translated into the parameters the
//! simulator needs. Absolute numbers are public-spec or published-bench
//! values; the *ratios* between compute and interconnect speed are what
//! the reproduction depends on (DESIGN.md §2).

/// One GPU generation's compute/memory profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuArch {
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub sms: usize,
    /// Resident GEMM thread blocks per SM (occupancy for 128x128 tiles).
    /// >1 is what lets spinning blocks (Alg. 2) hide latency.
    pub blocks_per_sm: usize,
    /// Dense bf16 tensor-core peak, TFLOP/s.
    pub peak_bf16_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbps: f64,
    /// Sustained fraction of peak a well-tuned large GEMM achieves
    /// (cuBLAS/CUTLASS reality, not marketing).
    pub gemm_eff: f64,
    /// Kernel launch overhead, microseconds.
    pub launch_us: f64,
    /// Additional per-kernel *gap* when many small kernels are enqueued on
    /// busy streams (the unpredictable timing §2.2 complains about); the
    /// simulator multiplies this by a seeded log-normal jitter.
    pub stream_gap_us: f64,
    /// Store-efficiency penalty applied when an epilogue writes rows
    /// narrower than the minimum efficient store (TMA on Hopper): the
    /// §6 m=64 ReduceScatter cliff.
    pub min_store_rows: usize,
    pub narrow_store_penalty: f64,
}

pub const A100: GpuArch = GpuArch {
    name: "A100",
    sms: 108,
    blocks_per_sm: 2,
    peak_bf16_tflops: 312.0,
    hbm_gbps: 2039.0,
    gemm_eff: 0.80,
    launch_us: 4.0,
    stream_gap_us: 3.0,
    min_store_rows: 1, // st-based epilogue: no narrow-store cliff
    narrow_store_penalty: 1.0,
};

pub const H800: GpuArch = GpuArch {
    name: "H800",
    sms: 132,
    blocks_per_sm: 2,
    peak_bf16_tflops: 990.0,
    hbm_gbps: 3350.0,
    gemm_eff: 0.75,
    launch_us: 4.0,
    stream_gap_us: 3.0,
    min_store_rows: 16, // TMA bulk-tensor stores want >=16 rows
    narrow_store_penalty: 0.55,
};

/// Intra-node interconnect flavor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Intra {
    /// NVSwitch fabric: any-to-any, limited by per-device egress/ingress.
    NvLink {
        /// Per-direction bandwidth per device, GB/s.
        per_dir_gbps: f64,
    },
    /// PCIe tree: per-device link into a shared switch per NUMA domain;
    /// cross-NUMA traffic also crosses the inter-socket link.
    Pcie {
        per_dir_gbps: f64,
        gpus_per_numa: usize,
        /// Effective bandwidth of the socket-to-socket path, GB/s.
        numa_link_gbps: f64,
    },
}

/// One of the paper's evaluation clusters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub arch: GpuArch,
    pub gpus_per_node: usize,
    pub intra: Intra,
    /// Per-GPU share of inter-node NIC bandwidth, GB/s per direction.
    pub nic_gbps_per_gpu: f64,
    /// NCCL ring bus bandwidth for intra-node collectives, GB/s —
    /// the non-overlapping baseline's effective speed.
    pub nccl_bus_gbps: f64,
    /// P2P transfer latency inside a node, microseconds.
    pub p2p_latency_us: f64,
    /// One-way latency of the inter-node NIC path (IB/RoCE verbs +
    /// switch hops), microseconds.
    pub nic_latency_us: f64,
    /// Signal set→visible latency (cuStreamWriteValue→spin loop), us.
    pub signal_latency_us: f64,
}

/// A100 PCIe (80GB): 8 GPU/node, 2 NUMA domains of 4 GPUs + 1 NIC each,
/// 2x100Gb/s inter-node.
pub const A100_PCIE: ClusterSpec = ClusterSpec {
    name: "A100 PCIe",
    arch: A100,
    gpus_per_node: 8,
    intra: Intra::Pcie {
        per_dir_gbps: 22.0,
        gpus_per_numa: 4,
        numa_link_gbps: 45.0,
    },
    nic_gbps_per_gpu: 100.0 / 8.0 * 2.0 / 8.0, // 2x100Gb/s over 8 GPUs
    nccl_bus_gbps: 13.0, // PCIe Gen4-only ring: published NCCL reality
    p2p_latency_us: 6.0,
    nic_latency_us: 10.0,
    signal_latency_us: 4.0,
};

/// A100 SXM4 (80GB): NVLink3 600GB/s bidir => 300GB/s per direction,
/// 4x200Gb/s NICs (2 GPUs share one).
pub const A100_NVLINK: ClusterSpec = ClusterSpec {
    name: "A100 NVLink",
    arch: A100,
    gpus_per_node: 8,
    intra: Intra::NvLink { per_dir_gbps: 300.0 },
    nic_gbps_per_gpu: 200.0 / 8.0 / 2.0, // Gb/s->GB/s and 2 GPUs per NIC
    nccl_bus_gbps: 230.0,
    p2p_latency_us: 2.0,
    nic_latency_us: 10.0,
    signal_latency_us: 3.0,
};

/// H800 SXM5: NVLink 400GB/s bidir per device => 200GB/s per direction
/// (export-trimmed), 1x400Gb/s NIC per GPU.
pub const H800_NVLINK: ClusterSpec = ClusterSpec {
    name: "H800 NVLink",
    arch: H800,
    gpus_per_node: 8,
    intra: Intra::NvLink { per_dir_gbps: 200.0 },
    nic_gbps_per_gpu: 400.0 / 8.0,
    nccl_bus_gbps: 160.0,
    p2p_latency_us: 2.0,
    nic_latency_us: 10.0,
    signal_latency_us: 3.0,
};

pub const ALL_CLUSTERS: [&ClusterSpec; 3] =
    [&A100_PCIE, &A100_NVLINK, &H800_NVLINK];

impl ClusterSpec {
    pub fn by_name(name: &str) -> Option<&'static ClusterSpec> {
        let key = name.to_ascii_lowercase().replace(['-', '_'], " ");
        ALL_CLUSTERS
            .iter()
            .copied()
            .find(|c| c.name.to_ascii_lowercase() == key)
    }

    /// Per-direction P2P bandwidth between two GPUs in this node, GB/s.
    pub fn p2p_gbps(&self) -> f64 {
        match self.intra {
            Intra::NvLink { per_dir_gbps } => per_dir_gbps,
            Intra::Pcie { per_dir_gbps, .. } => per_dir_gbps,
        }
    }

    /// Total resident thread blocks (SM slots) per device.
    pub fn sm_slots(&self) -> usize {
        self.arch.sms * self.arch.blocks_per_sm
    }
}

/// A multi-node serving cluster: `dp` independent TP groups of degree
/// `tp` laid out over `nodes` nodes of a base [`ClusterSpec`].
///
/// Layout follows Megatron-LM's serving convention: TP stays *within* a
/// node (NVLink/PCIe intra-node), DP replicas tile across nodes
/// (IB/RoCE inter-node, `nic_gbps_per_gpu` / `nic_latency_us`).
/// Replicas serve disjoint request streams, so the inter-node fabric
/// carries routing traffic only — the reason this layout is the one the
/// paper's Fig. 16/17 inference numbers assume.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleTopology {
    pub name: &'static str,
    pub cluster: &'static ClusterSpec,
    pub nodes: usize,
    /// TP degree of each replica (one TP group == one model instance).
    pub tp: usize,
    /// Number of data-parallel replicas.
    pub dp: usize,
}

/// Single node, one TP8 group — the baseline Fig. 16/17 configuration.
pub const SCALE_TP8: ScaleTopology = ScaleTopology {
    name: "1-node tp8",
    cluster: &A100_NVLINK,
    nodes: 1,
    tp: 8,
    dp: 1,
};

/// Two NVLink nodes, one TP8 replica per node.
pub const SCALE_TP8_DP2: ScaleTopology = ScaleTopology {
    name: "2-node tp8 dp2",
    cluster: &A100_NVLINK,
    nodes: 2,
    tp: 8,
    dp: 2,
};

/// PCIe-only cluster, two nodes, one TP8 replica per node — the
/// communication-dominated end of the sweep.
pub const SCALE_PCIE_TP8_DP2: ScaleTopology = ScaleTopology {
    name: "2-node pcie tp8 dp2",
    cluster: &A100_PCIE,
    nodes: 2,
    tp: 8,
    dp: 2,
};

/// Four H800 nodes — the high-communication-proportion arch at DP4.
pub const SCALE_H800_TP8_DP4: ScaleTopology = ScaleTopology {
    name: "4-node h800 tp8 dp4",
    cluster: &H800_NVLINK,
    nodes: 4,
    tp: 8,
    dp: 4,
};

pub const ALL_SCALE_TOPOLOGIES: [&ScaleTopology; 4] = [
    &SCALE_TP8,
    &SCALE_TP8_DP2,
    &SCALE_PCIE_TP8_DP2,
    &SCALE_H800_TP8_DP4,
];

/// The fleet DP degrees [`ScaleTopology::fleet`] is parametric over.
pub const FLEET_DPS: [usize; 6] = [8, 16, 32, 64, 128, 256];

/// One parametric fleet pool: `dp` nodes, one TP8 replica per node
/// (the Megatron serving layout at datacenter width — `nodes == dp`
/// keeps TP intra-node at every scale).
macro_rules! fleet_pool {
    ($cluster:expr, $name:literal, $dp:literal) => {
        &ScaleTopology {
            name: $name,
            cluster: $cluster,
            nodes: $dp,
            tp: 8,
            dp: $dp,
        }
    };
}

/// The dp64 NVLink fleet pool — the deterministic `fleet` bench cell
/// and the CI events/sec perf-gate point (`report::bench`).
pub const FLEET_NVLINK_DP64: ScaleTopology = ScaleTopology {
    name: "fleet nvlink tp8 dp64",
    cluster: &A100_NVLINK,
    nodes: 64,
    tp: 8,
    dp: 64,
};

/// The dp256 NVLink fleet pool — the full-suite fleet cell (2048
/// GPUs; skipped under `flux bench --quick` to bound CI wall time).
pub const FLEET_NVLINK_DP256: ScaleTopology = ScaleTopology {
    name: "fleet nvlink tp8 dp256",
    cluster: &A100_NVLINK,
    nodes: 256,
    tp: 8,
    dp: 256,
};

/// The parametric fleet registry: dp8–dp256 pools on each evaluation
/// cluster, addressable by `--topo`, scenario `topologies` entries and
/// [`ScaleTopology::fleet`]. Deliberately *separate* from
/// [`ALL_SCALE_TOPOLOGIES`]: the default `simulate --scale` /
/// `sweep-workloads` sweeps (and their pinned report bytes) stay on
/// the four paper topologies; fleet cells run only when named.
pub const ALL_FLEET_TOPOLOGIES: [&ScaleTopology; 18] = [
    fleet_pool!(&A100_NVLINK, "fleet nvlink tp8 dp8", 8),
    fleet_pool!(&A100_NVLINK, "fleet nvlink tp8 dp16", 16),
    fleet_pool!(&A100_NVLINK, "fleet nvlink tp8 dp32", 32),
    &FLEET_NVLINK_DP64,
    fleet_pool!(&A100_NVLINK, "fleet nvlink tp8 dp128", 128),
    &FLEET_NVLINK_DP256,
    fleet_pool!(&A100_PCIE, "fleet pcie tp8 dp8", 8),
    fleet_pool!(&A100_PCIE, "fleet pcie tp8 dp16", 16),
    fleet_pool!(&A100_PCIE, "fleet pcie tp8 dp32", 32),
    fleet_pool!(&A100_PCIE, "fleet pcie tp8 dp64", 64),
    fleet_pool!(&A100_PCIE, "fleet pcie tp8 dp128", 128),
    fleet_pool!(&A100_PCIE, "fleet pcie tp8 dp256", 256),
    fleet_pool!(&H800_NVLINK, "fleet h800 tp8 dp8", 8),
    fleet_pool!(&H800_NVLINK, "fleet h800 tp8 dp16", 16),
    fleet_pool!(&H800_NVLINK, "fleet h800 tp8 dp32", 32),
    fleet_pool!(&H800_NVLINK, "fleet h800 tp8 dp64", 64),
    fleet_pool!(&H800_NVLINK, "fleet h800 tp8 dp128", 128),
    fleet_pool!(&H800_NVLINK, "fleet h800 tp8 dp256", 256),
];

/// A training cluster layout: DP x PP x TP over nodes of a base
/// [`ClusterSpec`], Megatron-LM convention (§5.2): TP inside a node,
/// one pipeline stage per node, DP replicas tile the remaining nodes.
/// The PP hops and the DP gradient all-reduce both ride the inter-node
/// NIC path (`nic_gbps_per_gpu` / `nic_latency_us`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainTopology {
    pub name: &'static str,
    pub cluster: &'static ClusterSpec,
    pub dp: usize,
    pub pp: usize,
    /// TP degree of each pipeline stage (intra-node).
    pub tp: usize,
}

/// The paper's 128-GPU training layout (Fig. 16: DP2 x PP8 x TP8) on
/// each evaluation cluster.
pub const TRAIN_PCIE_128: TrainTopology = TrainTopology {
    name: "pcie dp2 pp8 tp8",
    cluster: &A100_PCIE,
    dp: 2,
    pp: 8,
    tp: 8,
};

pub const TRAIN_NVLINK_128: TrainTopology = TrainTopology {
    name: "nvlink dp2 pp8 tp8",
    cluster: &A100_NVLINK,
    dp: 2,
    pp: 8,
    tp: 8,
};

pub const TRAIN_H800_128: TrainTopology = TrainTopology {
    name: "h800 dp2 pp8 tp8",
    cluster: &H800_NVLINK,
    dp: 2,
    pp: 8,
    tp: 8,
};

pub const ALL_TRAIN_TOPOLOGIES: [&TrainTopology; 3] =
    [&TRAIN_PCIE_128, &TRAIN_NVLINK_128, &TRAIN_H800_128];

impl TrainTopology {
    pub fn by_name(name: &str) -> Option<&'static TrainTopology> {
        let norm =
            |s: &str| s.to_ascii_lowercase().replace(['-', '_'], " ");
        let key = norm(name);
        ALL_TRAIN_TOPOLOGIES.iter().copied().find(|t| norm(t.name) == key)
    }

    pub fn gpus(&self) -> usize {
        self.dp * self.pp * self.tp
    }

    pub fn nodes(&self) -> usize {
        self.gpus().div_ceil(self.cluster.gpus_per_node)
    }

    /// Check the TP-within-node / stage-per-node layout invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.dp >= 1 && self.pp >= 1 && self.tp >= 1,
            "{}: degenerate topology",
            self.name
        );
        anyhow::ensure!(
            self.tp <= self.cluster.gpus_per_node,
            "{}: TP{} exceeds the {}-GPU node (TP must stay intra-node)",
            self.name,
            self.tp,
            self.cluster.gpus_per_node
        );
        anyhow::ensure!(
            self.gpus() % self.cluster.gpus_per_node == 0,
            "{}: {} GPUs do not tile {}-GPU nodes",
            self.name,
            self.gpus(),
            self.cluster.gpus_per_node
        );
        Ok(())
    }
}

impl ScaleTopology {
    pub fn by_name(name: &str) -> Option<&'static ScaleTopology> {
        // Topology names contain hyphens themselves ("2-node tp8 dp2"),
        // so normalize both sides. Fleet pools resolve here too, so
        // `--topo fleet-nvlink-tp8-dp64` and scenario files reach them
        // without entering the default sweep registry.
        let norm =
            |s: &str| s.to_ascii_lowercase().replace(['-', '_'], " ");
        let key = norm(name);
        ALL_SCALE_TOPOLOGIES
            .iter()
            .chain(ALL_FLEET_TOPOLOGIES.iter())
            .copied()
            .find(|t| norm(t.name) == key)
    }

    /// Parametric fleet constructor: the registered
    /// `fleet <link> tp8 dp<N>` pool for `dp` in [`FLEET_DPS`] and
    /// `link` one of `nvlink` | `pcie` | `h800` (case-insensitive).
    pub fn fleet(dp: usize, link: &str) -> Option<&'static ScaleTopology> {
        let key = link.to_ascii_lowercase();
        ALL_FLEET_TOPOLOGIES
            .iter()
            .copied()
            .find(|t| {
                t.dp == dp && t.name.split(' ').nth(1) == Some(key.as_str())
            })
    }

    pub fn gpus(&self) -> usize {
        self.tp * self.dp
    }

    pub fn replicas_per_node(&self) -> usize {
        self.dp.div_ceil(self.nodes)
    }

    /// Check the TP-within-node / DP-across-nodes layout invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.tp >= 1 && self.dp >= 1 && self.nodes >= 1,
            "{}: degenerate topology",
            self.name
        );
        anyhow::ensure!(
            self.tp <= self.cluster.gpus_per_node,
            "{}: TP{} exceeds the {}-GPU node (TP must stay intra-node)",
            self.name,
            self.tp,
            self.cluster.gpus_per_node
        );
        anyhow::ensure!(
            self.replicas_per_node() * self.tp <= self.cluster.gpus_per_node,
            "{}: {} replicas/node x TP{} exceeds {} GPUs/node",
            self.name,
            self.replicas_per_node(),
            self.tp,
            self.cluster.gpus_per_node
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(ClusterSpec::by_name("a100 pcie"), Some(&A100_PCIE));
        assert_eq!(ClusterSpec::by_name("A100-NVLink"), Some(&A100_NVLINK));
        assert_eq!(ClusterSpec::by_name("h800_nvlink"), Some(&H800_NVLINK));
        assert!(ClusterSpec::by_name("tpu v5").is_none());
    }

    #[test]
    fn relative_speeds_match_the_paper_story() {
        // H800 computes ~3x faster than A100 but its NVLink is slower:
        // that is why H800 has the *highest* communication proportion
        // (§6 "High communication proportion").
        assert!(H800.peak_bf16_tflops / A100.peak_bf16_tflops > 2.5);
        assert!(
            H800_NVLINK.p2p_gbps() < A100_NVLINK.p2p_gbps(),
            "H800 NVLink is export-trimmed below A100's"
        );
        // PCIe is an order of magnitude slower than NVLink.
        assert!(A100_NVLINK.p2p_gbps() / A100_PCIE.p2p_gbps() > 10.0);
    }

    #[test]
    fn sm_slots() {
        assert_eq!(A100_PCIE.sm_slots(), 216);
        assert_eq!(H800_NVLINK.sm_slots(), 264);
    }

    #[test]
    fn scale_topologies_validate_and_tile_nodes() {
        for t in ALL_SCALE_TOPOLOGIES {
            t.validate().unwrap();
            assert_eq!(t.gpus(), t.tp * t.dp);
            // The DP replicas fit on the cluster's nodes.
            assert!(
                t.replicas_per_node() * t.nodes >= t.dp,
                "{}",
                t.name
            );
        }
        assert_eq!(SCALE_TP8_DP2.replicas_per_node(), 1);
    }

    #[test]
    fn scale_lookup_by_name() {
        assert_eq!(
            ScaleTopology::by_name("2-node_tp8_dp2"),
            Some(&SCALE_TP8_DP2)
        );
        assert!(ScaleTopology::by_name("mystery").is_none());
    }

    #[test]
    fn fleet_registry_is_parametric_and_validates() {
        // Every (dp, link) point exists, validates the TP-intra-node
        // layout, and round-trips through both lookup surfaces.
        assert_eq!(ALL_FLEET_TOPOLOGIES.len(), FLEET_DPS.len() * 3);
        for &dp in &FLEET_DPS {
            for link in ["nvlink", "pcie", "h800"] {
                let t = ScaleTopology::fleet(dp, link)
                    .unwrap_or_else(|| panic!("missing fleet {link} dp{dp}"));
                t.validate().unwrap();
                assert_eq!(t.dp, dp);
                assert_eq!(t.tp, 8);
                assert_eq!(t.nodes, dp, "one TP8 replica per node");
                assert_eq!(t.replicas_per_node(), 1);
                assert_eq!(ScaleTopology::by_name(t.name), Some(t));
            }
        }
        // The default sweep registry is untouched by the fleet pools.
        assert_eq!(ALL_SCALE_TOPOLOGIES.len(), 4);
        assert!(ALL_SCALE_TOPOLOGIES
            .iter()
            .all(|t| !t.name.starts_with("fleet")));
    }

    #[test]
    fn fleet_lookup_rejects_unregistered_points() {
        assert!(ScaleTopology::fleet(64, "NVLink").is_some(), "case");
        assert!(ScaleTopology::fleet(512, "nvlink").is_none());
        assert!(ScaleTopology::fleet(64, "infiniband").is_none());
        assert_eq!(
            ScaleTopology::by_name("fleet-h800-tp8-dp128")
                .map(|t| (t.dp, t.cluster.name)),
            Some((128, "H800 NVLink"))
        );
        assert_eq!(
            ScaleTopology::fleet(256, "nvlink"),
            Some(&FLEET_NVLINK_DP256)
        );
        assert_eq!(
            ScaleTopology::fleet(64, "nvlink"),
            Some(&FLEET_NVLINK_DP64)
        );
    }

    #[test]
    fn tp_spanning_nodes_is_rejected() {
        let bad = ScaleTopology {
            name: "tp16 spanning",
            cluster: &A100_NVLINK,
            nodes: 2,
            tp: 16,
            dp: 1,
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn train_topologies_are_the_papers_128_gpu_layout() {
        for t in ALL_TRAIN_TOPOLOGIES {
            t.validate().unwrap();
            assert_eq!(t.gpus(), 128, "{}", t.name);
            assert_eq!((t.dp, t.pp, t.tp), (2, 8, 8), "{}", t.name);
            assert_eq!(t.nodes(), 16, "{}", t.name);
        }
    }

    #[test]
    fn train_lookup_by_name() {
        assert_eq!(
            TrainTopology::by_name("pcie-dp2-pp8-tp8"),
            Some(&TRAIN_PCIE_128)
        );
        assert_eq!(
            TrainTopology::by_name("H800_dp2_pp8_tp8"),
            Some(&TRAIN_H800_128)
        );
        assert!(TrainTopology::by_name("dp9000").is_none());
    }

    #[test]
    fn train_tp_spanning_nodes_is_rejected() {
        let bad = TrainTopology {
            name: "tp16 spanning",
            cluster: &A100_NVLINK,
            dp: 1,
            pp: 2,
            tp: 16,
        };
        assert!(bad.validate().is_err());
    }
}
