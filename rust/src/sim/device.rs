//! Simulated GPU device: SM pool + stream-ordered kernel launches.
//!
//! A device executes *kernels*; a kernel is a bag of thread-block tiles
//! list-scheduled over the SM pool ([`Pool`]), non-preemptively — the
//! same contract as the hardware block scheduler. Streams order kernel
//! launches and model the launch overhead + timing jitter that §2.2
//! identifies as a core weakness of medium-grained (multi-kernel)
//! overlap on GPUs.

use crate::cost::arch::GpuArch;
use crate::sim::resources::{Pool, Serial, Time};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct Device {
    pub arch: GpuArch,
    pub sm: Pool,
    /// Launch/driver pipe: kernel launches serialize per device.
    launch_pipe: Serial,
    rng: Rng,
    /// Log-normal sigma for stream timing jitter (0 disables).
    pub jitter_sigma: f64,
}

/// Timing of one simulated kernel execution.
#[derive(Clone, Copy, Debug)]
pub struct KernelTiming {
    /// When the kernel's first tile started computing.
    pub start: Time,
    /// When the last tile finished.
    pub end: Time,
}

impl Device {
    pub fn new(arch: &GpuArch, rank: usize, seed: u64) -> Device {
        Device {
            arch: *arch,
            sm: Pool::new(arch.sms * arch.blocks_per_sm),
            launch_pipe: Serial::new(),
            rng: Rng::new(seed ^ (rank as u64).wrapping_mul(0x9E37)),
            jitter_sigma: 0.0,
        }
    }

    /// Per-launch overhead with optional jitter: the unpredictable stream
    /// timing of a busy production node (§2.2 limitation #1).
    pub fn launch_overhead(&mut self) -> Time {
        let base = self.arch.launch_us * 1e3;
        if self.jitter_sigma > 0.0 {
            let gap = self.arch.stream_gap_us * 1e3;
            base + gap * self.rng.jitter(self.jitter_sigma)
        } else {
            base
        }
    }

    /// Launch a kernel whose tiles are all ready immediately.
    /// `issue` is when the host/stream issues the launch.
    pub fn launch_uniform(
        &mut self,
        issue: Time,
        n_tiles: usize,
        tile_dur: Time,
    ) -> KernelTiming {
        let ov = self.launch_overhead();
        let (_, t0) = self.launch_pipe.acquire(issue, ov);
        let mut end: Time = t0;
        let mut start = f64::INFINITY;
        for _ in 0..n_tiles {
            let (s, e) = self.sm.acquire(t0, tile_dur);
            start = start.min(s);
            end = end.max(e);
        }
        KernelTiming { start: start.min(end), end }
    }

    /// Launch a kernel whose tiles become runnable at per-tile signal
    /// times (the fused FLUX kernel). Tiles are *placed* on SM slots in
    /// issue order and spin until their signal (Alg. 2 WaitSignal):
    /// residency is occupied while spinning, and latency hiding comes
    /// from blocks_per_sm > 1 — exactly the §3.3 zoom-in narrative.
    pub fn launch_signal_gated(
        &mut self,
        issue: Time,
        tiles: &[GatedTile],
    ) -> KernelTiming {
        let ov = self.launch_overhead();
        let (_, t0) = self.launch_pipe.acquire(issue, ov);
        let mut end: Time = t0;
        let mut start = f64::INFINITY;
        for t in tiles {
            let (s, e) = self.sm.acquire_spinning(t0, t.signal.max(t0), t.dur);
            start = start.min(s);
            end = end.max(e);
        }
        KernelTiming { start: start.min(end), end }
    }

    pub fn reset(&mut self) {
        self.sm.reset();
        self.launch_pipe.reset();
    }
}

/// A tile gated by a readiness signal, with an optional epilogue-store
/// cost already folded into `dur` by the caller.
#[derive(Clone, Copy, Debug)]
pub struct GatedTile {
    pub signal: Time,
    pub dur: Time,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::A100;

    fn dev() -> Device {
        Device::new(&A100, 0, 1)
    }

    #[test]
    fn uniform_kernel_waves() {
        let mut d = dev();
        let slots = d.sm.k();
        let t = d.launch_uniform(0.0, slots * 2, 100.0);
        // Two full waves after launch overhead.
        let ov = A100.launch_us * 1e3;
        assert!((t.end - (ov + 200.0)).abs() < 1e-6, "end={}", t.end);
    }

    #[test]
    fn partial_wave_costs_a_full_wave() {
        let mut d = dev();
        let slots = d.sm.k();
        let t1 = d.launch_uniform(0.0, slots, 100.0);
        d.reset();
        let t2 = d.launch_uniform(0.0, slots + 1, 100.0);
        assert!(t2.end - t1.end >= 99.0, "wave quantization");
    }

    #[test]
    fn signal_gating_delays_only_gated_tiles() {
        let mut d = dev();
        let slots = d.sm.k();
        // Half the tiles ready at 0, half at 1000; one wave total.
        let tiles: Vec<GatedTile> = (0..slots)
            .map(|i| GatedTile {
                signal: if i % 2 == 0 { 0.0 } else { 1000.0 },
                dur: 100.0,
            })
            .collect();
        let t = d.launch_signal_gated(0.0, &tiles);
        let ov = A100.launch_us * 1e3; // 4000ns > the 1000ns signal
        // Gated tiles spin from launch; work starts at max(ov, signal).
        assert!((t.end - (ov + 100.0)).abs() < 1e-6, "end={}", t.end);
    }

    #[test]
    fn spinning_tiles_block_residency() {
        let mut d = dev();
        let slots = d.sm.k();
        // All slots taken by tiles waiting until t=10_000; one extra
        // ready tile must wait for a slot even though it is ready.
        let mut tiles: Vec<GatedTile> = (0..slots)
            .map(|_| GatedTile { signal: 10_000.0, dur: 10.0 })
            .collect();
        tiles.push(GatedTile { signal: 0.0, dur: 10.0 });
        let t = d.launch_signal_gated(0.0, &tiles);
        assert!(t.end >= 10_020.0, "end={}", t.end);
    }

    #[test]
    fn jitter_perturbs_launch_overhead() {
        let mut d = dev();
        d.jitter_sigma = 0.3;
        let xs: Vec<f64> = (0..32).map(|_| d.launch_overhead()).collect();
        let all_same = xs.windows(2).all(|w| w[0] == w[1]);
        assert!(!all_same, "jitter should vary launches");
        assert!(xs.iter().all(|&x| x > A100.launch_us * 1e3));
    }

    #[test]
    fn launches_serialize_on_the_pipe() {
        let mut d = dev();
        let a = d.launch_uniform(0.0, 1, 10.0);
        let b = d.launch_uniform(0.0, 1, 10.0);
        assert!(b.start >= a.start, "launch pipe is FIFO");
    }
}
