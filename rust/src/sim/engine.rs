//! Classic event-queue DES engine.
//!
//! The kernel/link layers use the forward-scheduling resource calculus
//! (resources.rs); this engine sits above them for *open-loop* workloads
//! where future events depend on simulation state: request arrivals in
//! the serving simulation (Fig. 16/17 decode) and the training-step loop.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sim::resources::Time;

/// An event: fires at `at`, carrying a payload `E`.
struct Scheduled<E> {
    at: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time (then lower seq for FIFO ties) first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue: ties break in insertion order.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: Time,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (>= now).
    pub fn schedule(&mut self, at: Time, payload: E) {
        debug_assert!(
            at >= self.now - 1e-9,
            "scheduling into the past: {at} < {}",
            self.now
        );
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` after now.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        let at = self.now + delay;
        self.schedule(at, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|s| {
            self.now = s.at;
            (s.at, s.payload)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30.0, "c");
        q.schedule(10.0, "a");
        q.schedule(20.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.next())
            .map(|(_, e)| e)
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5.0, 1);
        q.schedule(5.0, 2);
        q.schedule(5.0, 3);
        let order: Vec<i32> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(7.5, ());
        assert_eq!(q.now(), 0.0);
        q.next();
        assert_eq!(q.now(), 7.5);
        q.schedule_in(2.5, ());
        let (t, _) = q.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(10.0, ());
        q.next();
        q.schedule(5.0, ());
    }
}
