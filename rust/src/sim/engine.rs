//! Classic event-queue DES engine.
//!
//! The kernel/link layers use the forward-scheduling resource calculus
//! (resources.rs); this engine sits above them for *open-loop* workloads
//! where future events depend on simulation state: request arrivals in
//! the serving simulation (Fig. 16/17 decode) and the training-step loop.
//!
//! # Queue implementations
//!
//! [`EventQueue`] — the default — is a **calendar queue** (Brown 1988,
//! "Calendar queues: a fast O(1) priority queue implementation"): events
//! hash by time into an array of bucket lists covering a sliding window,
//! so schedule and pop are O(1) amortized instead of the `BinaryHeap`'s
//! O(log n). Payloads live in an arena (`Vec` slab with a free list) and
//! buckets store `u32` handles, so the hot path moves small indices, not
//! payloads. [`HeapEventQueue`] keeps the previous `BinaryHeap`
//! implementation as the reference semantics: the differential property
//! tests (tests/engine_diff.rs) pin the calendar queue to it pop-for-pop,
//! and `flux bench` reports the throughput of both so the speedup stays
//! measured, not assumed.
//!
//! Both implement [`DesQueue`] with the identical total order — ascending
//! event time (IEEE order; non-finite rejected, `-0.0` normalized at the
//! boundary) with exact ties broken FIFO by insertion sequence — so the
//! choice of queue cannot change simulation results, only speed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sim::resources::Time;
use crate::util::prng::Rng;

/// Common interface over the calendar and heap event queues, so the
/// differential tests and the `events_per_sec` bench workload can drive
/// either implementation through one code path.
pub trait DesQueue<E> {
    /// Current simulation time (the timestamp of the last popped event).
    fn now(&self) -> Time;
    /// Number of pending events.
    fn len(&self) -> usize;
    /// True when no events are pending.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Schedule `payload` at absolute time `at`.
    fn schedule(&mut self, at: Time, payload: E);
    /// Schedule `payload` `delay` after now.
    fn schedule_in(&mut self, delay: Time, payload: E);
    /// Pop the next event, advancing the clock.
    fn next(&mut self) -> Option<(Time, E)>;
}

/// Validate and normalize an event time against the current clock.
///
/// Shared by both queue implementations so their admission semantics
/// cannot drift apart. Panics on non-finite `at` (always an upstream
/// arithmetic bug — 0/0 rates, uninitialized ready times — and admitting
/// one would corrupt the time order and the FIFO tie-break for every
/// event behind it) and on `at` more than 1e-9 behind `now` (a genuinely
/// past event; the error names both the event time and the clock).
/// `-0.0` is normalized to `+0.0` so numerically-equal times always fall
/// through to the FIFO `seq` tie-break, and an `at` within the 1e-9
/// float-noise sliver *below* `now` is clamped up to `now`: previously
/// such events were admitted as-is and silently rewound the clock on
/// pop, corrupting every timestamp derived from it afterwards.
#[inline]
fn admit(at: Time, now: Time) -> Time {
    assert!(at.is_finite(), "non-finite event time {at} scheduled at now={now}");
    // Normalize -0.0: `total_cmp` would order it before +0.0, which
    // would let two numerically-equal times bypass the FIFO seq
    // tie-break.
    let at = if at == 0.0 { 0.0 } else { at };
    // Hard assert (release too): a past event would fire behind the
    // clock and silently corrupt every timestamp after it.
    assert!(
        at >= now - 1e-9,
        "scheduling into the past: event time {at} is behind the clock \
         now={now}"
    );
    // Float-noise sliver below `now`: never let the clock rewind.
    if at < now {
        now
    } else {
        at
    }
}

// ---------------------------------------------------------------------------
// Calendar queue
// ---------------------------------------------------------------------------

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;

/// Arena slot: one scheduled event. `payload` is `take()`n on pop and the
/// slot index recycled through the free list.
struct Slot<E> {
    at: Time,
    seq: u64,
    payload: Option<E>,
}

/// One calendar day: event handles in ascending `(at, seq)` order from
/// `head` on; `[..head]` are already popped (drained lazily so pops are
/// O(1) instead of `Vec::remove`'s O(n)).
#[derive(Default)]
struct Bucket {
    items: Vec<u32>,
    head: usize,
}

impl Bucket {
    #[inline]
    fn is_empty(&self) -> bool {
        self.head >= self.items.len()
    }

    fn live(&self) -> &[u32] {
        &self.items[self.head..]
    }
}

/// Deterministic calendar event queue: ties break in insertion order.
///
/// Buckets directly map the time window `[cal_start, far_start)` with
/// `far_start = cal_start + width * n_buckets`; events at or beyond
/// `far_start` wait in an unsorted overflow list and get redistributed
/// when the calendar drains or resizes. The bucket map is monotone in
/// time, and events are only ever scheduled at/after `now`, so a scan
/// cursor (`cur`) can sweep forward without ever revisiting earlier
/// buckets between rebuilds. Rebuilds (grow when `len > 2 * n_buckets`,
/// shrink when `len < n_buckets / 8`, redistribute when the calendar
/// drains into a non-empty overflow list) re-anchor the window on the
/// live events' min/max and are O(len), amortized O(1) per operation.
pub struct EventQueue<E> {
    arena: Vec<Slot<E>>,
    free: Vec<u32>,
    buckets: Vec<Bucket>,
    /// Start of the time window the buckets cover.
    cal_start: Time,
    /// Width of one bucket (> 0, finite).
    width: Time,
    /// First time *not* covered by the buckets: `cal_start + width * nb`.
    far_start: Time,
    /// Scan cursor: every bucket before `cur` is empty.
    cur: usize,
    /// Overflow events at/beyond `far_start`, unsorted.
    far: Vec<u32>,
    len: usize,
    seq: u64,
    now: Time,
    pops: u64,
    rebuilds: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        let mut q = EventQueue {
            arena: Vec::new(),
            free: Vec::new(),
            buckets: Vec::new(),
            cal_start: 0.0,
            width: 1.0,
            far_start: 0.0,
            cur: 0,
            far: Vec::new(),
            len: 0,
            seq: 0,
            now: 0.0,
            pops: 0,
            rebuilds: 0,
        };
        q.buckets.resize_with(MIN_BUCKETS, Bucket::default);
        q.set_calendar(0.0);
        q
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Total events popped so far (deterministic progress counter for the
    /// `events_per_sec` bench section).
    pub fn pops(&self) -> u64 {
        self.pops
    }

    /// Total events scheduled so far.
    pub fn scheduled(&self) -> u64 {
        self.seq
    }

    /// Total calendar rebuilds so far (grow, shrink, and
    /// drain-redistribute all count — the amortized-O(1) claim is only
    /// honest if this stays small relative to [`Self::pops`]).
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Live calendar geometry `(cal_start, width, n_buckets)` — exposed
    /// so the differential property tests can aim events at exact
    /// bucket edges; not part of the stable queue API.
    #[doc(hidden)]
    pub fn bucket_params(&self) -> (Time, Time, usize) {
        (self.cal_start, self.width, self.buckets.len())
    }

    /// High-water mark of the event arena: the peak number of
    /// simultaneously-pending events this queue has ever held (slots
    /// are recycled through the free list, so the slab only grows when
    /// every existing slot is live). A pure function of the
    /// schedule/pop stream — reported by the fleet bench section.
    pub fn slab_high_water(&self) -> usize {
        self.arena.len()
    }

    /// Clear the queue for reuse, keeping every allocation (event
    /// slab, free list, bucket storage). A reset queue is
    /// observationally identical to [`EventQueue::new`] — clock,
    /// counters and calendar geometry all return to their initial
    /// state — but the next run skips the slab growth this one paid
    /// for. The per-worker arenas in `serving::scale` lean on the
    /// identity (the tests pin it).
    pub fn reset(&mut self) {
        self.arena.clear();
        self.free.clear();
        self.buckets.truncate(MIN_BUCKETS);
        for bk in &mut self.buckets {
            bk.items.clear();
            bk.head = 0;
        }
        self.width = 1.0;
        self.cur = 0;
        self.far.clear();
        self.len = 0;
        self.seq = 0;
        self.now = 0.0;
        self.pops = 0;
        self.rebuilds = 0;
        self.set_calendar(0.0);
    }

    /// Re-anchor the window at `start`, keeping the current bucket count
    /// and (roughly) the current width. Doubles the width until the
    /// window has positive float extent: at huge magnitudes
    /// `start + width * nb` can round back to `start`, which would make
    /// every bucket span zero representable times.
    fn set_calendar(&mut self, start: Time) {
        let nb = self.buckets.len() as f64;
        let mut w = self.width;
        if !(w.is_finite() && w > 0.0) {
            w = 1.0;
        }
        while start + w * nb <= start {
            w *= 2.0;
        }
        self.width = w;
        self.cal_start = start;
        self.far_start = start + w * nb;
    }

    #[inline]
    fn key(&self, idx: u32) -> (Time, u64) {
        let s = &self.arena[idx as usize];
        (s.at, s.seq)
    }

    #[inline]
    #[allow(clippy::disallowed_methods)] // see the flux-lint pragma
    fn key_lt(a: (Time, u64), b: (Time, u64)) -> bool {
        // Stored times are finite and -0.0-normalized, so IEEE compare
        // plus the seq tie-break is the same total order as `total_cmp`.
        // flux-lint: allow(D002) -- admit() rejects non-finite times
        match a.0.partial_cmp(&b.0) {
            Some(Ordering::Less) => true,
            Some(Ordering::Greater) => false,
            _ => a.1 < b.1,
        }
    }

    /// File `idx` into its bucket (or the overflow list).
    fn insert(&mut self, idx: u32) {
        let key = self.key(idx);
        let at = key.0;
        if at >= self.far_start {
            self.far.push(idx);
            return;
        }
        // Monotone time→bucket map; `as usize` saturates (negative → 0,
        // huge → MAX), and the clamp catches rounding past the last
        // bucket, so every calendar event lands in range.
        let nb = self.buckets.len();
        let mut b = ((at - self.cal_start) / self.width) as usize;
        if b >= nb {
            b = nb - 1;
        }
        let arena = &self.arena;
        let key_of = |i: u32| {
            let s = &arena[i as usize];
            (s.at, s.seq)
        };
        let bk = &mut self.buckets[b];
        if bk.is_empty() {
            bk.items.clear();
            bk.head = 0;
            bk.items.push(idx);
            return;
        }
        // Fast path: strictly after the bucket's last event. Monotone
        // event streams and exact-tie storms (seq always increases) both
        // take this O(1) append.
        let last = key_of(bk.items[bk.items.len() - 1]);
        if Self::key_lt(last, key) {
            bk.items.push(idx);
            return;
        }
        // Slow path: drop the popped prefix, then sorted-insert.
        if bk.head > 0 {
            bk.items.drain(..bk.head);
            bk.head = 0;
        }
        let pos = bk.items.partition_point(|&i| Self::key_lt(key_of(i), key));
        bk.items.insert(pos, idx);
    }

    /// Collect every live event and redistribute into `target_len`-sized
    /// calendar re-anchored on the live min/max times.
    fn rebuild(&mut self, target_len: usize) {
        self.rebuilds += 1;
        let mut scratch: Vec<u32> = Vec::with_capacity(self.len);
        for bk in &mut self.buckets {
            scratch.extend_from_slice(bk.live());
            bk.items.clear();
            bk.head = 0;
        }
        scratch.append(&mut self.far);
        self.cur = 0;
        if scratch.is_empty() {
            return;
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &i in &scratch {
            let at = self.arena[i as usize].at;
            if at < lo {
                lo = at;
            }
            if at > hi {
                hi = at;
            }
        }
        let nb = target_len
            .max(1)
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        if nb != self.buckets.len() {
            self.buckets.clear();
            self.buckets.resize_with(nb, Bucket::default);
        }
        let span = hi - lo;
        let mut w = if span > 0.0 { span / nb as f64 } else { 1.0 };
        if !(w.is_finite() && w > 0.0) {
            w = 1.0;
        }
        self.width = w;
        // `set_calendar` guarantees far_start > lo, so the earliest event
        // always lands in the calendar and the drain loop makes progress.
        self.set_calendar(lo);
        for idx in scratch {
            self.insert(idx);
        }
    }

    /// Admit and file one event; the caller owes the grow check.
    #[inline]
    fn admit_one(&mut self, at: Time, payload: E) {
        let at = admit(at, self.now);
        if self.len == 0 {
            // Empty queue: re-anchor the window on the new event so a
            // simulation idling far from t=0 doesn't funnel everything
            // through the overflow list.
            self.cur = 0;
            self.set_calendar(at);
        }
        let seq = self.seq;
        self.seq += 1;
        self.len += 1;
        let idx = match self.free.pop() {
            Some(idx) => {
                self.arena[idx as usize] =
                    Slot { at, seq, payload: Some(payload) };
                idx
            }
            None => {
                assert!(
                    self.arena.len() < u32::MAX as usize,
                    "event arena exhausted u32 handles"
                );
                self.arena.push(Slot { at, seq, payload: Some(payload) });
                (self.arena.len() - 1) as u32
            }
        };
        self.insert(idx);
    }

    /// Re-run the resize policy after admissions: grow when the load
    /// factor passes 2 events/bucket (same threshold whether events
    /// arrived one at a time or in a batch).
    #[inline]
    fn maybe_grow(&mut self) {
        if self.len > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS
        {
            self.rebuild(self.len);
        }
    }

    /// Schedule `payload` at absolute time `at` (>= now).
    ///
    /// Panics on non-finite `at` and on times behind the clock; times in
    /// the 1e-9 float-noise sliver below `now` are clamped to `now` so a
    /// pop can never rewind the clock. See the shared `admit` validation
    /// for the rationale.
    pub fn schedule(&mut self, at: Time, payload: E) {
        self.admit_one(at, payload);
        self.maybe_grow();
    }

    /// Batch-admit a stream of events in iteration order.
    ///
    /// Each event passes the exact same `admit` validation and takes
    /// consecutive `seq` numbers, so ties break exactly as the
    /// equivalent sequence of [`EventQueue::schedule`] calls would and
    /// the pop sequence is identical (the differential tests pin
    /// this). What's amortized is the *resize policy*: the grow check
    /// runs once after the whole batch instead of per event, so a
    /// large pre-scheduled arrival stream (time-sorted, which takes
    /// the bucket fast path) admits without intermediate rebuilds.
    pub fn schedule_many<I>(&mut self, events: I)
    where
        I: IntoIterator<Item = (Time, E)>,
    {
        for (at, payload) in events {
            self.admit_one(at, payload);
        }
        self.maybe_grow();
    }

    /// Schedule `payload` `delay` after now.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        let at = self.now + delay;
        self.schedule(at, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, E)> {
        if self.len == 0 {
            return None;
        }
        loop {
            let nb = self.buckets.len();
            while self.cur < nb {
                let bk = &mut self.buckets[self.cur];
                if !bk.is_empty() {
                    let idx = bk.items[bk.head];
                    bk.head += 1;
                    if bk.head == bk.items.len() {
                        bk.items.clear();
                        bk.head = 0;
                    }
                    let slot = &mut self.arena[idx as usize];
                    let at = slot.at;
                    let payload =
                        slot.payload.take().expect("live slot has a payload");
                    self.free.push(idx);
                    self.len -= 1;
                    self.pops += 1;
                    self.now = at;
                    if self.len == 0 {
                        self.cur = 0;
                    } else if nb > MIN_BUCKETS && self.len < nb / 8 {
                        self.rebuild(self.len);
                    }
                    return Some((at, payload));
                }
                self.cur += 1;
            }
            // Calendar drained with events pending: they are all in the
            // overflow list; re-anchor the window on them.
            assert!(
                !self.far.is_empty(),
                "event queue invariant: len={} but no events anywhere",
                self.len
            );
            self.rebuild(self.len);
        }
    }
}

impl<E> DesQueue<E> for EventQueue<E> {
    fn now(&self) -> Time {
        EventQueue::now(self)
    }
    fn len(&self) -> usize {
        EventQueue::len(self)
    }
    fn schedule(&mut self, at: Time, payload: E) {
        EventQueue::schedule(self, at, payload);
    }
    fn schedule_in(&mut self, delay: Time, payload: E) {
        EventQueue::schedule_in(self, delay, payload);
    }
    fn next(&mut self) -> Option<(Time, E)> {
        EventQueue::next(self)
    }
}

// ---------------------------------------------------------------------------
// Reference heap queue
// ---------------------------------------------------------------------------

/// An event: fires at `at`, carrying a payload `E`.
struct Scheduled<E> {
    at: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time (then lower seq for FIFO ties) first.
        // `total_cmp` keeps the ordering total for every float the heap
        // can hold: non-finite times are rejected and -0.0 normalized at
        // `schedule()`, so numerically-equal times always fall through
        // to the FIFO `seq` tie-break.
        other
            .at
            .total_cmp(&self.at)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The previous `BinaryHeap` event queue, kept as the reference
/// implementation: identical admission rules and total order as
/// [`EventQueue`], O(log n) per operation. The differential property
/// tests replay identical streams through both and require pop-for-pop
/// equality; `flux bench --wall` reports both queues' throughput so the
/// calendar queue's speedup is a measured number.
pub struct HeapEventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: Time,
    pops: u64,
}

impl<E> Default for HeapEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapEventQueue<E> {
    pub fn new() -> Self {
        HeapEventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0, pops: 0 }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Total events popped so far.
    pub fn pops(&self) -> u64 {
        self.pops
    }

    /// Total events scheduled so far.
    pub fn scheduled(&self) -> u64 {
        self.seq
    }

    /// Schedule `payload` at absolute time `at` (>= now); same admission
    /// rules as [`EventQueue::schedule`].
    pub fn schedule(&mut self, at: Time, payload: E) {
        let at = admit(at, self.now);
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` after now.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        let at = self.now + delay;
        self.schedule(at, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|s| {
            self.now = s.at;
            self.pops += 1;
            (s.at, s.payload)
        })
    }
}

impl<E> DesQueue<E> for HeapEventQueue<E> {
    fn now(&self) -> Time {
        HeapEventQueue::now(self)
    }
    fn len(&self) -> usize {
        HeapEventQueue::len(self)
    }
    fn schedule(&mut self, at: Time, payload: E) {
        HeapEventQueue::schedule(self, at, payload);
    }
    fn schedule_in(&mut self, delay: Time, payload: E) {
        HeapEventQueue::schedule_in(self, delay, payload);
    }
    fn next(&mut self) -> Option<(Time, E)> {
        HeapEventQueue::next(self)
    }
}

// ---------------------------------------------------------------------------
// Hold-model bench workload
// ---------------------------------------------------------------------------

/// Result of one [`hold_workload`] run. `pops`, `schedules` and
/// `checksum` are pure functions of `(resident, ops, seed)` — identical
/// across machines and across queue implementations — while `wall_ns` is
/// machine-local and only reported behind `flux bench --wall`.
#[derive(Clone, Debug)]
pub struct HoldRun {
    pub resident: usize,
    pub ops: usize,
    pub pops: u64,
    pub schedules: u64,
    /// FNV-1a fold of every popped `(time bits, payload)` pair: equal
    /// checksums across queue implementations certify identical pop
    /// sequences without storing them.
    pub checksum: u64,
    /// Peak pending-event population — for the calendar queue exactly
    /// its slab high-water mark ([`EventQueue::slab_high_water`]),
    /// tracked here through the queue-agnostic `len()` so the heap
    /// reference reports the same number.
    pub high_water: usize,
    pub wall_ns: f64,
}

#[inline]
fn fnv_fold(acc: u64, x: u64) -> u64 {
    (acc ^ x).wrapping_mul(0x100_0000_01b3)
}

/// The classic *hold model* queue benchmark (Vaucher & Duval 1975): keep
/// `resident` events pending and repeat pop-one/schedule-one `ops` times,
/// then drain. Gaps are mostly short (steady-state serving traffic) with
/// occasional 1e5× far jumps that force the calendar through its
/// overflow/rebuild path, plus exact ties; the same seeded stream drives
/// both queue implementations.
pub fn hold_workload(resident: usize, ops: usize, seed: u64) -> HoldRun {
    run_hold(EventQueue::new(), resident, ops, seed)
}

/// [`hold_workload`] through the reference [`HeapEventQueue`].
pub fn hold_workload_heap(resident: usize, ops: usize, seed: u64) -> HoldRun {
    run_hold(HeapEventQueue::new(), resident, ops, seed)
}

fn run_hold<Q: DesQueue<u64>>(
    mut q: Q,
    resident: usize,
    ops: usize,
    seed: u64,
) -> HoldRun {
    assert!(resident > 0, "hold workload needs a resident population");
    let mut rng = Rng::new(seed);
    let mut checksum = 0xcbf2_9ce4_8422_2325u64;
    let start = crate::util::bench::Stopwatch::start();
    for i in 0..resident {
        q.schedule(rng.f64() * 1e6, i as u64);
    }
    let mut schedules = resident as u64;
    let mut pops = 0u64;
    let mut high_water = q.len();
    for _ in 0..ops {
        let (t, p) = q.next().expect("resident population never drains");
        pops += 1;
        checksum = fnv_fold(checksum, t.to_bits() ^ p);
        let gap = match rng.below(64) {
            0 => rng.f64() * 2.0e8, // far jump: exercises overflow list
            1 => 0.0,               // exact tie: exercises FIFO order
            _ => rng.f64() * 2.0e3, // steady state
        };
        q.schedule(t + gap, p);
        schedules += 1;
        if q.len() > high_water {
            high_water = q.len();
        }
    }
    while let Some((t, p)) = q.next() {
        pops += 1;
        checksum = fnv_fold(checksum, t.to_bits() ^ p);
    }
    let wall_ns = start.elapsed_ns();
    HoldRun { resident, ops, pops, schedules, checksum, high_water, wall_ns }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30.0, "c");
        q.schedule(10.0, "a");
        q.schedule(20.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.next())
            .map(|(_, e)| e)
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5.0, 1);
        q.schedule(5.0, 2);
        q.schedule(5.0, 3);
        let order: Vec<i32> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(7.5, ());
        assert_eq!(q.now(), 0.0);
        q.next();
        assert_eq!(q.now(), 7.5);
        q.schedule_in(2.5, ());
        let (t, _) = q.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(10.0, ());
        q.next();
        q.schedule(5.0, ());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn heap_rejects_past_scheduling() {
        let mut q = HeapEventQueue::new();
        q.schedule(10.0, ());
        q.next();
        q.schedule(5.0, ());
    }

    #[test]
    fn past_float_sliver_clamps_to_now() {
        // An event 1e-10 behind the clock is float noise, not a bug; it
        // used to be admitted as-is and *rewind* the clock on pop. Now it
        // fires exactly at `now`.
        let mut q = EventQueue::new();
        q.schedule(10.0, "first");
        q.next();
        q.schedule(10.0 - 1e-10, "sliver");
        let (t, e) = q.next().unwrap();
        assert_eq!(t, 10.0);
        assert_eq!(e, "sliver");
        assert_eq!(q.now(), 10.0);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_infinite_time() {
        let mut q = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_nan_delay() {
        let mut q = EventQueue::new();
        q.schedule_in(f64::NAN, ());
    }

    #[test]
    fn adversarial_timestamps_stay_totally_ordered() {
        // -0.0 == 0.0 must be a *tie* (FIFO by seq), subnormals and
        // near-identical times must not perturb the order, and a dense
        // run of exact ties must drain strictly in insertion order.
        let mut q = EventQueue::new();
        q.schedule(0.0, "a");
        q.schedule(-0.0, "b");
        q.schedule(f64::MIN_POSITIVE, "c");
        q.schedule(0.0, "d");
        let order: Vec<&str> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "d", "c"]);

        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.schedule(5.0, i);
        }
        let order: Vec<u32> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn grow_shrink_and_overflow_keep_sorted_order() {
        // Push the queue through every resize path: enough events to grow
        // past MIN_BUCKETS several times, times spread over ten orders of
        // magnitude so the overflow list and window re-anchoring engage,
        // then a full drain (exercising shrink rebuilds on the way down).
        let mut rng = Rng::new(0xCA1E);
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            let at = match rng.below(16) {
                0 => rng.f64() * 1e10,
                1 => (rng.below(32) as f64) * 0.5, // tie lattice
                _ => rng.f64() * 1e3,
            };
            q.schedule(at, i);
        }
        assert_eq!(q.len(), 10_000);
        let mut last: Option<(Time, u64)> = None;
        let mut n = 0;
        while let Some((t, p)) = q.next() {
            if let Some((lt, _)) = last {
                assert!(t >= lt, "time went backwards: {t} < {lt}");
            }
            last = Some((t, p));
            n += 1;
        }
        assert_eq!(n, 10_000);
        assert_eq!(q.pops(), 10_000);
        assert_eq!(q.scheduled(), 10_000);
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queue_reanchors_far_from_origin() {
        // Drain to empty at a huge timestamp, then keep scheduling: the
        // window must re-anchor instead of funnelling everything through
        // the overflow path forever (and ULP(1e18) >> default width must
        // not wedge the window at zero extent).
        let mut q = EventQueue::new();
        q.schedule(1e18, 0u64);
        q.next();
        q.schedule(1e18, 1);
        q.schedule(1e18 + 1e4, 2);
        q.schedule(1e18, 3);
        let order: Vec<u64> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 3, 2]);
    }

    #[test]
    fn hold_workload_checksum_matches_heap_reference() {
        // Same seeded stream through both implementations: identical
        // deterministic counters and pop-sequence checksum.
        let a = hold_workload(64, 2_000, 0xBEEF);
        let b = hold_workload_heap(64, 2_000, 0xBEEF);
        assert_eq!(a.checksum, b.checksum);
        assert_eq!(a.pops, b.pops);
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.pops, 64 + 2_000);
        assert_eq!(a.schedules, 64 + 2_000);
        // The hold model keeps the population constant, so the peak is
        // exactly the resident count — on both implementations.
        assert_eq!(a.high_water, 64);
        assert_eq!(b.high_water, 64);
    }

    #[test]
    fn schedule_many_pops_identically_to_single_schedules() {
        // The batch admit defers only the resize policy; admission
        // order, seq numbering and therefore the full pop sequence
        // must match event-for-event.
        let mut rng = Rng::new(0xFEE7);
        let stream: Vec<(Time, u64)> = (0..5_000u64)
            .map(|i| {
                let at = match rng.below(8) {
                    0 => rng.f64() * 1e9, // overflow territory
                    1 => 250.0,           // tie lattice
                    _ => rng.f64() * 1e4,
                };
                (at, i)
            })
            .collect();
        let mut one = EventQueue::new();
        for &(at, p) in &stream {
            one.schedule(at, p);
        }
        let mut many = EventQueue::new();
        many.schedule_many(stream.iter().copied());
        assert_eq!(one.len(), many.len());
        assert_eq!(one.scheduled(), many.scheduled());
        assert_eq!(one.slab_high_water(), many.slab_high_water());
        loop {
            match (one.next(), many.next()) {
                (None, None) => break,
                (a, b) => assert_eq!(
                    a.map(|(t, p)| (t.to_bits(), p)),
                    b.map(|(t, p)| (t.to_bits(), p))
                ),
            }
        }
    }

    #[test]
    fn reset_queue_replays_like_a_fresh_one() {
        // reset() must restore new-queue state exactly (slab capacity
        // aside): the same seeded hold stream replayed through a
        // recycled queue reproduces counters, checksum and geometry.
        let fresh = hold_workload(256, 5_000, 0x0E5C);
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule(i as f64 * 3.5, i);
        }
        while q.next().is_some() {}
        assert_eq!(q.slab_high_water(), 10_000);
        q.reset();
        assert_eq!(q.len(), 0);
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.pops(), 0);
        assert_eq!(q.scheduled(), 0);
        assert_eq!(q.rebuilds(), 0);
        assert_eq!(q.slab_high_water(), 0);
        assert_eq!(
            q.bucket_params(),
            EventQueue::<u64>::new().bucket_params()
        );
        let recycled = run_hold(q, 256, 5_000, 0x0E5C);
        assert_eq!(recycled.checksum, fresh.checksum);
        assert_eq!(recycled.pops, fresh.pops);
        assert_eq!(recycled.schedules, fresh.schedules);
        assert_eq!(recycled.high_water, fresh.high_water);
    }

    #[test]
    fn slab_high_water_tracks_peak_population() {
        let mut q = EventQueue::new();
        for i in 0..100u64 {
            q.schedule(i as f64, i);
        }
        assert_eq!(q.slab_high_water(), 100);
        for _ in 0..50 {
            q.next();
        }
        // Pops recycle slots; the slab remembers the peak.
        assert_eq!(q.len(), 50);
        assert_eq!(q.slab_high_water(), 100);
        // Refilling reuses freed slots before growing.
        q.schedule_many((0..50u64).map(|i| (1e3 + i as f64, i)));
        assert_eq!(q.slab_high_water(), 100);
        q.schedule(2e3, 7);
        assert_eq!(q.slab_high_water(), 101);
    }
}
