//! Classic event-queue DES engine.
//!
//! The kernel/link layers use the forward-scheduling resource calculus
//! (resources.rs); this engine sits above them for *open-loop* workloads
//! where future events depend on simulation state: request arrivals in
//! the serving simulation (Fig. 16/17 decode) and the training-step loop.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sim::resources::Time;

/// An event: fires at `at`, carrying a payload `E`.
struct Scheduled<E> {
    at: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time (then lower seq for FIFO ties) first.
        // `total_cmp` keeps the ordering total for every float the heap
        // can hold: non-finite times are rejected and -0.0 normalized at
        // `schedule()`, so numerically-equal times always fall through
        // to the FIFO `seq` tie-break.
        other
            .at
            .total_cmp(&self.at)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue: ties break in insertion order.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: Time,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (>= now).
    ///
    /// Panics on non-finite `at`: a NaN or infinite event time is always
    /// an upstream arithmetic bug (0/0 rates, uninitialized ready times),
    /// and admitting one would corrupt both the time order and the FIFO
    /// `seq` tie-break for every event behind it. Rejecting at the
    /// boundary, in release builds too, keeps the corruption from
    /// propagating silently through a long serving simulation.
    pub fn schedule(&mut self, at: Time, payload: E) {
        assert!(
            at.is_finite(),
            "non-finite event time {at} scheduled at now={}",
            self.now
        );
        // Normalize -0.0: `total_cmp` would order it before +0.0, which
        // would let two numerically-equal times bypass the FIFO seq
        // tie-break.
        let at = if at == 0.0 { 0.0 } else { at };
        // Hard assert (release too): a past event would rewind `now` on
        // pop and silently corrupt every timestamp after it.
        assert!(
            at >= self.now - 1e-9,
            "scheduling into the past: {at} < {}",
            self.now
        );
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` after now.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        let at = self.now + delay;
        self.schedule(at, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|s| {
            self.now = s.at;
            (s.at, s.payload)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30.0, "c");
        q.schedule(10.0, "a");
        q.schedule(20.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.next())
            .map(|(_, e)| e)
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5.0, 1);
        q.schedule(5.0, 2);
        q.schedule(5.0, 3);
        let order: Vec<i32> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(7.5, ());
        assert_eq!(q.now(), 0.0);
        q.next();
        assert_eq!(q.now(), 7.5);
        q.schedule_in(2.5, ());
        let (t, _) = q.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(10.0, ());
        q.next();
        q.schedule(5.0, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_infinite_time() {
        let mut q = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_nan_delay() {
        let mut q = EventQueue::new();
        q.schedule_in(f64::NAN, ());
    }

    #[test]
    fn adversarial_timestamps_stay_totally_ordered() {
        // -0.0 == 0.0 must be a *tie* (FIFO by seq), subnormals and
        // near-identical times must not perturb the order, and a dense
        // run of exact ties must drain strictly in insertion order.
        let mut q = EventQueue::new();
        q.schedule(0.0, "a");
        q.schedule(-0.0, "b");
        q.schedule(f64::MIN_POSITIVE, "c");
        q.schedule(0.0, "d");
        let order: Vec<&str> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "d", "c"]);

        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.schedule(5.0, i);
        }
        let order: Vec<u32> =
            std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }
}
