//! Resource primitives for the discrete-event cluster simulator.
//!
//! The simulator is a *list-scheduling* DES: work items arrive with ready
//! times, resources serialize or pool them, and completion times propagate
//! forward. Three primitives cover every device-side phenomenon the paper
//! depends on:
//!
//! * [`Serial`] — a FIFO resource (a link direction, a memory-controller
//!   write port, a CUDA stream): one item at a time.
//! * [`Pool`] — a k-server resource (the SM array): k items concurrently,
//!   each new item takes the earliest-free slot. This is exactly the GPU
//!   thread-block scheduler's behaviour for persistent-occupancy kernels,
//!   and is what produces *wave quantization* — the split-GEMM efficiency
//!   cliff of §2.2/Fig. 5.
//! * [`Rate`] — a fluid-approximation bandwidth resource for links shared
//!   by many concurrent transfers.

pub type Time = f64; // nanoseconds

/// FIFO serial resource.
#[derive(Clone, Debug, Default)]
pub struct Serial {
    free_at: Time,
    busy: Time,
}

impl Serial {
    pub fn new() -> Self {
        Serial { free_at: 0.0, busy: 0.0 }
    }

    /// Schedule an item that becomes ready at `ready` and holds the
    /// resource for `dur`. Returns (start, end).
    pub fn acquire(&mut self, ready: Time, dur: Time) -> (Time, Time) {
        let start = ready.max(self.free_at);
        let end = start + dur;
        self.free_at = end;
        self.busy += dur;
        (start, end)
    }

    pub fn free_at(&self) -> Time {
        self.free_at
    }

    /// Total busy time — utilization accounting for reports.
    pub fn busy_time(&self) -> Time {
        self.busy
    }

    pub fn reset(&mut self) {
        self.free_at = 0.0;
        self.busy = 0.0;
    }
}

/// k-server pool: models the SM array (or any array of identical
/// execution slots). `acquire` assigns the earliest-free slot.
///
/// Implementation: a min-heap of slot free-times — O(log k) per acquire
/// (the original linear scan was the top entry in the §Perf profile;
/// see EXPERIMENTS.md §Perf L3-1).
#[derive(Clone, Debug)]
pub struct Pool {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<OrdTime>>,
    k: usize,
    busy: Time,
}

/// Total-ordered f64 wrapper for the heap (simulation times are never
/// NaN).
#[derive(Clone, Copy, Debug, PartialEq)]
struct OrdTime(Time);
impl Eq for OrdTime {}
impl PartialOrd for OrdTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl Pool {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "pool must have at least one slot");
        let mut heap = std::collections::BinaryHeap::with_capacity(k);
        for _ in 0..k {
            heap.push(std::cmp::Reverse(OrdTime(0.0)));
        }
        Pool { heap, k, busy: 0.0 }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Earliest-free-slot assignment. The item occupies the slot from
    /// max(ready, slot_free) until that + dur. Returns (start, end).
    ///
    /// NOTE: this models *non-preemptive* residency — a thread block that
    /// spins on a signal (Alg. 2's WaitSignal) still occupies its slot.
    /// Latency hiding across blocks comes from k > #SMs (multiple blocks
    /// resident per SM), exactly as on hardware.
    pub fn acquire(&mut self, ready: Time, dur: Time) -> (Time, Time) {
        let slot = self.heap.pop().unwrap().0 .0;
        let start = ready.max(slot);
        let end = start + dur;
        self.heap.push(std::cmp::Reverse(OrdTime(end)));
        self.busy += dur;
        (start, end)
    }

    /// Like `acquire`, but the slot is *held* starting from the earlier
    /// of (ready, slot availability): this is how a blocked-on-signal tile
    /// occupies residency while spinning. Returns (start_of_work, end).
    pub fn acquire_spinning(
        &mut self,
        issue: Time,
        signal: Time,
        dur: Time,
    ) -> (Time, Time) {
        let slot = self.heap.pop().unwrap().0 .0;
        // The block is placed on the slot as soon as both the slot and the
        // launch allow; it then spins until `signal`.
        let placed = issue.max(slot);
        let start = placed.max(signal);
        let end = start + dur;
        self.heap.push(std::cmp::Reverse(OrdTime(end)));
        self.busy += dur + (start - placed); // spin time counts as busy
        (start, end)
    }

    /// When will the whole pool drain?
    pub fn makespan(&self) -> Time {
        self.heap
            .iter()
            .map(|r| r.0 .0)
            .fold(0.0, Time::max)
    }

    pub fn busy_time(&self) -> Time {
        self.busy
    }

    pub fn reset(&mut self) {
        self.heap.clear();
        for _ in 0..self.k {
            self.heap.push(std::cmp::Reverse(OrdTime(0.0)));
        }
        self.busy = 0.0;
    }
}

/// Fluid bandwidth resource: transfers queue FIFO, each occupying the
/// pipe for bytes/bw. Equivalent to `Serial` but parameterized in bytes.
#[derive(Clone, Debug)]
pub struct Rate {
    pub bytes_per_ns: f64,
    pub latency_ns: f64,
    serial: Serial,
}

impl Rate {
    pub fn new(gigabytes_per_s: f64, latency_us: f64) -> Self {
        Rate {
            bytes_per_ns: gigabytes_per_s * 1e9 / 1e9, // GB/s == bytes/ns
            latency_ns: latency_us * 1e3,
            serial: Serial::new(),
        }
    }

    /// Queue a transfer of `bytes` ready at `ready`; returns (start, end)
    /// where end includes the propagation latency.
    pub fn transfer(&mut self, ready: Time, bytes: f64) -> (Time, Time) {
        let dur = bytes / self.bytes_per_ns;
        let (start, end) = self.serial.acquire(ready, dur);
        (start, end + self.latency_ns)
    }

    pub fn free_at(&self) -> Time {
        self.serial.free_at()
    }

    pub fn busy_time(&self) -> Time {
        self.serial.busy_time()
    }

    pub fn reset(&mut self) {
        self.serial.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_fifo_order() {
        let mut r = Serial::new();
        let (s1, e1) = r.acquire(0.0, 10.0);
        let (s2, e2) = r.acquire(0.0, 10.0);
        assert_eq!((s1, e1), (0.0, 10.0));
        assert_eq!((s2, e2), (10.0, 20.0));
        // Item ready later than free time starts at its ready time.
        let (s3, _) = r.acquire(100.0, 5.0);
        assert_eq!(s3, 100.0);
    }

    #[test]
    fn pool_runs_k_concurrently() {
        let mut p = Pool::new(4);
        let ends: Vec<Time> =
            (0..8).map(|_| p.acquire(0.0, 10.0).1).collect();
        // First 4 finish at 10, next 4 at 20 — two waves.
        assert_eq!(ends[..4], [10.0, 10.0, 10.0, 10.0]);
        assert_eq!(ends[4..], [20.0, 20.0, 20.0, 20.0]);
        assert_eq!(p.makespan(), 20.0);
    }

    #[test]
    fn pool_wave_quantization() {
        // 5 tiles on 4 slots takes 2 waves even though work is 1.25 waves:
        // the signature inefficiency that splitting GEMMs multiplies.
        let mut p = Pool::new(4);
        let end = (0..5).map(|_| p.acquire(0.0, 10.0).1).fold(0.0, f64::max);
        assert_eq!(end, 20.0);
    }

    #[test]
    fn spinning_occupies_slot() {
        let mut p = Pool::new(1);
        // Block placed at t=0 but its signal arrives at t=50.
        let (s, e) = p.acquire_spinning(0.0, 50.0, 10.0);
        assert_eq!((s, e), (50.0, 60.0));
        // Next block cannot be placed until the spinner's slot frees.
        let (s2, _) = p.acquire_spinning(0.0, 0.0, 10.0);
        assert_eq!(s2, 60.0);
    }

    #[test]
    fn rate_transfer_time() {
        let mut r = Rate::new(100.0, 1.0); // 100 GB/s, 1us latency
        let (s, e) = r.transfer(0.0, 100e9 * 1e-3); // 100MB
        assert_eq!(s, 0.0);
        // 100MB at 100GB/s = 1ms + 1us latency.
        assert!((e - (1e6 + 1e3)).abs() < 1e-6, "e={e}");
    }

    #[test]
    fn busy_accounting() {
        let mut p = Pool::new(2);
        p.acquire(0.0, 5.0);
        p.acquire(0.0, 7.0);
        assert_eq!(p.busy_time(), 12.0);
    }
}
