//! Chrome trace-event exporter for the DES.
//!
//! `flux simulate --scale|--train --trace <path>` dumps the event
//! stream as a chrome://tracing / Perfetto JSON object
//! (`{"traceEvents": [...]}`): one *pid* per replica or pipeline
//! stage (method lanes get disjoint pid ranges, named via metadata
//! events), complete-`"X"` spans for scheduler steps and transfers,
//! instant-`"i"` events for arrivals. Timestamps are microseconds
//! (the format's unit); simulation times are ns.
//!
//! Byte-stability: events are emitted in DES execution order and the
//! JSON writer is deterministic, so a fixed seed produces an
//! identical file across reruns — the same contract as the report
//! emitters, and what the CLI test byte-checks.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// An in-memory trace being collected by a simulation run.
#[derive(Debug, Default)]
pub struct Trace {
    events: Vec<Json>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Name a process lane (chrome metadata event). Call once per pid
    /// before its spans for a readable timeline.
    pub fn process_name(&mut self, pid: usize, name: &str) {
        self.events.push(obj(vec![
            ("ph", Json::from("M")),
            ("name", Json::from("process_name")),
            ("pid", Json::from(pid)),
            ("tid", Json::from(0usize)),
            (
                "args",
                obj(vec![("name", Json::from(name))]),
            ),
        ]));
    }

    /// A complete span: `[start_ns, start_ns + dur_ns)` on (pid, tid).
    pub fn span(
        &mut self,
        pid: usize,
        tid: usize,
        name: &str,
        start_ns: f64,
        dur_ns: f64,
        args: Vec<(&str, Json)>,
    ) {
        let mut ev = vec![
            ("ph", Json::from("X")),
            ("name", Json::from(name)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(tid)),
            ("ts", Json::from(start_ns / 1e3)),
            ("dur", Json::from(dur_ns / 1e3)),
        ];
        if !args.is_empty() {
            ev.push(("args", obj(args)));
        }
        self.events.push(obj(ev));
    }

    /// An instant event at `ts_ns` on (pid, tid).
    pub fn instant(
        &mut self,
        pid: usize,
        tid: usize,
        name: &str,
        ts_ns: f64,
        args: Vec<(&str, Json)>,
    ) {
        let mut ev = vec![
            ("ph", Json::from("i")),
            ("s", Json::from("t")), // thread-scoped instant
            ("name", Json::from(name)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(tid)),
            ("ts", Json::from(ts_ns / 1e3)),
        ];
        if !args.is_empty() {
            ev.push(("args", obj(args)));
        }
        self.events.push(obj(ev));
    }

    /// A counter-track sample (`"C"` phase) at `ts_ns` on `pid`:
    /// chrome://tracing renders one stacked track per counter name,
    /// which is how sampled gauges (queue depth, KV occupancy) appear
    /// alongside the span lanes.
    pub fn counter(
        &mut self,
        pid: usize,
        name: &str,
        ts_ns: f64,
        values: Vec<(&str, Json)>,
    ) {
        self.events.push(obj(vec![
            ("ph", Json::from("C")),
            ("name", Json::from(name)),
            ("pid", Json::from(pid)),
            ("ts", Json::from(ts_ns / 1e3)),
            ("args", obj(values)),
        ]));
    }

    /// The chrome://tracing document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("displayTimeUnit", Json::from("ms")),
            ("traceEvents", Json::Arr(self.events.clone())),
        ])
    }

    /// Write the document to `path`. Failures name the path (a
    /// `--trace` argument under a missing or read-only parent used to
    /// surface as a bare io error).
    pub fn write(&self, path: &Path) -> Result<()> {
        crate::util::fsio::write_text(path, &self.to_json().to_string())
            .context("writing chrome trace")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new();
        t.process_name(0, "flux/replica0");
        t.instant(0, 0, "arrive", 1500.0, vec![("req", Json::from(3usize))]);
        t.span(
            0,
            0,
            "prefill",
            2000.0,
            5_000_000.0,
            vec![("batch", Json::from(4usize))],
        );
        t.span(0, 1, "hop", 2500.0, 1000.0, Vec::new());
        t
    }

    #[test]
    fn emits_chrome_trace_shape() {
        let doc = sample().to_json();
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 4);
        // Metadata first, then the instant, then spans.
        assert_eq!(evs[0].get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(evs[1].get("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(evs[2].get("ph").unwrap().as_str().unwrap(), "X");
        // ns -> us conversion.
        assert_eq!(evs[2].get("ts").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(evs[2].get("dur").unwrap().as_f64().unwrap(), 5000.0);
        assert_eq!(
            evs[2]
                .get("args")
                .unwrap()
                .get("batch")
                .unwrap()
                .as_usize()
                .unwrap(),
            4
        );
    }

    #[test]
    fn counter_events_pin_the_chrome_counter_shape() {
        // Regression (satellite): the "C"-phase counter track emission
        // is byte-stable and carries its samples in `args`.
        let build = || {
            let mut t = Trace::new();
            t.process_name(3, "flux/replica0");
            t.counter(
                3,
                "serve.queue_depth",
                2_000_000.0,
                vec![("value", Json::from(5.0))],
            );
            t.counter(
                3,
                "serve.kv_used_blocks",
                2_000_000.0,
                vec![("value", Json::from(128.0))],
            );
            t.to_json().to_string()
        };
        let a = build();
        assert_eq!(a, build(), "counter emission must be byte-stable");
        assert_eq!(
            a,
            "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\
             {\"args\":{\"name\":\"flux/replica0\"},\"name\":\
             \"process_name\",\"ph\":\"M\",\"pid\":3,\"tid\":0},\
             {\"args\":{\"value\":5},\"name\":\"serve.queue_depth\",\
             \"ph\":\"C\",\"pid\":3,\"ts\":2000},\
             {\"args\":{\"value\":128},\"name\":\
             \"serve.kv_used_blocks\",\"ph\":\"C\",\"pid\":3,\
             \"ts\":2000}]}"
        );
    }

    #[test]
    fn serialization_is_deterministic() {
        assert_eq!(
            sample().to_json().to_string(),
            sample().to_json().to_string()
        );
    }

    #[test]
    fn write_round_trips_through_disk() {
        let dir = std::env::temp_dir().join("flux_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("t.json");
        let t = sample();
        t.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, t.to_json().to_string());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
