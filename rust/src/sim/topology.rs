//! Interconnect topology: link resources for one TP group.
//!
//! Builds the link graph for the three §5 clusters (and multi-node
//! extensions), then schedules point-to-point transfers over it.
//! A transfer holds every link on its path for `bytes / bottleneck_bw`
//! (cut-through approximation); contention is FIFO queueing on the shared
//! links, which is precisely what makes communication *order* matter
//! (§4.1 Fig. 7, §4.3 ring order, NUMA-aware PCIe scheduling).
//!
//! The destination's ingress resource doubles as its memory-controller
//! write port: N ranks P2P-writing the same device at the same instant
//! queue behind each other — the contention the naive (unswizzled) tile
//! mapping suffers.

use crate::cost::arch::{ClusterSpec, Intra};
use crate::sim::resources::{Serial, Time};

/// Index of a link resource inside `Net::res`.
type ResId = usize;

/// Tiny fixed-capacity path builder (max 6 hops in any topology here).
struct PathBuf6 {
    ids: [ResId; 6],
    len: usize,
}

impl PathBuf6 {
    fn new() -> Self {
        PathBuf6 { ids: [0; 6], len: 0 }
    }
    #[inline]
    fn push(&mut self, id: ResId) {
        self.ids[self.len] = id;
        self.len += 1;
    }
}

#[derive(Clone, Debug)]
struct Link {
    res: Serial,
    gbps: f64,
}

/// The link graph for `n` TP ranks laid out over one or more nodes.
#[derive(Clone, Debug)]
pub struct Net {
    pub spec: ClusterSpec,
    pub n: usize,
    res: Vec<Link>,
    /// Per-rank egress / ingress port (NVLink fabric port or PCIe link).
    egress: Vec<ResId>,
    ingress: Vec<ResId>,
    /// PCIe only: shared switch uplink per NUMA domain, [up, down]
    /// (PCIe is full duplex). Index [node][domain][direction].
    numa_up: Vec<Vec<[ResId; 2]>>,
    /// PCIe only: inter-socket link per node, one resource per
    /// direction (UPI/QPI is full duplex). Index [node][direction].
    numa_x: Vec<[ResId; 2]>,
    /// Per-rank NIC share for inter-node traffic, [tx, rx] (full duplex).
    nic: Vec<[ResId; 2]>,
}

impl Net {
    pub fn new(spec: &ClusterSpec, n: usize) -> Net {
        assert!(n >= 1);
        let mut net = Net {
            spec: *spec,
            n,
            res: Vec::new(),
            egress: Vec::new(),
            ingress: Vec::new(),
            numa_up: Vec::new(),
            numa_x: Vec::new(),
            nic: Vec::new(),
        };
        let nodes = n.div_ceil(spec.gpus_per_node);
        let p2p = spec.p2p_gbps();
        for _ in 0..n {
            let e = net.alloc(p2p);
            net.egress.push(e);
            let i = net.alloc(p2p);
            net.ingress.push(i);
            let tx = net.alloc(spec.nic_gbps_per_gpu);
            let rx = net.alloc(spec.nic_gbps_per_gpu);
            net.nic.push([tx, rx]);
        }
        if let Intra::Pcie { per_dir_gbps, gpus_per_numa, numa_link_gbps } =
            spec.intra
        {
            for _node in 0..nodes {
                let domains = spec.gpus_per_node.div_ceil(gpus_per_numa);
                let ups: Vec<[ResId; 2]> = (0..domains)
                    .map(|_| {
                        let up = net.alloc(per_dir_gbps);
                        let down = net.alloc(per_dir_gbps);
                        [up, down]
                    })
                    .collect();
                net.numa_up.push(ups);
                let fwd = net.alloc(numa_link_gbps);
                let rev = net.alloc(numa_link_gbps);
                net.numa_x.push([fwd, rev]);
            }
        }
        net
    }

    fn alloc(&mut self, gbps: f64) -> ResId {
        self.res.push(Link { res: Serial::new(), gbps });
        self.res.len() - 1
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.spec.gpus_per_node
    }

    pub fn numa_of(&self, rank: usize) -> usize {
        match self.spec.intra {
            Intra::Pcie { gpus_per_numa, .. } => {
                (rank % self.spec.gpus_per_node) / gpus_per_numa
            }
            Intra::NvLink { .. } => 0,
        }
    }

    /// Is src→dst a cross-NUMA (but intra-node) path on a PCIe box?
    pub fn crosses_numa(&self, src: usize, dst: usize) -> bool {
        self.node_of(src) == self.node_of(dst)
            && self.numa_of(src) != self.numa_of(dst)
    }

    /// Stack-allocated path (≤ 6 hops) — no heap allocation on the
    /// per-tile store hot path (§Perf L3-2).
    fn path(&self, src: usize, dst: usize) -> ([ResId; 6], usize) {
        assert!(src < self.n && dst < self.n && src != dst);
        let same_node = self.node_of(src) == self.node_of(dst);
        let mut p = PathBuf6::new();
        p.push(self.egress[src]);
        if same_node {
            match self.spec.intra {
                Intra::NvLink { .. } => {}
                Intra::Pcie { .. } => {
                    // Same-switch (same NUMA) P2P stays under the PCIe
                    // switch; only cross-NUMA traffic climbs the uplinks
                    // and the inter-socket link.
                    if self.crosses_numa(src, dst) {
                        let node = self.node_of(src);
                        let dir = usize::from(
                            self.numa_of(src) > self.numa_of(dst));
                        p.push(self.numa_up[node][self.numa_of(src)][0]);
                        p.push(self.numa_x[node][dir]);
                        p.push(self.numa_up[node][self.numa_of(dst)][1]);
                    }
                }
            }
        } else {
            p.push(self.nic[src][0]); // tx at the source
            p.push(self.nic[dst][1]); // rx at the destination
            // The NIC hangs off the same PCIe switch as its 4 GPUs
            // (§4.3: "4 GPUs and 1 NIC connect to one CPU core"), so
            // GPU->NIC traffic stays under the switch: no uplink hop.
        }
        p.push(self.ingress[dst]);
        (p.ids, p.len)
    }

    /// Schedule a P2P transfer (or P2P store stream).
    ///
    /// Fluid virtual-cut-through model: each link on the path carries the
    /// transfer's bytes independently (FIFO per link, duration
    /// bytes/link_bw) as soon after `ready` as it is free; the transfer
    /// completes when the *slowest/busiest* link has carried it. This
    /// keeps per-link utilization exact while avoiding the convoy
    /// artifacts of whole-path reservation (an idle path costs
    /// bytes/bottleneck_bw + latency, matching the closed forms in
    /// cost::comm). Returns (start, end), latency included in end.
    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        ready: Time,
    ) -> (Time, Time) {
        // `x * 1.0 == x` bitwise for every finite f64, so the
        // fault-free path through the scaled variant is exact.
        self.transfer_scaled(src, dst, bytes, ready, 1.0)
    }

    /// [`Net::transfer`] under a fault-injected bandwidth slowdown:
    /// every link on the path carries the bytes `slowdown`× slower
    /// (an injected NIC/link brownout). `slowdown = 1.0` is exactly
    /// the healthy transfer.
    pub fn transfer_scaled(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        ready: Time,
        slowdown: f64,
    ) -> (Time, Time) {
        let (path, plen) = self.path(src, dst);
        let mut start = f64::INFINITY;
        let mut end: Time = ready;
        for &id in &path[..plen] {
            let dur = bytes / self.res[id].gbps * slowdown;
            let (s, e) = self.res[id].res.acquire(ready, dur);
            start = start.min(s);
            end = end.max(e);
        }
        let latency = if self.node_of(src) == self.node_of(dst) {
            self.spec.p2p_latency_us * 1e3
        } else {
            self.spec.nic_latency_us * 1e3
        };
        (start, end + latency)
    }

    /// Direct write of `bytes` from src's kernel into dst's memory (the
    /// fused epilogue's P2P store). Identical path semantics; split out
    /// for readability at call sites.
    pub fn p2p_store(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        ready: Time,
    ) -> (Time, Time) {
        if src == dst {
            // Local store: HBM write, effectively free at this granularity.
            return (ready, ready);
        }
        self.transfer(src, dst, bytes, ready)
    }

    /// When does rank's ingress port go idle? (= all writes to it landed)
    pub fn ingress_free(&self, rank: usize) -> Time {
        self.res[self.ingress[rank]].res.free_at()
    }

    pub fn reset(&mut self) {
        for l in &mut self.res {
            l.res.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};

    const MB: f64 = 1e6;

    #[test]
    fn nvlink_pairs_are_independent() {
        let mut net = Net::new(&A100_NVLINK, 8);
        // 0->1 and 2->3 share nothing: same start/end.
        let (_, e1) = net.transfer(0, 1, 30.0 * MB, 0.0);
        let (_, e2) = net.transfer(2, 3, 30.0 * MB, 0.0);
        assert!((e1 - e2).abs() < 1e-9);
    }

    #[test]
    fn same_destination_contends() {
        // Both writes target rank 1: ingress queues them (§4.1 Fig. 7).
        let mut net = Net::new(&A100_NVLINK, 8);
        let (_, e1) = net.transfer(0, 1, 30.0 * MB, 0.0);
        let (_, e2) = net.transfer(2, 1, 30.0 * MB, 0.0);
        assert!(e2 > e1 * 1.9, "e1={e1} e2={e2}");
    }

    #[test]
    fn same_source_contends_on_egress() {
        let mut net = Net::new(&H800_NVLINK, 8);
        let (_, e1) = net.transfer(0, 1, 30.0 * MB, 0.0);
        let (_, e2) = net.transfer(0, 2, 30.0 * MB, 0.0);
        assert!(e2 > e1 * 1.9);
    }

    #[test]
    fn pcie_same_switch_pairs_are_parallel() {
        let mut net = Net::new(&A100_PCIE, 8);
        // Disjoint same-NUMA pairs stay under the switch: no contention.
        let (_, e1) = net.transfer(0, 1, 30.0 * MB, 0.0);
        let (_, e2) = net.transfer(2, 3, 30.0 * MB, 0.0);
        assert!((e1 - e2).abs() < 1e-6, "same-switch P2P is independent");
    }

    #[test]
    fn pcie_cross_numa_shares_the_socket_link() {
        let mut net = Net::new(&A100_PCIE, 8);
        let (_, a) = net.transfer(0, 4, 30.0 * MB, 0.0);
        let (_, b) = net.transfer(1, 5, 30.0 * MB, 0.0);
        assert!(b > a * 1.5, "cross-NUMA transfers serialize on numa_x");
    }

    #[test]
    fn numa_mapping() {
        let net = Net::new(&A100_PCIE, 8);
        assert_eq!(net.numa_of(0), 0);
        assert_eq!(net.numa_of(3), 0);
        assert_eq!(net.numa_of(4), 1);
        assert!(net.crosses_numa(0, 4));
        assert!(!net.crosses_numa(0, 3));
    }

    #[test]
    fn internode_uses_nic() {
        let mut net = Net::new(&H800_NVLINK, 16);
        assert_eq!(net.node_of(9), 1);
        let (_, intra) = net.transfer(0, 1, 50.0 * MB, 0.0);
        net.reset();
        let (_, inter) = net.transfer(0, 9, 50.0 * MB, 0.0);
        // 50GB/s NIC vs 200GB/s NVLink.
        assert!(inter > 3.0 * intra, "inter={inter} intra={intra}");
    }

    #[test]
    fn local_store_is_free() {
        let mut net = Net::new(&A100_NVLINK, 8);
        let (s, e) = net.p2p_store(3, 3, 100.0 * MB, 42.0);
        assert_eq!((s, e), (42.0, 42.0));
    }

    #[test]
    fn ready_time_respected() {
        let mut net = Net::new(&A100_NVLINK, 4);
        let (s, _) = net.transfer(0, 1, MB, 500.0);
        assert_eq!(s, 500.0);
    }

    #[test]
    fn internode_latency_comes_from_the_spec() {
        // Tiny transfer: end time is dominated by the NIC latency term.
        let mut net = Net::new(&H800_NVLINK, 16);
        let (_, e) = net.transfer(0, 9, 1.0, 0.0);
        assert!(e >= H800_NVLINK.nic_latency_us * 1e3, "e={e}");
    }

    #[test]
    fn replica_nets_are_independent_tp_groups() {
        // The scale coordinator gives each DP replica its own TP-degree
        // Net (TP stays intra-node, ScaleTopology::validate): loading
        // one replica's links must leave another's untouched.
        use crate::cost::arch::SCALE_TP8_DP2;
        let mut a = Net::new(SCALE_TP8_DP2.cluster, SCALE_TP8_DP2.tp);
        let mut b = Net::new(SCALE_TP8_DP2.cluster, SCALE_TP8_DP2.tp);
        assert_eq!(a.n, SCALE_TP8_DP2.tp);
        let (_, e0) = a.transfer(0, 1, 30.0 * MB, 0.0);
        let (_, e1) = b.transfer(0, 1, 30.0 * MB, 0.0);
        assert!((e0 - e1).abs() < 1e-9);
        let (_, e2) = b.transfer(0, 1, 30.0 * MB, 0.0);
        assert!(e2 > e1, "second transfer on the same replica queues");
    }
}
