//! A simulated TP group: N devices + the interconnect between them.

use crate::cost::arch::ClusterSpec;
use crate::sim::device::Device;
use crate::sim::topology::Net;

#[derive(Clone, Debug)]
pub struct Cluster {
    pub spec: ClusterSpec,
    pub devices: Vec<Device>,
    pub net: Net,
}

impl Cluster {
    pub fn new(spec: &ClusterSpec, n: usize, seed: u64) -> Cluster {
        Cluster {
            spec: *spec,
            devices: (0..n)
                .map(|r| Device::new(&spec.arch, r, seed))
                .collect(),
            net: Net::new(spec, n),
        }
    }

    /// Enable stream-timing jitter (production-environment mode, §2.2).
    pub fn with_jitter(mut self, sigma: f64) -> Cluster {
        for d in &mut self.devices {
            d.jitter_sigma = sigma;
        }
        self
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    pub fn reset(&mut self) {
        for d in &mut self.devices {
            d.reset();
        }
        self.net.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::A100_NVLINK;

    #[test]
    fn builds_and_resets() {
        let mut c = Cluster::new(&A100_NVLINK, 8, 42);
        assert_eq!(c.n(), 8);
        c.devices[0].launch_uniform(0.0, 10, 100.0);
        c.net.transfer(0, 1, 1e6, 0.0);
        c.reset();
        assert_eq!(c.devices[0].sm.makespan(), 0.0);
        assert_eq!(c.net.ingress_free(1), 0.0);
    }

    #[test]
    fn jitter_flag_propagates() {
        let c = Cluster::new(&A100_NVLINK, 4, 1).with_jitter(0.25);
        assert!(c.devices.iter().all(|d| d.jitter_sigma == 0.25));
    }
}
