//! Discrete-event cluster simulator: devices (SM pools, streams,
//! launch jitter), interconnect topologies (NVLink / PCIe+NUMA / NICs)
//! and the resource calculus they share.
//!
//! This is the substrate standing in for the paper's 8–128 GPU testbeds
//! (DESIGN.md §2): every timing phenomenon the evaluation measures —
//! wave quantization, stream jitter, P2P write contention, signal-wait
//! exposure — is a scheduling/queueing effect reproduced here.

pub mod cluster;
pub mod device;
pub mod engine;
pub mod resources;
pub mod topology;
pub mod trace;

pub use resources::Time;
