//! Event-driven DP x PP x TP training-step simulation (Fig. 16
//! training rows, executed microbatch-by-microbatch).
//!
//! The closed-form [`crate::parallel::train_step_ns`] prices one
//! training step with the 1F1B algebra of `parallel::schedule`; this
//! module *runs* the same step through the shared DES event queue
//! ([`crate::sim::engine::EventQueue`]): every microbatch's forward and
//! backward on every pipeline stage is an event, PP activation/gradient
//! hops are timed transfers on real [`crate::sim::topology::Net`] links
//! (NIC path — one stage per node at this scale), and the DP gradient
//! all-reduce streams bucket-by-bucket as backward microbatches retire,
//! so only its tail past the last backward is exposed.
//!
//! Both paths consume the *same* [`StepCosts`] substrate
//! (`parallel::step_costs`), so they can only diverge in scheduling —
//! which is the point: the event-driven path measures the pipeline
//! bubble, the steady-state hop stalls and the exposed DP tail instead
//! of assuming them, and `des_agrees_with_analytic_train_step` pins how
//! far the two are allowed to drift (documented tolerance: 6% per
//! topology/method; observed max ~4.7%, on the hop-heavy PCIe cluster).
//!
//! Scheduling policy (Megatron-LM's non-interleaved 1F1B,
//! PipeDream-Flush): stage `s` holds at most `pp - s` microbatches in
//! flight (the activation-memory cap), runs a backward whenever one is
//! ready (backward priority), and fills the remaining slots with
//! forwards. Warmup/steady/drain fall out of those two rules.
//!
//! Everything is deterministic: per-microbatch stage times come from
//! the seeded overlap strategies once per (cluster, method), so the
//! same [`TrainScenario`] produces byte-identical reports across
//! reruns — the contract `flux simulate --train --json` (BENCH_2 in
//! CI) is byte-checked against.

use anyhow::{bail, ensure, Result};

use crate::cost::arch::TrainTopology;
use crate::faults::FaultTimeline;
use crate::model::configs::TransformerConfig;
use crate::obs::{self, Metrics};
use crate::parallel::{
    ideal_stage_times, step_costs, train_step_ns, Layout, Method,
    StepCosts,
};
use crate::sim::engine::EventQueue;
use crate::sim::resources::Serial;
use crate::sim::topology::Net;
use crate::sim::trace::Trace;
use crate::util::json::Json;

/// One training experiment: a topology, a model and a microbatch plan.
#[derive(Clone, Copy, Debug)]
pub struct TrainScenario {
    pub topo: &'static TrainTopology,
    pub model: &'static TransformerConfig,
    /// Microbatches per pipeline per step (global batch / dp / micro).
    pub microbatches: usize,
    /// Tokens per microbatch (batch x seq of the paper's 2048 plan).
    pub micro_tokens: usize,
    pub seq: usize,
    pub seed: u64,
}

impl TrainScenario {
    /// CI-sized scenario: fewer microbatches, same op shapes.
    pub fn quick(topo: &'static TrainTopology) -> TrainScenario {
        TrainScenario {
            topo,
            model: &crate::model::configs::GPT3_175B,
            microbatches: 8,
            micro_tokens: 2048,
            seq: 2048,
            seed: 7,
        }
    }

    /// Paper-shaped scenario (§5.2: 16 microbatches of 2048 tokens).
    pub fn full(topo: &'static TrainTopology) -> TrainScenario {
        TrainScenario { microbatches: 16, ..TrainScenario::quick(topo) }
    }

    pub fn layout(&self) -> Layout {
        Layout { dp: self.topo.dp, pp: self.topo.pp, tp: self.topo.tp }
    }

    fn costs(&self, method: Method) -> StepCosts {
        step_costs(
            self.topo.cluster,
            self.model,
            &self.layout(),
            self.micro_tokens,
            self.seq,
            method,
            self.seed,
        )
    }
}

/// Result of one event-driven training step.
#[derive(Clone, Copy, Debug)]
pub struct TrainRun {
    pub method: Method,
    /// Full step: pipeline + exposed DP tail + optimizer.
    pub step_ns: f64,
    /// Pipeline phase only (first forward to last backward).
    pub pipe_ns: f64,
    /// Measured bubble: idle fraction of the pp stages over the
    /// pipeline phase (the DES twin of `schedule::bubble_fraction`).
    pub bubble_fraction: f64,
    /// DP all-reduce time left exposed past the last backward.
    pub dp_exposed_ns: f64,
    pub opt_ns: f64,
    /// The closed-form `train_step_ns` for the same configuration.
    pub analytic_ns: f64,
    /// Events processed by the queue (scale/debug metric).
    pub events: usize,
}

/// DES events. Completions carry the stage that ran; arrivals the
/// stage being delivered to. Microbatches arrive in index order on
/// every edge, so counters (not ids) track readiness.
enum Ev {
    FwdDone(usize),
    BwdDone(usize),
    ActArrive(usize),
    GradArrive(usize),
    AllReduceDone(usize),
}

/// Per-stage 1F1B state, struct-of-arrays: every DES event touches one
/// or two counters of one stage, and the dispatch predicate reads four
/// of them — splitting the arrays keeps those reads on a handful of
/// cache lines across all stages instead of striding over full stage
/// records. Index `s` across all vectors is one pipeline stage.
struct Stages {
    /// Activations delivered (stage 0: all microbatches at t=0).
    fwd_avail: Vec<usize>,
    /// Output gradients delivered (last stage: own forwards).
    bwd_avail: Vec<usize>,
    fwd_done: Vec<usize>,
    bwd_done: Vec<usize>,
    busy: Vec<bool>,
    busy_ns: Vec<f64>,
    /// Duration of the step currently executing on each stage: under
    /// a straggler window the scheduled duration is inflated, and the
    /// trace spans must reconstruct their start from what actually
    /// ran, not the nominal stage cost.
    cur_dur: Vec<f64>,
    last_bwd_end: Vec<f64>,
    /// Each stage's DP all-reduce stream (its own NIC queue pair;
    /// Megatron pins DP traffic off the PP path, and the analytic twin
    /// ignores PP/DP contention the same way).
    dp_link: Vec<Serial>,
    ar_end: Vec<f64>,
}

impl Stages {
    fn new(pp: usize, m: usize) -> Stages {
        let mut fwd_avail = vec![0; pp];
        fwd_avail[0] = m;
        Stages {
            fwd_avail,
            bwd_avail: vec![0; pp],
            fwd_done: vec![0; pp],
            bwd_done: vec![0; pp],
            busy: vec![false; pp],
            busy_ns: vec![0.0; pp],
            cur_dur: vec![0.0; pp],
            last_bwd_end: vec![0.0; pp],
            dp_link: (0..pp).map(|_| Serial::new()).collect(),
            ar_end: vec![0.0; pp],
        }
    }
}

/// 1F1B dispatch for one stage: backward priority under the
/// `pp - s` in-flight cap.
fn try_start(
    stages: &mut Stages,
    q: &mut EventQueue<Ev>,
    s: usize,
    m: usize,
    pp: usize,
    costs: &StepCosts,
    faults: Option<&FaultTimeline>,
) {
    let now = q.now();
    if stages.busy[s] {
        return;
    }
    let in_flight = stages.fwd_done[s] - stages.bwd_done[s];
    let can_bwd = stages.bwd_done[s] < stages.bwd_avail[s];
    let can_fwd = stages.fwd_done[s] < m
        && stages.fwd_done[s] < stages.fwd_avail[s]
        && in_flight < pp - s;
    // A straggler window inflates the step that starts inside it
    // (stage index = fault-spec replica index). The fault-free arm
    // keeps the nominal cost untouched.
    let dur = |nominal: f64| match faults {
        Some(tl) => nominal * tl.step_factor(s, now),
        None => nominal,
    };
    if can_bwd {
        let d = dur(costs.stage.bwd_ns);
        stages.busy[s] = true;
        stages.busy_ns[s] += d;
        stages.cur_dur[s] = d;
        q.schedule(now + d, Ev::BwdDone(s));
    } else if can_fwd {
        let d = dur(costs.stage.fwd_ns);
        stages.busy[s] = true;
        stages.busy_ns[s] += d;
        stages.cur_dur[s] = d;
        q.schedule(now + d, Ev::FwdDone(s));
    }
}

/// Scenario invariants shared by every public entry point (the DES
/// core itself assumes them: `m - 1` underflows on an empty plan, and
/// an untileable layer count would silently truncate stage work).
fn validate_scenario(sc: &TrainScenario) -> Result<()> {
    sc.topo.validate()?;
    ensure!(sc.microbatches >= 1, "empty microbatch plan");
    ensure!(
        sc.model.n_layers % sc.topo.pp == 0,
        "{} layers do not tile {} pipeline stages",
        sc.model.n_layers,
        sc.topo.pp
    );
    Ok(())
}

/// Run one (scenario, method) training step through the event queue.
pub fn run_train(sc: &TrainScenario, method: Method) -> Result<TrainRun> {
    run_train_with(sc, method, None, None)
}

/// Like [`run_train`], optionally recording the DES event stream into
/// a chrome trace: `(trace, pid0)` — pipeline stage `s` becomes
/// process `pid0 + s` (compute spans on tid 0, PP hops on tid 1, DP
/// all-reduce buckets on tid 2).
pub fn run_train_traced(
    sc: &TrainScenario,
    method: Method,
    trace: Option<(&mut Trace, usize)>,
) -> Result<TrainRun> {
    run_train_with(sc, method, None, trace)
}

/// [`run_train`] under an expanded fault timeline: straggler windows
/// inflate the afflicted stage's fwd/bwd step times (spec replica
/// index = pipeline stage), and NIC windows slow both the PP
/// activation/gradient hops and the DP all-reduce buckets. Kills and
/// resizes have no training semantics (a synchronous step has no
/// replica to drain mid-flight) and are rejected up front. An empty
/// timeline is byte-identical to [`run_train`].
pub fn run_train_with(
    sc: &TrainScenario,
    method: Method,
    faults: Option<&FaultTimeline>,
    trace: Option<(&mut Trace, usize)>,
) -> Result<TrainRun> {
    run_train_observed(sc, method, faults, trace, None)
}

/// The fully-instrumented entry: [`run_train_with`] plus an optional
/// [`Metrics`] registry recording per-stage fwd/bwd/hop/bucket time
/// attribution, sampled pipeline occupancy and fault-window markers.
/// The registry only reads simulator state, so `metrics: None` is the
/// exact [`run_train_with`] path.
pub fn run_train_observed(
    sc: &TrainScenario,
    method: Method,
    faults: Option<&FaultTimeline>,
    mut trace: Option<(&mut Trace, usize)>,
    mut metrics: Option<&mut Metrics>,
) -> Result<TrainRun> {
    validate_scenario(sc)?;
    if let Some(tl) = faults {
        if !tl.kills.is_empty() || !tl.resizes.is_empty() {
            bail!(
                "fault timeline has {} kill(s) and {} resize(s): \
                 training is a synchronous step with no replica to \
                 drain — only stragglers and nic windows apply",
                tl.kills.len(),
                tl.resizes.len()
            );
        }
    }
    if let Some((tr, pid0)) = trace.as_mut() {
        for s in 0..sc.topo.pp {
            tr.process_name(
                *pid0 + s,
                &format!("{}/stage{s}", method.name()),
            );
        }
        if let Some(tl) = faults {
            for w in &tl.stragglers {
                if w.replica < sc.topo.pp {
                    tr.span(
                        *pid0 + w.replica,
                        1,
                        "straggler",
                        w.start_ns,
                        w.end_ns - w.start_ns,
                        vec![("factor", Json::from(w.factor))],
                    );
                }
            }
        }
    }
    // Fault windows as instant markers: when each straggler / NIC
    // degradation window opens, stamped at its start time.
    if let Some(m) = metrics.as_deref_mut() {
        if let Some(tl) = faults {
            for w in &tl.stragglers {
                if w.replica < sc.topo.pp {
                    m.marker(w.start_ns, "fault.straggler", obs::stage(w.replica));
                }
            }
            for w in &tl.nic {
                m.marker(w.start_ns, "fault.nic", obs::labels(&[]));
            }
        }
    }
    let costs = sc.costs(method);
    let out = simulate_with_costs(
        sc.topo,
        sc.microbatches,
        &costs,
        faults,
        trace,
        metrics,
    )?;
    Ok(TrainRun {
        method,
        analytic_ns: train_step_ns(
            sc.topo.cluster,
            sc.model,
            &sc.layout(),
            sc.microbatches,
            sc.micro_tokens,
            sc.seq,
            method,
            sc.seed,
        ),
        ..out
    })
}

/// The communication-free floor of one step (every TP op at Eq. 1's
/// `GEMM_non-split`), run through the same DES — the training-level
/// Eq.-2 denominator.
pub fn ideal_step_ns(sc: &TrainScenario) -> Result<f64> {
    validate_scenario(sc)?;
    let ideal = StepCosts {
        stage: ideal_stage_times(
            sc.topo.cluster,
            sc.model,
            &sc.layout(),
            sc.micro_tokens,
            sc.seq,
        ),
        ..sc.costs(Method::NonOverlap)
    };
    Ok(simulate_with_costs(
        sc.topo,
        sc.microbatches,
        &ideal,
        None,
        None,
        None,
    )?
    .step_ns)
}

/// Eq. 2 against a precomputed ideal: the fraction of the
/// non-overlapping step's exposed communication the method hides.
/// The report computes [`ideal_step_ns`] once per topology and prices
/// every method against it through this one formula.
pub fn overlap_efficiency_vs_ideal(
    base_step_ns: f64,
    method_step_ns: f64,
    ideal_step_ns: f64,
) -> f64 {
    let exposed = base_step_ns - ideal_step_ns;
    if exposed <= 0.0 {
        return 0.0;
    }
    (base_step_ns - method_step_ns) / exposed
}

/// Eq. 2 at the training-step level, ideal derived from the scenario.
pub fn train_overlap_efficiency(
    sc: &TrainScenario,
    base_step_ns: f64,
    method_step_ns: f64,
) -> Result<f64> {
    Ok(overlap_efficiency_vs_ideal(
        base_step_ns,
        method_step_ns,
        ideal_step_ns(sc)?,
    ))
}

/// The method-independent DES core: schedule `microbatches` through the
/// 1F1B state machine over `topo.pp` stages, timing hops on the link
/// graph and streaming the DP all-reduce behind backward.
fn simulate_with_costs(
    topo: &TrainTopology,
    microbatches: usize,
    costs: &StepCosts,
    faults: Option<&FaultTimeline>,
    mut trace: Option<(&mut Trace, usize)>,
    mut metrics: Option<&mut Metrics>,
) -> Result<TrainRun> {
    // Empty timelines take the exact fault-free arithmetic.
    let faults = faults.filter(|tl| !tl.is_empty());
    let pp = topo.pp;
    let m = microbatches;
    // One Net spanning the pipeline's nodes: stage s's rank 0 stands in
    // for its TP group on the inter-node path (each GPU moves its own
    // activation slice through its own NIC share, so one share's
    // timing IS the per-GPU hop, same as the closed form).
    let mut net = Net::new(topo.cluster, pp * topo.cluster.gpus_per_node);
    let rank_of = |s: usize| s * topo.cluster.gpus_per_node;

    let mut stages = Stages::new(pp, m);

    // Gradient buckets: each backward microbatch unlocks 1/m of the
    // all-reduce wire, but nothing streams before 20% of the backwards
    // have retired (grads are still accumulating) — the DES twin of the
    // closed form's 0.8-window. Deferred buckets release together when
    // the window opens.
    let k0 = (m.div_ceil(5)).min(m - 1);
    let bucket_ns = costs.grad_wire_ns / m as f64;

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut events = 0usize;
    try_start(&mut stages, &mut q, 0, m, pp, costs, faults);

    // Injected link slowdown at hop/bucket release time; 1.0 scales
    // bit-identically to the healthy transfer.
    let nic_slow = |tl: Option<&FaultTimeline>, now: f64| match tl {
        Some(tl) => tl.nic_scale(now),
        None => 1.0,
    };

    while let Some((now, ev)) = q.next() {
        events += 1;
        // Seeded-cadence occupancy snapshot: in-flight microbatches
        // and busy flag per stage — read-only against the 1F1B state.
        if let Some(m) = metrics.as_deref_mut() {
            if let Some(t) = m.sample_due(now) {
                for s in 0..pp {
                    let in_flight =
                        (stages.fwd_done[s] - stages.bwd_done[s]) as f64;
                    let busy = if stages.busy[s] { 1.0 } else { 0.0 };
                    m.point(t, "train.in_flight", obs::stage(s), in_flight);
                    m.point(t, "train.busy", obs::stage(s), busy);
                    if let Some((tr, pid0)) = trace.as_mut() {
                        tr.counter(
                            *pid0 + s,
                            "train.in_flight",
                            t,
                            vec![("value", Json::from(in_flight))],
                        );
                    }
                }
            }
        }
        match ev {
            Ev::FwdDone(s) => {
                stages.busy[s] = false;
                stages.fwd_done[s] += 1;
                if let Some(m) = metrics.as_deref_mut() {
                    m.add("train.fwd_ns", obs::stage(s), stages.cur_dur[s]);
                }
                if let Some((tr, pid0)) = trace.as_mut() {
                    tr.span(
                        *pid0 + s,
                        0,
                        "fwd",
                        now - stages.cur_dur[s],
                        stages.cur_dur[s],
                        vec![(
                            "micro",
                            Json::from(stages.fwd_done[s] - 1),
                        )],
                    );
                }
                if s + 1 < pp {
                    let (hop_start, end) = net.transfer_scaled(
                        rank_of(s),
                        rank_of(s + 1),
                        costs.act_bytes,
                        now,
                        nic_slow(faults, now),
                    );
                    if let Some(m) = metrics.as_deref_mut() {
                        m.add("train.hop_ns", obs::stage(s + 1), end - hop_start);
                    }
                    if let Some((tr, pid0)) = trace.as_mut() {
                        tr.span(
                            *pid0 + s + 1,
                            1,
                            "act-hop",
                            hop_start,
                            end - hop_start,
                            Vec::new(),
                        );
                    }
                    q.schedule(end, Ev::ActArrive(s + 1));
                } else {
                    // The last stage turns around in place.
                    stages.bwd_avail[s] += 1;
                }
                try_start(&mut stages, &mut q, s, m, pp, costs, faults);
            }
            Ev::BwdDone(s) => {
                stages.busy[s] = false;
                stages.bwd_done[s] += 1;
                stages.last_bwd_end[s] = now;
                if let Some(m) = metrics.as_deref_mut() {
                    m.add("train.bwd_ns", obs::stage(s), stages.cur_dur[s]);
                }
                if let Some((tr, pid0)) = trace.as_mut() {
                    tr.span(
                        *pid0 + s,
                        0,
                        "bwd",
                        now - stages.cur_dur[s],
                        stages.cur_dur[s],
                        vec![(
                            "micro",
                            Json::from(stages.bwd_done[s] - 1),
                        )],
                    );
                }
                if s > 0 {
                    let (hop_start, end) = net.transfer_scaled(
                        rank_of(s),
                        rank_of(s - 1),
                        costs.act_bytes,
                        now,
                        nic_slow(faults, now),
                    );
                    if let Some(m) = metrics.as_deref_mut() {
                        m.add("train.hop_ns", obs::stage(s - 1), end - hop_start);
                    }
                    if let Some((tr, pid0)) = trace.as_mut() {
                        tr.span(
                            *pid0 + s - 1,
                            1,
                            "grad-hop",
                            hop_start,
                            end - hop_start,
                            Vec::new(),
                        );
                    }
                    q.schedule(end, Ev::GradArrive(s - 1));
                }
                let done = stages.bwd_done[s];
                if topo.dp > 1 && done > k0 {
                    // First post-window backward releases the deferred
                    // buckets too.
                    let release = if done == k0 + 1 { done } else { 1 };
                    let b_dur = match faults {
                        Some(tl) => bucket_ns * tl.nic_scale(now),
                        None => bucket_ns,
                    };
                    let mut ar_end = 0.0;
                    for _ in 0..release {
                        let (b_start, b_end) =
                            stages.dp_link[s].acquire(now, b_dur);
                        if let Some(m) = metrics.as_deref_mut() {
                            m.add(
                                "train.bucket_ns",
                                obs::stage(s),
                                b_end - b_start,
                            );
                        }
                        if let Some((tr, pid0)) = trace.as_mut() {
                            tr.span(
                                *pid0 + s,
                                2,
                                "dp-bucket",
                                b_start,
                                b_end - b_start,
                                Vec::new(),
                            );
                        }
                        ar_end = b_end;
                    }
                    if done == m {
                        q.schedule(ar_end, Ev::AllReduceDone(s));
                    }
                } else if topo.dp == 1 && done == m {
                    stages.ar_end[s] = now;
                }
                try_start(&mut stages, &mut q, s, m, pp, costs, faults);
            }
            Ev::ActArrive(s) => {
                stages.fwd_avail[s] += 1;
                try_start(&mut stages, &mut q, s, m, pp, costs, faults);
            }
            Ev::GradArrive(s) => {
                stages.bwd_avail[s] += 1;
                try_start(&mut stages, &mut q, s, m, pp, costs, faults);
            }
            Ev::AllReduceDone(s) => {
                stages.ar_end[s] = now;
            }
        }
    }

    for s in 0..pp {
        ensure!(
            stages.fwd_done[s] == m && stages.bwd_done[s] == m,
            "stage {s} stalled at fwd {}/{m} bwd {}/{m} \
             (1F1B scheduling bug)",
            stages.fwd_done[s],
            stages.bwd_done[s]
        );
    }

    let pipe_ns = stages
        .last_bwd_end
        .iter()
        .copied()
        .fold(0.0f64, f64::max);
    let ar_max =
        stages.ar_end.iter().copied().fold(0.0f64, f64::max);
    let busy: f64 = stages.busy_ns.iter().sum();
    let step_ns = pipe_ns.max(ar_max) + costs.opt_ns;
    // End-of-run telemetry: engine counters plus the step's
    // exposed-vs-overlapped communication split — the Eq.-2 quantities
    // as gauges the time-series figure plots per method.
    if let Some(m) = metrics.as_deref_mut() {
        let root = obs::labels(&[]);
        m.add("engine.events_popped", root.clone(), q.pops() as f64);
        m.add("engine.events_scheduled", root.clone(), q.scheduled() as f64);
        m.add("engine.calendar_rebuilds", root.clone(), q.rebuilds() as f64);
        m.gauge("train.pipe_ns", root.clone(), pipe_ns);
        m.gauge("train.dp_exposed_ns", root.clone(), pipe_ns.max(ar_max) - pipe_ns);
        m.gauge("train.step_ns", root, step_ns);
    }
    Ok(TrainRun {
        method: Method::NonOverlap, // overwritten by run_train
        step_ns,
        pipe_ns,
        bubble_fraction: 1.0 - busy / (pp as f64 * pipe_ns),
        dp_exposed_ns: pipe_ns.max(ar_max) - pipe_ns,
        opt_ns: costs.opt_ns,
        analytic_ns: 0.0, // overwritten by run_train
        events,
    })
}

/// The Fig.-16-shaped three-way comparison on one scenario.
pub struct TrainComparison {
    pub megatron: TrainRun,
    pub te: TrainRun,
    pub flux: TrainRun,
}

impl TrainComparison {
    /// Flux speedup over the Megatron-LM (non-overlap) execution.
    pub fn speedup(&self) -> f64 {
        self.megatron.step_ns / self.flux.step_ns
    }

    /// Flux speedup over TransformerEngine.
    pub fn speedup_vs_te(&self) -> f64 {
        self.te.step_ns / self.flux.step_ns
    }
}

/// Run one scenario under every method in `methods`, sequentially and
/// in order — the uniform method-set entry for in-process callers
/// (comparisons, tests). The report layer reaches the same `run_train`
/// runs through `exp::Runner::run_product` instead, so the method set
/// spreads across workers there.
pub fn run_train_methods(
    sc: &TrainScenario,
    methods: &[Method],
) -> Result<Vec<TrainRun>> {
    methods.iter().map(|&m| run_train(sc, m)).collect()
}

pub fn compare_train(sc: &TrainScenario) -> Result<TrainComparison> {
    let runs = run_train_methods(sc, &Method::TRAIN_SET)?;
    Ok(TrainComparison {
        megatron: runs[0],
        te: runs[1],
        flux: runs[2],
    })
}

/// All three methods with the DES streams captured side by side in one
/// chrome trace: Megatron stages on pids `[0, pp)`, TE on
/// `[pp, 2*pp)`, Flux on `[2*pp, 3*pp)`.
pub fn compare_train_traced(
    sc: &TrainScenario,
    trace: &mut Trace,
) -> Result<TrainComparison> {
    let pp = sc.topo.pp;
    Ok(TrainComparison {
        megatron: run_train_traced(
            sc,
            Method::NonOverlap,
            Some((&mut *trace, 0)),
        )?,
        te: run_train_traced(
            sc,
            Method::Medium,
            Some((&mut *trace, pp)),
        )?,
        flux: run_train_traced(
            sc,
            Method::Flux,
            Some((&mut *trace, 2 * pp)),
        )?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{
        ALL_TRAIN_TOPOLOGIES, A100_NVLINK, TRAIN_H800_128,
        TRAIN_NVLINK_128, TRAIN_PCIE_128,
    };
    use crate::parallel::schedule;

    #[test]
    fn des_pipeline_is_exact_without_hops() {
        // On a single-stage pipeline there are no hops and no bubble:
        // the DES must reproduce m * (f + b) to float precision — same
        // costs, independently derived schedule.
        const PP1: TrainTopology = TrainTopology {
            name: "pp1",
            cluster: &A100_NVLINK,
            dp: 2,
            pp: 1,
            tp: 8,
        };
        let sc = TrainScenario {
            topo: &PP1,
            ..TrainScenario::quick(&TRAIN_NVLINK_128)
        };
        for method in Method::ALL {
            let c = sc.costs(method);
            let run = run_train(&sc, method).unwrap();
            let closed = sc.microbatches as f64
                * (c.stage.fwd_ns + c.stage.bwd_ns);
            let rel = (run.pipe_ns - closed).abs() / closed;
            assert!(
                rel < 1e-9,
                "{}: DES pipe {} vs closed {closed}",
                method.name(),
                run.pipe_ns
            );
            assert_eq!(run.bubble_fraction, 0.0, "{}", method.name());
        }
    }

    #[test]
    fn des_pipeline_bounded_by_the_1f1b_closed_form() {
        // With hops, the closed form is a *lower bound*: it threads the
        // fill/drain hops onto the critical path but idealizes away the
        // steady-state stalls where an activation arrives a hop-latency
        // after the downstream stage wanted it. The DES measures those
        // (that is the point of running events), and they stay small:
        // within 6% even on the hop-heavy PCIe cluster.
        for topo in ALL_TRAIN_TOPOLOGIES {
            let sc = TrainScenario::quick(topo);
            for method in Method::ALL {
                let c = sc.costs(method);
                let run = run_train(&sc, method).unwrap();
                let closed = schedule::one_f1b_ns(
                    sc.topo.pp,
                    sc.microbatches,
                    c.stage.fwd_ns,
                    c.stage.bwd_ns,
                    c.hop_ns,
                );
                assert!(
                    run.pipe_ns >= closed * (1.0 - 1e-9),
                    "{} {}: DES pipe {} below closed form {closed}",
                    topo.name,
                    method.name(),
                    run.pipe_ns
                );
                assert!(
                    run.pipe_ns <= closed * 1.06,
                    "{} {}: DES pipe {} exceeds closed form {closed} \
                     by more than 6%",
                    topo.name,
                    method.name(),
                    run.pipe_ns
                );
            }
        }
    }

    #[test]
    fn des_agrees_with_analytic_train_step() {
        // The differential contract: event-driven and closed-form step
        // times agree within 6% on every paper topology and method
        // (the residual is steady-state hop stalls the closed form
        // idealizes away, plus DP-tail bucket granularity; observed
        // max ~4.7% on PCIe), and the PR-2 ordering invariant carries
        // over: flux >= decoupled.
        for topo in ALL_TRAIN_TOPOLOGIES {
            for sc in
                [TrainScenario::quick(topo), TrainScenario::full(topo)]
            {
                let mut step = std::collections::BTreeMap::new();
                for method in Method::ALL {
                    let run = run_train(&sc, method).unwrap();
                    let rel = (run.step_ns - run.analytic_ns).abs()
                        / run.analytic_ns;
                    assert!(
                        rel < 0.06,
                        "{} {} m={}: DES {} vs analytic {} ({rel:.4})",
                        topo.name,
                        method.name(),
                        sc.microbatches,
                        run.step_ns,
                        run.analytic_ns
                    );
                    step.insert(method.name(), run.step_ns);
                }
                assert!(
                    step["Flux"] < step["non-overlap"],
                    "{}: flux must beat the decoupled execution",
                    topo.name
                );
            }
        }
    }

    #[test]
    fn method_set_runs_match_the_three_way_comparison() {
        let sc = TrainScenario::quick(&TRAIN_NVLINK_128);
        let runs =
            run_train_methods(&sc, &Method::TRAIN_SET).unwrap();
        assert_eq!(runs.len(), 3);
        let cmp = compare_train(&sc).unwrap();
        assert_eq!(runs[0].step_ns, cmp.megatron.step_ns);
        assert_eq!(runs[1].step_ns, cmp.te.step_ns);
        assert_eq!(runs[2].step_ns, cmp.flux.step_ns);
        assert_eq!(runs[0].method, Method::NonOverlap);
        assert_eq!(runs[2].method, Method::Flux);
    }

    #[test]
    fn deterministic_across_reruns() {
        let sc = TrainScenario::quick(&TRAIN_H800_128);
        let a = run_train(&sc, Method::Flux).unwrap();
        let b = run_train(&sc, Method::Flux).unwrap();
        assert_eq!(a.step_ns, b.step_ns);
        assert_eq!(a.pipe_ns, b.pipe_ns);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn fig16_speedup_bands() {
        // Fig. 16 training on the event-driven path: PCIe lands in the
        // paper's ~1.2x band and dominates NVLink, which stays modest.
        let sp = |topo| {
            compare_train(&TrainScenario::full(topo)).unwrap().speedup()
        };
        let pcie = sp(&TRAIN_PCIE_128);
        let nvl = sp(&TRAIN_NVLINK_128);
        let h800 = sp(&TRAIN_H800_128);
        assert!(pcie > 1.10 && pcie < 1.60, "pcie speedup {pcie}");
        assert!(nvl > 1.00 && nvl < 1.20, "nvlink speedup {nvl}");
        assert!(h800 > 1.00 && h800 < 1.45, "h800 speedup {h800}");
        assert!(pcie > nvl && h800 > nvl);
    }

    #[test]
    fn measured_bubble_tracks_the_analytic_fraction() {
        // Hop latency adds bubble, so measured >= analytic; more
        // microbatches amortize both the same way.
        let sc8 = TrainScenario::quick(&TRAIN_NVLINK_128);
        let sc16 = TrainScenario::full(&TRAIN_NVLINK_128);
        let b8 = run_train(&sc8, Method::Flux).unwrap().bubble_fraction;
        let b16 = run_train(&sc16, Method::Flux).unwrap().bubble_fraction;
        let a8 = schedule::bubble_fraction(sc8.topo.pp, sc8.microbatches);
        assert!(b8 > 0.0 && b8 < 1.0, "bubble {b8}");
        assert!(b16 < b8, "m=16 {b16} must amortize m=8 {b8}");
        // Same order of magnitude as the f==b closed form.
        assert!((b8 - a8).abs() < 0.15, "measured {b8} analytic {a8}");
    }

    #[test]
    fn dp_tail_is_a_sliver_of_the_step() {
        // Megatron hides nearly all of the gradient all-reduce; only
        // the tail bucket stays exposed.
        for topo in ALL_TRAIN_TOPOLOGIES {
            let run =
                run_train(&TrainScenario::full(topo), Method::Flux)
                    .unwrap();
            assert!(run.dp_exposed_ns > 0.0, "{}", topo.name);
            assert!(
                run.dp_exposed_ns < 0.1 * run.step_ns,
                "{}: exposed {} of step {}",
                topo.name,
                run.dp_exposed_ns,
                run.step_ns
            );
        }
    }

    #[test]
    fn overlap_efficiency_positive_for_flux_zero_for_base() {
        let sc = TrainScenario::quick(&TRAIN_PCIE_128);
        let base = run_train(&sc, Method::NonOverlap).unwrap();
        let fx = run_train(&sc, Method::Flux).unwrap();
        let eff =
            train_overlap_efficiency(&sc, base.step_ns, fx.step_ns)
                .unwrap();
        assert!(eff > 0.0 && eff <= 1.0, "flux eff {eff}");
        let self_eff =
            train_overlap_efficiency(&sc, base.step_ns, base.step_ns)
                .unwrap();
        assert_eq!(self_eff, 0.0);
    }

    #[test]
    fn trace_capture_is_deterministic_and_spans_all_stages() {
        let sc = TrainScenario::quick(&TRAIN_NVLINK_128);
        let mut a = Trace::new();
        let mut b = Trace::new();
        compare_train_traced(&sc, &mut a).unwrap();
        compare_train_traced(&sc, &mut b).unwrap();
        let text = a.to_json().to_string();
        assert_eq!(text, b.to_json().to_string(), "trace must replay");
        let doc = Json::parse(&text).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 methods x 8 stages x 8 microbatches x (fwd + bwd) compute
        // spans at minimum, plus hops, buckets and metadata.
        assert!(evs.len() >= 3 * 8 * 8 * 2, "{}", evs.len());
        // The traced runs must not perturb the simulation.
        let plain = run_train(&sc, Method::Flux).unwrap();
        let mut t = Trace::new();
        let traced =
            run_train_traced(&sc, Method::Flux, Some((&mut t, 0)))
                .unwrap();
        assert_eq!(plain.step_ns, traced.step_ns);
        assert_eq!(plain.events, traced.events);
    }

    #[test]
    fn empty_timeline_is_byte_identical_to_fault_free() {
        let sc = TrainScenario::quick(&TRAIN_H800_128);
        let spec = crate::faults::preset("straggler-storm").unwrap();
        let tl = spec.expand(sc.topo.pp, 0.0);
        assert!(tl.is_empty());
        let base = run_train(&sc, Method::Flux).unwrap();
        let faulted =
            run_train_with(&sc, Method::Flux, Some(&tl), None).unwrap();
        assert_eq!(base.step_ns, faulted.step_ns);
        assert_eq!(base.pipe_ns, faulted.pipe_ns);
        assert_eq!(base.dp_exposed_ns, faulted.dp_exposed_ns);
        assert_eq!(base.events, faulted.events);
    }

    #[test]
    fn stragglers_stretch_the_step_monotonically() {
        // A straggler-inflated stage sits on the 1F1B critical path,
        // so step time grows with intensity — for every method, on
        // every paper topology.
        let spec = crate::faults::preset("straggler-storm").unwrap();
        for topo in ALL_TRAIN_TOPOLOGIES {
            let sc = TrainScenario::quick(topo);
            for method in Method::TRAIN_SET {
                let step = |k: f64| {
                    let tl = spec.expand(sc.topo.pp, k);
                    if tl.is_empty() {
                        run_train(&sc, method).unwrap().step_ns
                    } else {
                        run_train_with(&sc, method, Some(&tl), None)
                            .unwrap()
                            .step_ns
                    }
                };
                let s0 = step(0.0);
                let s5 = step(0.5);
                let s10 = step(1.0);
                assert!(
                    s0 < s5 && s5 < s10,
                    "{} {}: {s0} !< {s5} !< {s10}",
                    topo.name,
                    method.name()
                );
            }
        }
    }

    #[test]
    fn nic_brownout_exposes_a_longer_dp_tail() {
        // Slower wire, same compute: the gradient all-reduce streams
        // behind backward but its exposed tail past the last backward
        // grows with the brownout.
        let spec = crate::faults::preset("nic-brownout").unwrap();
        let sc = TrainScenario::quick(&TRAIN_NVLINK_128);
        let base = run_train(&sc, Method::Flux).unwrap();
        let tl = spec.expand(sc.topo.pp, 1.0);
        let slow =
            run_train_with(&sc, Method::Flux, Some(&tl), None).unwrap();
        assert!(
            slow.dp_exposed_ns > base.dp_exposed_ns,
            "exposed tail {} !> {}",
            slow.dp_exposed_ns,
            base.dp_exposed_ns
        );
        assert!(slow.step_ns > base.step_ns);
    }

    #[test]
    fn kills_and_resizes_are_rejected_for_training() {
        let spec = crate::faults::preset("replica-churn").unwrap();
        let sc = TrainScenario::quick(&TRAIN_NVLINK_128);
        let tl = spec.expand(sc.topo.pp, 1.0);
        let err = run_train_with(&sc, Method::Flux, Some(&tl), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("kill"), "{err}");
    }

    #[test]
    fn rejects_layer_untileable_pipeline() {
        const PP7: TrainTopology = TrainTopology {
            name: "pp7",
            cluster: &A100_NVLINK,
            dp: 1,
            pp: 7,
            tp: 8,
        };
        let bad = TrainScenario {
            topo: &PP7,
            ..TrainScenario::quick(&TRAIN_NVLINK_128)
        };
        // 96 layers % 7 stages != 0 — every public entry point rejects.
        assert!(run_train(&bad, Method::NonOverlap).is_err());
        assert!(ideal_step_ns(&bad).is_err());
    }
}
