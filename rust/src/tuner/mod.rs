//! Auto-tuning (§4.4): exhaustive search over FLUX's tuning knobs per
//! (cluster, op, shape), with a cache keyed the way a GEMM library keys
//! its kernel selection — matrix shape, data type (bf16 fixed here), and
//! architecture/interconnect.
//!
//! Knobs searched (all from §4): tile-coordinate swizzling on/off,
//! pull vs push transfers, the communication tile size ladder
//! (chunk size halving down to the GEMM tile), fused vs discrete
//! reduction.

use std::collections::BTreeMap;

use crate::cost::arch::ClusterSpec;
use crate::cost::gemm::pick_tile;
use crate::overlap::flux::{simulate, FluxConfig, ReduceStrategy};
use crate::overlap::tiles::comm_tile_candidates;
use crate::overlap::{Op, OpTiming, Problem};

/// A tuned result: the winning config and its simulated time.
#[derive(Clone, Copy, Debug)]
pub struct Tuned {
    pub config: FluxConfig,
    pub timing: OpTiming,
    pub candidates_tried: usize,
}

/// The §4.4 search space for one problem.
pub fn search_space(cluster: &ClusterSpec, p: &Problem) -> Vec<FluxConfig> {
    let mut out = Vec::new();
    let comm_sizes: Vec<usize> = match p.op {
        Op::AgGemm => {
            let bm = pick_tile(&p.local_gemm()).bm;
            comm_tile_candidates(p.m, p.n_tp, bm)
        }
        // RS communication granularity IS the GEMM tile (epilogue
        // stores); no independent knob.
        Op::GemmRs => vec![0],
    };
    let _ = cluster;
    let reduce_opts: &[(bool, ReduceStrategy)] = match p.op {
        // Reduction knobs only affect RS; pin them for AG.
        Op::AgGemm => &[(true, ReduceStrategy::WarpSpecialized)],
        Op::GemmRs => &[
            (true, ReduceStrategy::RedAtomic),
            (true, ReduceStrategy::WarpSpecialized),
            (false, ReduceStrategy::Discrete),
        ],
    };
    for swizzle in [true, false] {
        for pull in [true, false] {
            for &comm_rows in &comm_sizes {
                for &(fuse_reduction, reduce) in reduce_opts {
                    out.push(FluxConfig {
                        swizzle,
                        pull,
                        comm_rows,
                        fuse_reduction,
                        reduce,
                    });
                }
            }
        }
    }
    out
}

/// Exhaustively tune one problem. Deterministic (fixed seed per
/// candidate) so results are reproducible.
pub fn tune(cluster: &ClusterSpec, p: &Problem, seed: u64) -> Tuned {
    let space = search_space(cluster, p);
    let mut best: Option<Tuned> = None;
    for cfg in &space {
        let timing = simulate(cluster, p, cfg, seed);
        if best
            .map(|b| timing.overall_ns < b.timing.overall_ns)
            .unwrap_or(true)
        {
            best = Some(Tuned {
                config: *cfg,
                timing,
                candidates_tried: space.len(),
            });
        }
    }
    best.expect("search space is never empty")
}

/// Cache key: problem identity on a given cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub cluster: &'static str,
    pub op_is_ag: bool,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub n_tp: usize,
}

/// Tuning cache: tune once per (cluster, problem), reuse thereafter —
/// the same behaviour as a GEMM library's algorithm-selection cache.
#[derive(Default)]
pub struct TunerCache {
    cache: BTreeMap<Key, Tuned>,
    pub misses: usize,
    pub hits: usize,
}

impl TunerCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(
        &mut self,
        cluster: &'static ClusterSpec,
        p: &Problem,
        seed: u64,
    ) -> Tuned {
        let key = Key {
            cluster: cluster.name,
            op_is_ag: p.op == Op::AgGemm,
            m: p.m,
            n: p.n,
            k: p.k,
            n_tp: p.n_tp,
        };
        if let Some(t) = self.cache.get(&key) {
            self.hits += 1;
            return *t;
        }
        self.misses += 1;
        let t = tune(cluster, p, seed);
        self.cache.insert(key, t);
        t
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE};

    #[test]
    fn tuned_never_loses_to_default() {
        for p in [
            Problem::ag(2048, 49152, 12288, 8),
            Problem::rs(2048, 12288, 49152, 8),
            Problem::ag(512, 49152, 12288, 8),
        ] {
            for cl in [&A100_PCIE, &A100_NVLINK] {
                let tuned = tune(cl, &p, 7);
                let default =
                    simulate(cl, &p, &FluxConfig::default(), 7);
                assert!(
                    tuned.timing.overall_ns <= default.overall_ns + 1e-6,
                    "{} {}: tuned {} default {}",
                    cl.name, p.op.name(),
                    tuned.timing.overall_ns, default.overall_ns
                );
            }
        }
    }

    #[test]
    fn tuner_picks_push_on_pcie_pull_on_nvlink() {
        // Fig. 9's conclusion, rediscovered by search.
        let p = Problem::ag(4096, 49152, 12288, 8);
        let pcie = tune(&A100_PCIE, &p, 7);
        assert!(!pcie.config.pull, "PCIe should tune to push");
        let nvl = tune(&A100_NVLINK, &p, 7);
        assert!(nvl.config.pull, "NVLink should tune to pull");
    }

    #[test]
    fn tuner_prefers_swizzle_at_scale() {
        let p = Problem::rs(8192, 12288, 49152, 8);
        let t = tune(&A100_NVLINK, &p, 7);
        assert!(t.config.swizzle, "swizzle should win at m=8192");
    }

    #[test]
    fn ag_space_includes_comm_tile_ladder() {
        let p = Problem::ag(8192, 49152, 12288, 8);
        let space = search_space(&A100_NVLINK, &p);
        let sizes: std::collections::BTreeSet<usize> =
            space.iter().map(|c| c.comm_rows).collect();
        assert!(sizes.contains(&1024) && sizes.contains(&128),
                "ladder missing: {sizes:?}");
    }

    #[test]
    fn cache_hits_after_first_tune() {
        let mut c = TunerCache::new();
        let p = Problem::ag(1024, 49152, 12288, 8);
        let a = c.get(&A100_NVLINK, &p, 7);
        let b = c.get(&A100_NVLINK, &p, 7);
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 1);
        assert_eq!(a.config, b.config);
        // A different shape misses.
        c.get(&A100_NVLINK, &Problem::ag(2048, 49152, 12288, 8), 7);
        assert_eq!(c.misses, 2);
    }
}
