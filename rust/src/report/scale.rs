//! The serving-at-scale document (`flux simulate --scale --json`,
//! schema `flux-scale-v2`): every selected topology under the
//! scenario's method set, cells executed by the
//! [`crate::exp::Runner`] at (topology, method) grain and merged in
//! fixed order — byte-identical at any worker count.

use anyhow::{ensure, Result};

use crate::cost::arch::ScaleTopology;
use crate::exp::{Mode, Runner, Scenario};
use crate::overlap::Method;
use crate::serving::scale::{
    run_scale, ScaleComparison, ScaleReport, ScaleScenario,
};
use crate::util::json::{obj, Json};
use crate::workload::WorkloadSpec;

use super::{latency_percentiles, SCALE_SCHEMA};

fn scale_method_json(r: &ScaleReport) -> Json {
    let mut fields = vec![
        ("completed", Json::from(r.completed)),
        ("tokens", Json::from(r.tokens)),
        ("makespan_ns", Json::from(r.makespan_ns)),
        ("tokens_per_sec", Json::from(r.tokens_per_sec)),
        ("overlap_eff_pct", Json::from(r.overlap_eff * 100.0)),
        ("ttft_ns", latency_percentiles(&r.ttft)),
        ("per_token_ns", latency_percentiles(&r.per_token)),
        ("latency_ns", latency_percentiles(&r.latency)),
    ];
    // Sketch-mode twins: additive keys, populated only when the
    // scenario opted into `percentiles: "sketch"` — the default
    // document keeps its historical byte shape.
    if let Some(s) = &r.ttft_sketch {
        fields.push(("ttft_ns_sketch", latency_percentiles(s)));
    }
    if let Some(s) = &r.per_token_sketch {
        fields.push(("per_token_ns_sketch", latency_percentiles(s)));
    }
    if let Some(s) = &r.latency_sketch {
        fields.push(("latency_ns_sketch", latency_percentiles(s)));
    }
    if let Some(slo) = &r.slo {
        fields.push(("slo", slo.to_json()));
    }
    obj(fields)
}

/// One topology's entry of the scale/sweep documents: legacy v1
/// fields (`prompt`/`gen` for fixed mixes, `arrival_mean_ns` for
/// Poisson arrivals, cluster-level), the workload spec, one block per
/// method (keyed by [`Method::serve_label`]), and the comparative
/// fields whenever the set contains both the decoupled baseline and
/// flux.
pub(super) fn scale_entry(
    sc: &ScaleScenario,
    methods: &[Method],
    runs: &[ScaleReport],
) -> Json {
    use crate::workload::ArrivalSpec;
    let topo = sc.topo;
    let mut fields = vec![
        ("topology", Json::from(topo.name)),
        ("cluster", Json::from(topo.cluster.name)),
        ("nodes", Json::from(topo.nodes)),
        ("tp", Json::from(topo.tp)),
        ("dp", Json::from(topo.dp)),
        ("requests", Json::from(sc.n_requests())),
    ];
    if let Some(c) = sc.workload.mix.fixed() {
        fields.push(("prompt", Json::from(c.prompt)));
        fields.push(("gen", Json::from(c.gen)));
    }
    if let ArrivalSpec::Poisson { mean_ns } = sc.workload.arrival {
        fields.push((
            "arrival_mean_ns",
            Json::from(mean_ns / topo.dp as f64),
        ));
    }
    fields.push(("seed", Json::from(sc.seed as usize)));
    fields.push(("workload", sc.workload.to_json()));
    for (m, r) in methods.iter().zip(runs) {
        fields.push((m.serve_label(), scale_method_json(r)));
    }
    if let Some(cmp) = ScaleComparison::from_runs(runs) {
        fields.push(("speedup", Json::from(cmp.speedup())));
        fields.push((
            "latency_speedup",
            Json::from(cmp.latency_speedup()),
        ));
        if let Some(delta) = cmp.goodput_delta() {
            fields.push(("goodput_delta", Json::from(delta)));
        }
    }
    obj(fields)
}

/// Run one list of serving cells under one method set through the
/// runner, at (cell, method) grain; returns per-cell entry documents
/// in cell order. Shared with the sweep document.
pub(super) fn scale_entries(
    cells: &[ScaleScenario],
    methods: &[Method],
    runner: &Runner,
) -> Result<Vec<Json>> {
    let runs: Vec<Vec<ScaleReport>> =
        runner.run_product(cells, methods, |sc, &m| run_scale(sc, m))?;
    Ok(cells
        .iter()
        .zip(&runs)
        .map(|(sc, cell_runs)| scale_entry(sc, methods, cell_runs))
        .collect())
}

/// The serving-at-scale document for one scenario, cells executed by
/// `runner`.
pub fn scale_doc_scenario(sc: &Scenario, runner: &Runner) -> Result<Json> {
    ensure!(sc.mode == Mode::Serve, "not a serve scenario");
    let methods = sc.method_set();
    let cells = sc.serve_cells()?;
    let topologies = scale_entries(&cells, &methods, runner)?;
    let mut top = vec![
        ("schema", Json::from(SCALE_SCHEMA)),
        ("quick", Json::from(sc.quick)),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("topologies", Json::Arr(topologies)),
    ];
    if let Some(names) = sc.topo_filter_names()? {
        // A filtered doc must be distinguishable from a full sweep:
        // the trajectory diffing contract compares like with like.
        top.push(("topo_filter", super::topo_filter_json(&names)));
    }
    if let Some(name) = sc.workload_name() {
        // Same contract for a swapped request source.
        top.push(("workload_filter", Json::from(name)));
    }
    if !sc.name.is_empty() {
        // Scenario files stamp their name; CLI-built anonymous
        // scenarios keep the document's historical shape.
        top.push(("scenario", Json::from(sc.name.as_str())));
    }
    Ok(obj(top))
}

/// The serving-at-scale document: every topology in
/// `ALL_SCALE_TOPOLOGIES` under the decoupled and Flux executions.
/// Deterministic for a given `quick` — byte-identical across reruns.
pub fn scale_doc(quick: bool) -> Result<Json> {
    scale_doc_for(quick, None)
}

/// Like [`scale_doc`], restricted to one topology when `only` is set
/// (`flux simulate --scale --topo <name>`).
pub fn scale_doc_for(
    quick: bool,
    only: Option<&'static ScaleTopology>,
) -> Result<Json> {
    scale_doc_with(quick, only, None)
}

/// Like [`scale_doc_for`], with the request source swapped for a
/// custom workload (`flux simulate --scale --workload <preset|file>`).
pub fn scale_doc_with(
    quick: bool,
    only: Option<&'static ScaleTopology>,
    workload: Option<&WorkloadSpec>,
) -> Result<Json> {
    scale_doc_scenario(
        &Scenario::serve(only, workload.cloned(), quick),
        &Runner::new(),
    )
}

/// Human-readable rendering of the scale document.
pub fn print_scale(doc: &Json) -> Result<()> {
    fn ms(j: &Json, k: &str) -> Result<String> {
        Ok(format!("{:.1}", j.get(k)?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("topologies")?.as_arr()? {
        let fx = e.get("flux")?;
        let de = e.get("decoupled")?;
        rows.push(vec![
            e.get("topology")?.as_str()?.to_string(),
            format!(
                "{}x{}",
                e.get("tp")?.as_usize()?,
                e.get("dp")?.as_usize()?
            ),
            ms(fx.get("ttft_ns")?, "p50_ns")?,
            ms(fx.get("ttft_ns")?, "p99_ns")?,
            ms(fx.get("per_token_ns")?, "p50_ns")?,
            format!("{:.1}", fx.get("tokens_per_sec")?.as_f64()?),
            format!("{:.1}", de.get("tokens_per_sec")?.as_f64()?),
            format!("{:.1}%", fx.get("overlap_eff_pct")?.as_f64()?),
            format!("{:.2}x", e.get("speedup")?.as_f64()?),
        ]);
    }
    crate::util::bench::table(
        "serving at scale (flux vs decoupled, pinned seeds)",
        &[
            "topology",
            "tp x dp",
            "ttft p50 ms",
            "ttft p99 ms",
            "tok p50 ms",
            "flux tok/s",
            "dec tok/s",
            "flux eff",
            "speedup",
        ],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::ALL_SCALE_TOPOLOGIES;

    #[test]
    fn scale_doc_is_byte_stable_and_well_formed() {
        let a = scale_doc(true).unwrap().to_string();
        let b = scale_doc(true).unwrap().to_string();
        assert_eq!(a, b, "scale doc must be deterministic");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            SCALE_SCHEMA
        );
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), ALL_SCALE_TOPOLOGIES.len());
        for t in topos {
            for k in [
                "topology", "cluster", "nodes", "tp", "dp", "requests",
                "prompt", "gen", "arrival_mean_ns", "workload",
                "decoupled", "flux", "speedup", "goodput_delta",
            ] {
                assert!(t.opt(k).is_some(), "missing key {k}");
            }
            let fx = t.get("flux").unwrap();
            let ttft = fx.get("ttft_ns").unwrap();
            assert!(
                ttft.get("p99_ns").unwrap().as_f64().unwrap()
                    >= ttft.get("p50_ns").unwrap().as_f64().unwrap()
            );
            assert!(
                fx.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0
            );
            // v2: the default preset defines SLOs, so both methods
            // carry goodput accounting.
            let slo = fx.get("slo").unwrap();
            let g = slo.get("goodput").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&g), "goodput {g}");
            // The workload spec round-trips from the report itself.
            let wl = crate::workload::WorkloadSpec::from_json(
                t.get("workload").unwrap(),
            )
            .unwrap();
            assert_eq!(wl.name, "poisson-balanced");
        }
    }

    #[test]
    fn scale_doc_with_workload_marks_the_document() {
        let wl =
            crate::workload::preset("bursty-decode", true).unwrap();
        use crate::cost::arch::SCALE_TP8;
        let doc =
            scale_doc_with(true, Some(&SCALE_TP8), Some(&wl)).unwrap();
        assert_eq!(
            doc.get("workload_filter").unwrap().as_str().unwrap(),
            "bursty-decode"
        );
        assert_eq!(
            doc.get("topo_filter").unwrap().as_str().unwrap(),
            SCALE_TP8.name
        );
        // Anonymous CLI scenarios carry no scenario stamp.
        assert!(doc.opt("scenario").is_none());
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), 1);
        // Two-point mix + MMPP arrivals: no fixed prompt/gen, no
        // Poisson mean — the v1 compat fields are honestly absent.
        assert!(topos[0].opt("prompt").is_none());
        assert!(topos[0].opt("arrival_mean_ns").is_none());
    }

    #[test]
    fn named_scenario_with_custom_methods_extends_the_document() {
        use crate::exp::WorkloadRef;
        let sc = Scenario {
            name: "three-way".into(),
            mode: Mode::Serve,
            topos: Some(vec!["1-node tp8".into()]),
            workload: Some(WorkloadRef::Preset("steady-decode".into())),
            methods: Some(vec![
                Method::NonOverlap,
                Method::Medium,
                Method::Flux,
            ]),
            faults: None,
            metrics: None,
            percentiles: crate::util::stats::PercentileMode::Exact,
            quick: true,
        };
        let doc =
            scale_doc_scenario(&sc, &Runner::with_threads(2)).unwrap();
        assert_eq!(
            doc.get("scenario").unwrap().as_str().unwrap(),
            "three-way"
        );
        let t = &doc.get("topologies").unwrap().as_arr().unwrap()[0];
        // All three method blocks exist; flux still beats the
        // decoupled baseline on NVLink (the pinned sweep invariant).
        let span = |key: &str| {
            t.get(key).unwrap().get("makespan_ns").unwrap().as_f64()
        };
        let de = span("decoupled").unwrap();
        let md = span("medium").unwrap();
        let fx = span("flux").unwrap();
        assert!(md > 0.0, "medium block missing a makespan");
        assert!(fx <= de, "flux {fx} vs decoupled {de}");
        // Comparative fields still present (both references in set).
        assert!(t.get("speedup").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn sketch_scenario_adds_sketch_blocks_without_touching_exact() {
        use crate::util::stats::PercentileMode;
        let base = Scenario {
            name: "sketchy".into(),
            mode: Mode::Serve,
            topos: Some(vec!["1-node tp8".into()]),
            workload: None,
            methods: None,
            faults: None,
            metrics: None,
            percentiles: PercentileMode::Exact,
            quick: true,
        };
        let mut sketchy = base.clone();
        sketchy.percentiles = PercentileMode::Sketch;
        let runner = Runner::with_threads(1);
        let exact = scale_doc_scenario(&base, &runner).unwrap();
        let doc = scale_doc_scenario(&sketchy, &runner).unwrap();
        let te = &exact.get("topologies").unwrap().as_arr().unwrap()[0];
        let ts = &doc.get("topologies").unwrap().as_arr().unwrap()[0];
        let fe = te.get("flux").unwrap();
        let fs = ts.get("flux").unwrap();
        // Exact mode emits no sketch twins.
        assert!(fe.opt("ttft_ns_sketch").is_none());
        // Sketch mode adds them and leaves the exact fields bit-equal.
        for k in ["ttft_ns", "per_token_ns", "latency_ns"] {
            assert_eq!(
                fe.get(k).unwrap().to_string(),
                fs.get(k).unwrap().to_string(),
                "exact block {k} must not move in sketch mode"
            );
            let sk = fs.get(&format!("{k}_sketch")).unwrap();
            assert!(sk.get("p99_ns").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn print_scale_renders_without_error() {
        print_scale(&scale_doc(true).unwrap()).unwrap();
    }
}
