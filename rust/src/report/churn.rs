//! The failure-and-churn degradation document (`flux simulate
//! --scale|--train --faults <preset|file.json> --json`, schema
//! `flux-churn-v1`): one expanded fault timeline per intensity rung
//! of [`INTENSITIES`], every selected topology under the scenario's
//! method set, cells executed by the [`crate::exp::Runner`] at
//! (topology, method x intensity) grain and merged in fixed order —
//! byte-identical at any worker count.
//!
//! Intensity 0 expands to an **empty** timeline and dispatches to the
//! untouched fault-free simulation, so the first point of every curve
//! reproduces the flux-scale-v2 / flux-train-v1 numbers bit-for-bit
//! — the degradation curves are anchored to the exact baselines the
//! trajectory already pins.

use anyhow::{ensure, Result};

use crate::exp::{Mode, Runner, Scenario};
use crate::faults::FaultSpec;
use crate::overlap::Method;
use crate::serving::scale::{
    run_scale, run_scale_faulted, ScaleReport, ScaleScenario,
};
use crate::training::{run_train_with, TrainRun, TrainScenario};
use crate::util::json::{obj, Json};

use super::CHURN_SCHEMA;

/// The degradation-curve rungs every churn document sweeps: the
/// fault-free floor, the spec at half strength, the spec as written.
/// Expansion draws all randomness *before* scaling by the rung, so
/// the three timelines nest — higher intensity only stretches
/// downtimes and inflates factors, it never re-rolls.
pub const INTENSITIES: [f64; 3] = [0.0, 0.5, 1.0];

/// The (method, intensity) job grid one topology cell fans out into —
/// method-major, so a cell's runs chunk per method in
/// [`INTENSITIES`]-order.
fn job_grid(methods: &[Method]) -> Vec<(Method, f64)> {
    let mut jobs = Vec::with_capacity(methods.len() * INTENSITIES.len());
    for &m in methods {
        for &k in &INTENSITIES {
            jobs.push((m, k));
        }
    }
    jobs
}

/// One point of a serving degradation curve. `goodput`/`abandoned`
/// appear whenever the workload defines SLOs (every preset does);
/// `failed` counts requests drained by a kill/resize plus arrivals
/// that found no routable replica.
fn serve_point(intensity: f64, r: &ScaleReport) -> Json {
    let mut fields = vec![
        ("intensity", Json::from(intensity)),
        ("completed", Json::from(r.completed)),
        ("failed", Json::from(r.failed)),
        ("tokens", Json::from(r.tokens)),
        ("makespan_ns", Json::from(r.makespan_ns)),
        ("tokens_per_sec", Json::from(r.tokens_per_sec)),
        ("ttft_p99_ns", Json::from(r.ttft.p99)),
    ];
    if let Some(slo) = &r.slo {
        fields.push(("goodput", Json::from(slo.goodput())));
        fields.push(("abandoned", Json::from(slo.abandoned)));
    }
    obj(fields)
}

/// Per-topology serving entries, cells executed by `runner` at
/// (topology, method x intensity) grain.
fn serve_entries(
    sc: &Scenario,
    spec: &FaultSpec,
    runner: &Runner,
) -> Result<Vec<Json>> {
    let methods = sc.method_set();
    let cells = sc.serve_cells()?;
    let jobs = job_grid(&methods);
    let runs: Vec<Vec<ScaleReport>> =
        runner.run_product(&cells, &jobs, |cell: &ScaleScenario, &(m, k)| {
            let tl = spec.expand(cell.topo.dp, k);
            if tl.is_empty() {
                run_scale(cell, m)
            } else {
                run_scale_faulted(cell, m, &tl)
            }
        })?;
    let mut out = Vec::new();
    for (cell, cell_runs) in cells.iter().zip(&runs) {
        let mut fields = vec![
            ("topology", Json::from(cell.topo.name)),
            ("cluster", Json::from(cell.topo.cluster.name)),
            ("nodes", Json::from(cell.topo.nodes)),
            ("tp", Json::from(cell.topo.tp)),
            ("dp", Json::from(cell.topo.dp)),
            ("requests", Json::from(cell.n_requests())),
            ("seed", Json::from(cell.seed as usize)),
            ("workload", cell.workload.to_json()),
        ];
        for (mi, m) in methods.iter().enumerate() {
            let chunk = &cell_runs
                [mi * INTENSITIES.len()..(mi + 1) * INTENSITIES.len()];
            let points: Vec<Json> = INTENSITIES
                .iter()
                .zip(chunk)
                .map(|(&k, r)| serve_point(k, r))
                .collect();
            let mut mfields = vec![("curve", Json::Arr(points))];
            let first = chunk[0].slo.as_ref();
            let last = chunk[chunk.len() - 1].slo.as_ref();
            if let (Some(a), Some(b)) = (first, last) {
                // The headline number: goodput lost between the
                // fault-free floor and the spec as written.
                mfields.push((
                    "goodput_drop",
                    Json::from(a.goodput() - b.goodput()),
                ));
            }
            fields.push((m.serve_label(), obj(mfields)));
        }
        out.push(obj(fields));
    }
    Ok(out)
}

/// One point of a training degradation curve; `slowdown` is the step
/// time relative to the same method's fault-free floor (point 0 is
/// exactly 1.0 by construction).
fn train_point(intensity: f64, r: &TrainRun, base_step: f64) -> Json {
    obj(vec![
        ("intensity", Json::from(intensity)),
        ("step_ns", Json::from(r.step_ns)),
        ("pipe_ns", Json::from(r.pipe_ns)),
        ("dp_exposed_ns", Json::from(r.dp_exposed_ns)),
        ("slowdown", Json::from(r.step_ns / base_step)),
    ])
}

/// Per-topology training entries. Straggler windows index pipeline
/// stages (the training analogue of a serving replica) and NIC
/// windows stretch PP hops and DP buckets; specs with kills or
/// resizes are rejected by [`crate::training::run_train_with`].
fn train_entries(
    sc: &Scenario,
    spec: &FaultSpec,
    runner: &Runner,
) -> Result<Vec<Json>> {
    let methods = sc.method_set();
    let cells = sc.train_cells()?;
    let jobs = job_grid(&methods);
    let runs: Vec<Vec<TrainRun>> =
        runner.run_product(&cells, &jobs, |cell: &TrainScenario, &(m, k)| {
            let tl = spec.expand(cell.topo.pp, k);
            if tl.is_empty() {
                run_train_with(cell, m, None, None)
            } else {
                run_train_with(cell, m, Some(&tl), None)
            }
        })?;
    let mut out = Vec::new();
    for (cell, cell_runs) in cells.iter().zip(&runs) {
        let mut fields = vec![
            ("topology", Json::from(cell.topo.name)),
            ("cluster", Json::from(cell.topo.cluster.name)),
            ("dp", Json::from(cell.topo.dp)),
            ("pp", Json::from(cell.topo.pp)),
            ("tp", Json::from(cell.topo.tp)),
            ("microbatches", Json::from(cell.microbatches)),
            ("seed", Json::from(cell.seed as usize)),
        ];
        for (mi, m) in methods.iter().enumerate() {
            let chunk = &cell_runs
                [mi * INTENSITIES.len()..(mi + 1) * INTENSITIES.len()];
            let base_step = chunk[0].step_ns;
            let points: Vec<Json> = INTENSITIES
                .iter()
                .zip(chunk)
                .map(|(&k, r)| train_point(k, r, base_step))
                .collect();
            fields.push((
                m.train_label(),
                obj(vec![
                    ("curve", Json::Arr(points)),
                    (
                        "slowdown",
                        Json::from(
                            chunk[chunk.len() - 1].step_ns / base_step,
                        ),
                    ),
                ]),
            ));
        }
        out.push(obj(fields));
    }
    Ok(out)
}

/// The churn document for one scenario and one fault spec: goodput /
/// step-time degradation curves per method x topology x intensity.
/// Serve scenarios expand the spec per replica (`dp`), train
/// scenarios per pipeline stage (`pp`).
pub fn churn_doc_scenario(
    sc: &Scenario,
    spec: &FaultSpec,
    runner: &Runner,
) -> Result<Json> {
    spec.validate()?;
    ensure!(
        !spec.is_none(),
        "fault spec {:?} injects nothing — run the plain report \
         (drop --faults) instead of an all-zero degradation curve",
        spec.name
    );
    let topologies = match sc.mode {
        Mode::Serve => serve_entries(sc, spec, runner)?,
        Mode::Train => train_entries(sc, spec, runner)?,
    };
    let mut top = vec![
        ("schema", Json::from(CHURN_SCHEMA)),
        ("quick", Json::from(sc.quick)),
        ("mode", Json::from(sc.mode.name())),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("faults", spec.to_json()),
        (
            "intensities",
            Json::Arr(INTENSITIES.iter().map(|&k| Json::from(k)).collect()),
        ),
        ("topologies", Json::Arr(topologies)),
    ];
    if let Some(names) = sc.topo_filter_names()? {
        // Same contract as every other doc: a filtered report must be
        // distinguishable from a full sweep when diffing trajectories.
        top.push(("topo_filter", super::topo_filter_json(&names)));
    }
    if let Some(name) = sc.workload_name() {
        top.push(("workload_filter", Json::from(name)));
    }
    if !sc.name.is_empty() {
        top.push(("scenario", Json::from(sc.name.as_str())));
    }
    Ok(obj(top))
}

/// Human-readable rendering of the churn document: one row per
/// topology x method, the curve left to right.
pub fn print_churn(doc: &Json) -> Result<()> {
    let mode = doc.get("mode")?.as_str()?;
    match mode {
        "serve" => print_serve_churn(doc),
        _ => print_train_churn(doc),
    }
}

fn print_serve_churn(doc: &Json) -> Result<()> {
    // Goodput when the workload defines SLOs, "-" otherwise.
    fn good(p: &Json) -> Result<String> {
        Ok(match p.opt("goodput") {
            Some(g) => format!("{:.1}%", g.as_f64()? * 100.0),
            None => "-".to_string(),
        })
    }
    let mut rows = Vec::new();
    for e in doc.get("topologies")?.as_arr()? {
        for key in ["decoupled", "flux"] {
            let Some(block) = e.opt(key) else { continue };
            let curve = block.get("curve")?.as_arr()?;
            let last = &curve[curve.len() - 1];
            rows.push(vec![
                e.get("topology")?.as_str()?.to_string(),
                key.to_string(),
                good(&curve[0])?,
                good(&curve[1])?,
                good(last)?,
                last.get("failed")?.as_usize()?.to_string(),
                format!(
                    "{:.1}",
                    last.get("tokens_per_sec")?.as_f64()?
                ),
            ]);
        }
    }
    crate::util::bench::table(
        "serving under churn (goodput per fault intensity)",
        &[
            "topology",
            "method",
            "k=0",
            "k=0.5",
            "k=1",
            "failed@1",
            "tok/s@1",
        ],
        &rows,
    );
    Ok(())
}

fn print_train_churn(doc: &Json) -> Result<()> {
    fn ms(p: &Json) -> Result<String> {
        Ok(format!("{:.1}", p.get("step_ns")?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("topologies")?.as_arr()? {
        for key in ["megatron", "te", "flux"] {
            let Some(block) = e.opt(key) else { continue };
            let curve = block.get("curve")?.as_arr()?;
            let last = &curve[curve.len() - 1];
            rows.push(vec![
                e.get("topology")?.as_str()?.to_string(),
                key.to_string(),
                ms(&curve[0])?,
                ms(&curve[1])?,
                ms(last)?,
                format!(
                    "{:.1}",
                    last.get("dp_exposed_ns")?.as_f64()? / 1e6
                ),
                format!(
                    "{:.2}x",
                    block.get("slowdown")?.as_f64()?
                ),
            ]);
        }
    }
    crate::util::bench::table(
        "training under churn (step ms per fault intensity)",
        &[
            "topology",
            "method",
            "k=0 ms",
            "k=0.5 ms",
            "k=1 ms",
            "dp tail@1 ms",
            "slowdown",
        ],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::ALL_SCALE_TOPOLOGIES;
    use crate::faults;

    fn serve_doc(threads: usize) -> Json {
        let sc = Scenario::serve(None, None, true);
        let spec = faults::preset("replica-churn").unwrap();
        churn_doc_scenario(&sc, &spec, &Runner::with_threads(threads))
            .unwrap()
    }

    #[test]
    fn churn_doc_is_byte_stable_across_thread_counts() {
        let a = serve_doc(1).to_string();
        let b = serve_doc(4).to_string();
        assert_eq!(a, b, "churn doc must be thread-invariant");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            CHURN_SCHEMA
        );
        assert_eq!(doc.get("mode").unwrap().as_str().unwrap(), "serve");
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), ALL_SCALE_TOPOLOGIES.len());
        for t in topos {
            for key in ["decoupled", "flux"] {
                let curve = t
                    .get(key)
                    .unwrap()
                    .get("curve")
                    .unwrap()
                    .as_arr()
                    .unwrap();
                assert_eq!(curve.len(), INTENSITIES.len());
                for (p, &k) in curve.iter().zip(&INTENSITIES) {
                    assert_eq!(
                        p.get("intensity").unwrap().as_f64().unwrap(),
                        k
                    );
                }
                // Goodput never improves as the spec scales up.
                let g = |i: usize| {
                    curve[i].get("goodput").unwrap().as_f64().unwrap()
                };
                assert!(g(0) >= g(2), "{key}: {} < {}", g(0), g(2));
                // No faults at k=0: nothing fails, nothing abandons
                // beyond what the SLO already abandons fault-free.
                assert_eq!(
                    curve[0].get("failed").unwrap().as_usize().unwrap(),
                    0
                );
            }
        }
    }

    #[test]
    fn intensity_zero_reproduces_the_fault_free_scale_doc() {
        let churn = serve_doc(2);
        let scale = crate::report::scale_doc(true).unwrap();
        let ct = churn.get("topologies").unwrap().as_arr().unwrap();
        let st = scale.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(ct.len(), st.len());
        for (c, s) in ct.iter().zip(st) {
            for key in ["decoupled", "flux"] {
                let p0 = &c
                    .get(key)
                    .unwrap()
                    .get("curve")
                    .unwrap()
                    .as_arr()
                    .unwrap()[0];
                let sm = s.get(key).unwrap();
                for (ck, sk) in [
                    ("makespan_ns", "makespan_ns"),
                    ("tokens_per_sec", "tokens_per_sec"),
                ] {
                    assert_eq!(
                        p0.get(ck).unwrap().as_f64().unwrap(),
                        sm.get(sk).unwrap().as_f64().unwrap(),
                        "{key}.{ck} must be bit-identical"
                    );
                }
                assert_eq!(
                    p0.get("ttft_p99_ns").unwrap().as_f64().unwrap(),
                    sm.get("ttft_ns")
                        .unwrap()
                        .get("p99_ns")
                        .unwrap()
                        .as_f64()
                        .unwrap()
                );
            }
        }
    }

    #[test]
    fn train_churn_doc_slows_every_method() {
        use crate::cost::arch::TRAIN_NVLINK_128;
        let sc = Scenario::train(Some(&TRAIN_NVLINK_128), true);
        let spec = faults::preset("straggler-storm").unwrap();
        let a = churn_doc_scenario(&sc, &spec, &Runner::with_threads(1))
            .unwrap();
        let b = churn_doc_scenario(&sc, &spec, &Runner::with_threads(3))
            .unwrap();
        assert_eq!(a.to_string(), b.to_string());
        for t in a.get("topologies").unwrap().as_arr().unwrap() {
            for key in ["megatron", "te", "flux"] {
                let block = t.get(key).unwrap();
                let curve =
                    block.get("curve").unwrap().as_arr().unwrap();
                let s = |i: usize| {
                    curve[i].get("slowdown").unwrap().as_f64().unwrap()
                };
                assert_eq!(s(0), 1.0, "{key}: point 0 is the floor");
                assert!(
                    s(2) > s(1) && s(1) > 1.0,
                    "{key}: {} / {}",
                    s(1),
                    s(2)
                );
                assert_eq!(
                    block.get("slowdown").unwrap().as_f64().unwrap(),
                    s(2)
                );
            }
        }
    }

    #[test]
    fn kills_are_rejected_in_train_mode() {
        let sc = Scenario::train(None, true);
        let spec = faults::preset("replica-churn").unwrap();
        let err =
            churn_doc_scenario(&sc, &spec, &Runner::with_threads(1))
                .unwrap_err();
        assert!(
            format!("{err:#}").contains("kill"),
            "pointed error: {err:#}"
        );
    }

    #[test]
    fn empty_specs_are_rejected() {
        let sc = Scenario::serve(None, None, true);
        let err = churn_doc_scenario(
            &sc,
            &crate::faults::FaultSpec::none(),
            &Runner::with_threads(1),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("injects nothing"));
    }

    #[test]
    fn print_churn_renders_both_modes() {
        print_churn(&serve_doc(1)).unwrap();
        let spec = faults::preset("nic-brownout").unwrap();
        let tr = churn_doc_scenario(
            &Scenario::train(None, true),
            &spec,
            &Runner::new(),
        )
        .unwrap();
        print_churn(&tr).unwrap();
    }
}
