//! The op-level bench document (`flux bench --json`, schema
//! `flux-bench-v1`): the hotpath suite on the cluster simulator with
//! pinned seeds, every (cluster, op, m) cell an independent
//! [`crate::exp::Runner`] job.
//!
//! # Which cells run when
//!
//! | section          | `--quick`                  | full              |
//! |------------------|----------------------------|-------------------|
//! | `suite`          | 1 m × 2 seeds per cluster  | 3 m × 5 seeds     |
//! | `events_per_sec` | resident 4096              | 256/4096/65536    |
//! | `fleet` (hold)   | dp64                       | dp64 + dp256      |
//! | `fleet` (scale)  | dp64 quick-scale cell      | dp64 + dp256      |
//!
//! Every key in the base document is a pure function of `(quick,)` —
//! byte-stable across reruns and machines. `--wall` adds the
//! machine-local timings (`wall_ns`, `events_per_sec`, the heap-queue
//! comparison) on top, re-running the hold/fleet cells with wall
//! clocks on; those keys live under `wall` and inside wall-mode cell
//! objects, never in the byte-compared base document. The `--quick`
//! bound exists so CI's byte-compare loop stays fast: dp256 (65536
//! resident events, a 2048-request serving cell) runs only in full
//! mode.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::cost::arch::{
    ClusterSpec, ScaleTopology, ALL_CLUSTERS, FLEET_NVLINK_DP256,
    FLEET_NVLINK_DP64,
};
use crate::cost::gemm::tile_grid;
use crate::exp::Runner;
use crate::figures::{ag_problem, rs_problem};
use crate::overlap::{baseline, medium, Method, Problem};
use crate::serving::scale::{run_scale, ScaleScenario};
use crate::sim::engine::{hold_workload, hold_workload_heap, HoldRun};
use crate::tuner::TunerCache;
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

use super::{summary_json, write_doc, SCHEMA};

/// Pinned seeds for the simulated suite (full / quick).
const SEEDS_FULL: [u64; 5] = [7, 11, 13, 17, 23];
const SEEDS_QUICK: [u64; 2] = [7, 11];

/// GEMM m sweep (full / quick); GPT-3 op shapes, 8-way TP.
const MS_FULL: [usize; 3] = [512, 2048, 8192];
const MS_QUICK: [usize; 1] = [2048];

/// Pinned seed and sizes for the DES-engine hold workload behind the
/// `events_per_sec` section (full / quick resident populations).
const HOLD_SEED: u64 = 0x0E5C;
const HOLD_RESIDENT_FULL: [usize; 3] = [256, 4096, 65536];
const HOLD_RESIDENT_QUICK: [usize; 1] = [4096];
const HOLD_OPS_FULL: usize = 2_000_000;
const HOLD_OPS_QUICK: usize = 200_000;

/// Pinned seed and sizes for the `fleet` section: hold populations
/// sized to the dpN pools at [`FLEET_EVENTS_PER_REPLICA`] resident
/// events per DP replica (dp64 → 16384, dp256 → 65536).
const FLEET_SEED: u64 = 0x0F1E;
const FLEET_EVENTS_PER_REPLICA: usize = 256;
const FLEET_OPS_FULL: usize = 1_000_000;
const FLEET_OPS_QUICK: usize = 100_000;

/// One suite entry: a (cluster, op, m) cell with per-method metrics.
/// Cells never share tuner state: every (cluster, problem) pair is
/// tuned exactly once either way, with the same first pinned seed, so
/// a per-cell cache is byte-identical to the historical shared one —
/// and lets cells run on worker threads.
fn suite_entry(
    cluster: &'static ClusterSpec,
    p: &Problem,
    seeds: &[u64],
) -> Json {
    let mut cache = TunerCache::new();
    let base = baseline::simulate(cluster, p);

    let te_t: Vec<crate::overlap::OpTiming> = seeds
        .iter()
        .map(|&s| medium::simulate(cluster, p, s))
        .collect();
    let te: Vec<f64> = te_t.iter().map(|t| t.overall_ns).collect();
    let te_eff: Vec<f64> =
        te_t.iter().map(|t| t.overlap_efficiency(&base)).collect();

    // Tuned config is picked once with the first pinned seed (the same
    // cache a serving loop would hold), then timed across all seeds.
    let tuned = cache.get(cluster, p, seeds[0]);
    let fx_t: Vec<crate::overlap::OpTiming> = seeds
        .iter()
        .map(|&s| {
            crate::overlap::flux::simulate(cluster, p, &tuned.config, s)
        })
        .collect();
    let fx: Vec<f64> = fx_t.iter().map(|t| t.overall_ns).collect();
    let fx_eff: Vec<f64> =
        fx_t.iter().map(|t| t.overlap_efficiency(&base)).collect();

    // Simulated tile throughput: GEMM tiles the whole TP group retires
    // per second of simulated time (p50).
    let (_, tasks) = tile_grid(&cluster.arch, &p.local_gemm());
    let total_tiles = (tasks.len() * p.n_tp) as f64;

    // Percentiles via the one Summary substrate (identical sort +
    // interpolation to the historical hand-rolled emitter).
    let method = |xs: &[f64], effs: &[f64]| -> Json {
        let s = Summary::of(xs);
        let eff = Summary::of(effs);
        obj(vec![
            ("p50_ns", Json::from(s.p50)),
            ("p95_ns", Json::from(s.p95)),
            ("overlap_eff_pct", Json::from(eff.p50 * 100.0)),
            ("tiles_per_sec", Json::from(total_tiles / (s.p50 * 1e-9))),
        ])
    };

    obj(vec![
        ("cluster", Json::from(cluster.name)),
        ("op", Json::from(p.op.name())),
        ("m", Json::from(p.m)),
        ("n_tp", Json::from(p.n_tp)),
        ("gemm_nonsplit_ns", Json::from(base.gemm_nonsplit_ns)),
        (
            "baseline",
            obj(vec![
                ("overall_ns", Json::from(base.overall_ns)),
                ("ect_ns", Json::from(base.ect_ns())),
            ]),
        ),
        ("te", method(&te, &te_eff)),
        ("flux", method(&fx, &fx_eff)),
        ("flux_config", Json::from(format!("{:?}", tuned.config))),
    ])
}

/// Build the full bench document (deterministic for a given `quick`).
pub fn bench_doc(quick: bool) -> Json {
    bench_doc_with(quick, &Runner::new())
}

/// Like [`bench_doc`], with the cell matrix executed by `runner`
/// (byte-identical at any worker count).
pub fn bench_doc_with(quick: bool, runner: &Runner) -> Json {
    let seeds: &[u64] = if quick { &SEEDS_QUICK } else { &SEEDS_FULL };
    let ms: &[usize] = if quick { &MS_QUICK } else { &MS_FULL };
    let mut cells: Vec<(&'static ClusterSpec, Problem)> = Vec::new();
    for cluster in ALL_CLUSTERS {
        for &m in ms {
            for p in [ag_problem(m, 8), rs_problem(m, 8)] {
                cells.push((cluster, p));
            }
        }
    }
    let suite = runner
        .run_matrix(&cells, |&(cluster, p)| {
            Ok(suite_entry(cluster, &p, seeds))
        })
        .expect("bench cells are infallible");
    obj(vec![
        ("schema", Json::from(SCHEMA)),
        ("quick", Json::from(quick)),
        (
            "seeds",
            Json::Arr(
                seeds.iter().map(|&s| Json::from(s as usize)).collect(),
            ),
        ),
        ("suite", Json::Arr(suite)),
        // Additive on flux-bench-v1 (consumers tolerate added keys):
        // deterministic engine-throughput workload counters. Wall-clock
        // throughput lives under `wall.events_per_sec` (--wall only) so
        // this document stays byte-stable across reruns and machines.
        ("events_per_sec", events_per_sec_doc(quick, false, runner)),
        // Also additive: fleet-scale engine populations + quick-scale
        // serving cells on the dpN pools (wall twin under `wall.fleet`).
        ("fleet", fleet_doc(quick, false, runner)),
    ])
}

/// Fleet pools benched in the given mode: dp64 always; dp256 only in
/// full mode, so `--quick` wall time stays bounded (module docs).
fn fleet_topos(quick: bool) -> Vec<&'static ScaleTopology> {
    let mut topos = vec![&FLEET_NVLINK_DP64];
    if !quick {
        topos.push(&FLEET_NVLINK_DP256);
    }
    topos
}

/// The `fleet` section: the DES engine under fleet-scale event
/// populations, plus a quick-scale serving cell per pool proving the
/// full serving hot path completes at that DP.
///
/// `cells` drives the pinned-seed hold workload with one resident
/// event per in-flight request slot ([`FLEET_EVENTS_PER_REPLICA`] per
/// replica) — pop/schedule counts, the FNV pop-sequence checksum and
/// the event-slab high-water mark are all pure functions of
/// `(quick,)`. `scale` runs the quick serving preset end to end on
/// each pool and reports its deterministic totals. Same wall split as
/// [`events_per_sec_doc`]: `wall_ns`/`events_per_sec` appear only
/// with `wall = true`, so the base document stays byte-stable.
pub fn fleet_doc(quick: bool, wall: bool, runner: &Runner) -> Json {
    let ops = if quick { FLEET_OPS_QUICK } else { FLEET_OPS_FULL };
    let topos = fleet_topos(quick);
    let holds: Vec<HoldRun> = runner
        .run_matrix(&topos, |t| {
            Ok(hold_workload(
                t.dp * FLEET_EVENTS_PER_REPLICA,
                ops,
                FLEET_SEED,
            ))
        })
        .expect("fleet hold cells are infallible");
    let scales: Vec<(usize, usize, f64)> = runner
        .run_matrix(&topos, |t| {
            let rep = run_scale(&ScaleScenario::quick(*t), Method::Flux)?;
            Ok((rep.completed, rep.tokens, rep.makespan_ns))
        })
        .expect("fleet pools serve the quick preset");

    let mut cells = Vec::new();
    for (t, run) in topos.iter().zip(&holds) {
        let mut kv = vec![
            ("topo", Json::from(t.name)),
            ("dp", Json::from(t.dp)),
            ("resident", Json::from(run.resident)),
            ("ops", Json::from(run.ops)),
            ("pops", Json::from(run.pops as usize)),
            ("schedules", Json::from(run.schedules as usize)),
            ("checksum", Json::from(format!("{:016x}", run.checksum))),
            ("slab_high_water", Json::from(run.high_water)),
        ];
        if wall {
            kv.push(("wall_ns", Json::from(run.wall_ns)));
            kv.push((
                "events_per_sec",
                Json::from(
                    (run.pops + run.schedules) as f64
                        / (run.wall_ns * 1e-9),
                ),
            ));
        }
        cells.push(obj(kv));
    }
    let scale_cells: Vec<Json> = topos
        .iter()
        .zip(&scales)
        .map(|(t, &(completed, tokens, makespan_ns))| {
            obj(vec![
                ("topo", Json::from(t.name)),
                ("dp", Json::from(t.dp)),
                ("completed", Json::from(completed)),
                ("tokens", Json::from(tokens)),
                ("makespan_ns", Json::from(makespan_ns)),
            ])
        })
        .collect();
    obj(vec![
        ("workload", Json::from("hold")),
        ("seed", Json::from(FLEET_SEED as usize)),
        ("ops_per_cell", Json::from(ops)),
        ("events_per_replica", Json::from(FLEET_EVENTS_PER_REPLICA)),
        ("cells", Json::Arr(cells)),
        ("scale", Json::Arr(scale_cells)),
    ])
}

/// Hold-workload sizes for the given mode.
fn hold_cells(quick: bool) -> (&'static [usize], usize) {
    if quick {
        (&HOLD_RESIDENT_QUICK, HOLD_OPS_QUICK)
    } else {
        (&HOLD_RESIDENT_FULL, HOLD_OPS_FULL)
    }
}

/// The `events_per_sec` section: the DES engine driven through the
/// pinned-seed hold workload (see [`hold_workload`]), one cell per
/// resident-population size, cells spread across `runner`'s workers.
///
/// With `wall = false` every emitted key is a pure function of
/// `(quick,)` — pop/schedule counts and the pop-sequence checksum — so
/// the section is safe inside the byte-compared base document. With
/// `wall = true` each cell gains `wall_ns`/`events_per_sec`, the
/// section gains the aggregate throughput, and the same workload is
/// replayed through the reference
/// [`HeapEventQueue`](crate::sim::engine::HeapEventQueue) to report
/// `heap_events_per_sec` and `speedup_vs_heap` — the calendar queue's
/// win as a measured number on this machine.
pub fn events_per_sec_doc(quick: bool, wall: bool, runner: &Runner) -> Json {
    let (residents, ops) = hold_cells(quick);
    let runs: Vec<HoldRun> = runner
        .run_matrix(residents, |&resident| {
            Ok(hold_workload(resident, ops, HOLD_SEED))
        })
        .expect("hold cells are infallible");
    let heap_runs: Option<Vec<HoldRun>> = wall.then(|| {
        runner
            .run_matrix(residents, |&resident| {
                Ok(hold_workload_heap(resident, ops, HOLD_SEED))
            })
            .expect("hold cells are infallible")
    });

    let events_of = |r: &HoldRun| r.pops + r.schedules;
    let mut cells = Vec::new();
    let mut total_events = 0u64;
    let mut total_wall_ns = 0.0;
    for run in &runs {
        total_events += events_of(run);
        total_wall_ns += run.wall_ns;
        let mut kv = vec![
            ("resident", Json::from(run.resident)),
            ("ops", Json::from(run.ops)),
            ("pops", Json::from(run.pops as usize)),
            ("schedules", Json::from(run.schedules as usize)),
            ("checksum", Json::from(format!("{:016x}", run.checksum))),
        ];
        if wall {
            kv.push(("wall_ns", Json::from(run.wall_ns)));
            kv.push((
                "events_per_sec",
                Json::from(events_of(run) as f64 / (run.wall_ns * 1e-9)),
            ));
        }
        cells.push(obj(kv));
    }

    let mut kv = vec![
        ("workload", Json::from("hold")),
        ("seed", Json::from(HOLD_SEED as usize)),
        ("ops_per_cell", Json::from(ops)),
        ("cells", Json::Arr(cells)),
        ("total_events", Json::from(total_events as usize)),
    ];
    if let Some(heap_runs) = heap_runs {
        let mut heap_wall_ns = 0.0;
        for (cal, heap) in runs.iter().zip(&heap_runs) {
            // Same seed, same admission rules, same total order: a
            // checksum mismatch would mean the two queues disagreed on
            // pop order, which the differential tests forbid.
            assert_eq!(
                cal.checksum, heap.checksum,
                "calendar and heap queues diverged on the hold workload \
                 (resident={})",
                cal.resident
            );
            heap_wall_ns += heap.wall_ns;
        }
        let cal_eps = total_events as f64 / (total_wall_ns * 1e-9);
        let heap_eps = total_events as f64 / (heap_wall_ns * 1e-9);
        kv.push(("events_per_sec", Json::from(cal_eps)));
        kv.push(("heap_events_per_sec", Json::from(heap_eps)));
        kv.push(("speedup_vs_heap", Json::from(cal_eps / heap_eps)));
    }
    obj(kv)
}

/// Wall-clock hotpath timings (NOT byte-stable; appended only on
/// `--wall`).
pub fn wall_doc() -> Json {
    use crate::cost::arch::{A100_NVLINK, A100_PCIE};
    use crate::overlap::flux::FluxConfig;
    use crate::overlap::tiles;
    use crate::util::bench::Bench;

    let mut b = Bench::new();
    b.run("swizzle_order_64", || tiles::swizzle_order(64, 3, 8));
    b.run("comm_schedule_m8192_rows128", || {
        tiles::comm_schedule(8192, 3, 8, 128, true)
    });
    let p_rs = rs_problem(4096, 8);
    b.run("flux_rs_sim_m4096_nvlink", || {
        crate::overlap::flux::simulate(
            &A100_NVLINK,
            &p_rs,
            &FluxConfig::default(),
            7,
        )
    });
    let p_ag = ag_problem(4096, 8);
    b.run("flux_ag_sim_m4096_pcie", || {
        crate::overlap::flux::simulate(
            &A100_PCIE,
            &p_ag,
            &FluxConfig::for_cluster(&A100_PCIE),
            7,
        )
    });
    let entries: Vec<(&str, Json)> = b
        .results()
        .iter()
        .map(|(name, s)| (name.as_str(), summary_json(s)))
        .collect();
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

/// Write the bench document; returns the path written.
pub fn write_bench(
    quick: bool,
    wall: bool,
    out: Option<&Path>,
    runner: &Runner,
) -> Result<PathBuf> {
    let mut doc = bench_doc_with(quick, runner);
    if wall {
        if let Json::Obj(m) = &mut doc {
            let mut w = wall_doc();
            if let Json::Obj(wm) = &mut w {
                // Machine-local engine throughput (and the heap-queue
                // comparison) ride under `wall`, never in the
                // byte-compared base document.
                wm.insert(
                    "events_per_sec".to_string(),
                    events_per_sec_doc(quick, true, runner),
                );
                wm.insert(
                    "fleet".to_string(),
                    fleet_doc(quick, true, runner),
                );
            }
            m.insert("wall".to_string(), w);
        }
    }
    write_doc(&doc, out)
}

/// Human-readable rendering of a bench document (`flux bench` without
/// `--json`).
pub fn print_bench(doc: &Json) -> Result<()> {
    fn ms_of(j: &Json, k: &str) -> Result<String> {
        Ok(format!("{:.3}", j.get(k)?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("suite")?.as_arr()? {
        let fx = e.get("flux")?;
        let te = e.get("te")?;
        rows.push(vec![
            e.get("cluster")?.as_str()?.to_string(),
            e.get("op")?.as_str()?.to_string(),
            e.get("m")?.as_usize()?.to_string(),
            ms_of(e.get("baseline")?, "overall_ns")?,
            ms_of(te, "p50_ns")?,
            ms_of(fx, "p50_ns")?,
            ms_of(fx, "p95_ns")?,
            format!("{:.1}%", fx.get("overlap_eff_pct")?.as_f64()?),
            format!("{:.2e}", fx.get("tiles_per_sec")?.as_f64()?),
        ]);
    }
    crate::util::bench::table(
        "bench suite (simulated, pinned seeds)",
        &[
            "cluster", "op", "m", "torch ms", "TE p50 ms", "flux p50 ms",
            "flux p95 ms", "flux eff", "tiles/s",
        ],
        &rows,
    );
    if let Some(eps) = doc.opt("events_per_sec") {
        let mut rows = Vec::new();
        for c in eps.get("cells")?.as_arr()? {
            let mut row = vec![
                c.get("resident")?.as_usize()?.to_string(),
                c.get("ops")?.as_usize()?.to_string(),
                c.get("pops")?.as_usize()?.to_string(),
                c.get("checksum")?.as_str()?.to_string(),
            ];
            row.push(match c.opt("events_per_sec") {
                Some(v) => format!("{:.2e}", v.as_f64()?),
                None => "- (--wall)".to_string(),
            });
            rows.push(row);
        }
        crate::util::bench::table(
            "DES engine hold workload (pinned seed)",
            &["resident", "ops", "pops", "checksum", "events/s"],
            &rows,
        );
    }
    if let Some(fl) = doc.opt("fleet") {
        let mut rows = Vec::new();
        for c in fl.get("cells")?.as_arr()? {
            let mut row = vec![
                c.get("topo")?.as_str()?.to_string(),
                c.get("resident")?.as_usize()?.to_string(),
                c.get("pops")?.as_usize()?.to_string(),
                c.get("slab_high_water")?.as_usize()?.to_string(),
                c.get("checksum")?.as_str()?.to_string(),
            ];
            row.push(match c.opt("events_per_sec") {
                Some(v) => format!("{:.2e}", v.as_f64()?),
                None => "- (--wall)".to_string(),
            });
            rows.push(row);
        }
        for s in fl.get("scale")?.as_arr()? {
            rows.push(vec![
                format!("{} (scale)", s.get("topo")?.as_str()?),
                "-".to_string(),
                s.get("completed")?.as_usize()?.to_string(),
                "-".to_string(),
                format!(
                    "{:.3}ms",
                    s.get("makespan_ns")?.as_f64()? / 1e6
                ),
                "-".to_string(),
            ]);
        }
        crate::util::bench::table(
            "fleet cells (dpN pools, pinned seed)",
            &[
                "topo", "resident", "pops", "slab hw", "checksum",
                "events/s",
            ],
            &rows,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_doc_is_byte_stable() {
        // The acceptance contract: consecutive runs are byte-identical.
        let a = bench_doc(true).to_string();
        let b = bench_doc(true).to_string();
        assert_eq!(a, b);
        assert!(a.contains("flux-bench-v1"));
    }

    #[test]
    fn parallel_doc_is_byte_identical_to_sequential() {
        // The run_matrix contract on the op-level suite: worker count
        // never changes the document.
        let seq = bench_doc_with(true, &Runner::with_threads(1));
        let par = bench_doc_with(true, &Runner::with_threads(4));
        assert_eq!(seq.to_string(), par.to_string());
    }

    #[test]
    fn quick_doc_parses_and_has_schema_fields() {
        let doc = bench_doc(true);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert!(parsed.get("quick").unwrap().as_bool().unwrap());
        let suite = parsed.get("suite").unwrap().as_arr().unwrap();
        // 3 clusters x 1 m x 2 ops in quick mode.
        assert_eq!(suite.len(), 6);
        for e in suite {
            for k in [
                "cluster", "op", "m", "n_tp", "gemm_nonsplit_ns",
                "baseline", "te", "flux", "flux_config",
            ] {
                assert!(e.opt(k).is_some(), "missing key {k}");
            }
            let fx = e.get("flux").unwrap();
            assert!(fx.get("p50_ns").unwrap().as_f64().unwrap() > 0.0);
            assert!(
                fx.get("p95_ns").unwrap().as_f64().unwrap()
                    >= fx.get("p50_ns").unwrap().as_f64().unwrap()
            );
            assert!(fx.get("tiles_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
        // The additive engine-throughput section: deterministic keys
        // only (no wall_ns / events_per_sec in the base document).
        let eps = parsed.get("events_per_sec").unwrap();
        assert_eq!(eps.get("workload").unwrap().as_str().unwrap(), "hold");
        let cells = eps.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.get("resident").unwrap().as_usize().unwrap(), 4096);
        assert!(c.get("pops").unwrap().as_usize().unwrap() > 0);
        assert!(c.opt("wall_ns").is_none());
        assert!(c.opt("events_per_sec").is_none());
        assert!(eps.opt("events_per_sec").is_none());
        // The additive fleet section: deterministic keys only.
        let fl = parsed.get("fleet").unwrap();
        assert_eq!(fl.get("workload").unwrap().as_str().unwrap(), "hold");
        let cells = fl.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1, "quick mode runs dp64 only");
        let c = &cells[0];
        assert_eq!(c.get("dp").unwrap().as_usize().unwrap(), 64);
        assert_eq!(
            c.get("resident").unwrap().as_usize().unwrap(),
            64 * FLEET_EVENTS_PER_REPLICA
        );
        assert!(c.opt("wall_ns").is_none());
        assert!(c.opt("events_per_sec").is_none());
        let scale = fl.get("scale").unwrap().as_arr().unwrap();
        assert_eq!(scale.len(), 1);
        // Quick serving preset: 8 requests per replica at dp64.
        assert_eq!(
            scale[0].get("completed").unwrap().as_usize().unwrap(),
            512
        );
    }

    #[test]
    fn fleet_section_quick_skips_dp256() {
        let fl = fleet_doc(true, false, &Runner::with_threads(1));
        let cells = fl.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.get("dp").unwrap().as_usize().unwrap(), 64);
        assert_eq!(
            c.get("ops").unwrap().as_usize().unwrap(),
            FLEET_OPS_QUICK
        );
        // Pop-then-schedule keeps the pending population pinned at the
        // resident size, so the slab never outgrows it.
        assert_eq!(
            c.get("slab_high_water").unwrap().as_usize().unwrap(),
            16384
        );
        assert!(c.get("pops").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn fleet_section_full_completes_dp256_quick_scale_cell() {
        let fl = fleet_doc(false, false, &Runner::with_threads(2));
        let cells = fl.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        let c = &cells[1];
        assert_eq!(c.get("dp").unwrap().as_usize().unwrap(), 256);
        assert_eq!(
            c.get("resident").unwrap().as_usize().unwrap(),
            65536
        );
        assert_eq!(
            c.get("slab_high_water").unwrap().as_usize().unwrap(),
            65536
        );
        let scale = fl.get("scale").unwrap().as_arr().unwrap();
        assert_eq!(scale.len(), 2);
        let s = &scale[1];
        assert_eq!(s.get("dp").unwrap().as_usize().unwrap(), 256);
        // The acceptance bar: a dp256 pool completes the quick-scale
        // serving cell (256 replicas x 8 requests each).
        assert_eq!(s.get("completed").unwrap().as_usize().unwrap(), 2048);
        assert!(s.get("makespan_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn fleet_wall_mode_reports_throughput() {
        let fl = fleet_doc(true, true, &Runner::with_threads(1));
        for c in fl.get("cells").unwrap().as_arr().unwrap() {
            assert!(c.get("wall_ns").unwrap().as_f64().unwrap() > 0.0);
            assert!(
                c.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0
            );
        }
    }

    #[test]
    fn wall_section_reports_throughput_and_heap_comparison() {
        let doc = events_per_sec_doc(true, true, &Runner::with_threads(1));
        let eps = doc.get("events_per_sec").unwrap().as_f64().unwrap();
        assert!(eps > 0.0, "events_per_sec must be positive: {eps}");
        let heap =
            doc.get("heap_events_per_sec").unwrap().as_f64().unwrap();
        assert!(heap > 0.0);
        let speedup = doc.get("speedup_vs_heap").unwrap().as_f64().unwrap();
        assert!(speedup > 0.0);
        for c in doc.get("cells").unwrap().as_arr().unwrap() {
            assert!(c.get("wall_ns").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn print_bench_renders_without_error() {
        print_bench(&bench_doc(true)).unwrap();
    }
}
