//! The event-driven training document (`flux simulate --train --json`,
//! schema `flux-train-v1`): every selected topology under the
//! scenario's method set (default: the Megatron-LM, TransformerEngine
//! and Flux executions of the 1F1B step), executed by the
//! [`crate::exp::Runner`] at (topology, method) grain — plus one
//! comm-free ideal-floor cell per topology — and merged in registry
//! order, byte-identical at any worker count.

use anyhow::{ensure, Context, Result};

use crate::cost::arch::TrainTopology;
use crate::exp::{Mode, Runner, Scenario};
use crate::overlap::Method;
use crate::parallel::schedule;
use crate::training::{
    ideal_step_ns, overlap_efficiency_vs_ideal, run_train, TrainRun,
    TrainScenario,
};
use crate::util::json::{obj, Json};

use super::TRAIN_SCHEMA;

/// One topology's entry: the scenario plan, one block per method
/// (keyed by [`Method::train_label`]), Eq. 2 against the precomputed
/// comm-free ideal, and the comparative speedups when flux (and TE)
/// are in the set.
fn train_entry(
    sc: &TrainScenario,
    methods: &[Method],
    runs: &[TrainRun],
    ideal: f64,
) -> Result<Json> {
    let topo = sc.topo;
    let base = methods
        .iter()
        .position(|&m| m == Method::NonOverlap)
        .context("train scenarios always include the baseline method")?;
    let base_step = runs[base].step_ns;
    let method_json = |r: &TrainRun| {
        obj(vec![
            ("step_ns", Json::from(r.step_ns)),
            ("analytic_ns", Json::from(r.analytic_ns)),
            ("pipe_ns", Json::from(r.pipe_ns)),
            (
                "bubble_fraction_pct",
                Json::from(r.bubble_fraction * 100.0),
            ),
            ("dp_exposed_ns", Json::from(r.dp_exposed_ns)),
            ("opt_ns", Json::from(r.opt_ns)),
            (
                "overlap_eff_pct",
                Json::from(
                    overlap_efficiency_vs_ideal(
                        base_step, r.step_ns, ideal,
                    ) * 100.0,
                ),
            ),
            (
                "des_vs_analytic",
                Json::from(r.step_ns / r.analytic_ns),
            ),
            ("events", Json::from(r.events)),
        ])
    };
    let mut fields = vec![
        ("topology", Json::from(topo.name)),
        ("cluster", Json::from(topo.cluster.name)),
        ("dp", Json::from(topo.dp)),
        ("pp", Json::from(topo.pp)),
        ("tp", Json::from(topo.tp)),
        ("gpus", Json::from(topo.gpus())),
        ("microbatches", Json::from(sc.microbatches)),
        ("micro_tokens", Json::from(sc.micro_tokens)),
        ("seq", Json::from(sc.seq)),
        ("seed", Json::from(sc.seed as usize)),
        (
            "bubble_analytic_pct",
            Json::from(
                schedule::bubble_fraction(topo.pp, sc.microbatches)
                    * 100.0,
            ),
        ),
        ("ideal_step_ns", Json::from(ideal)),
    ];
    for (m, r) in methods.iter().zip(runs) {
        fields.push((m.train_label(), method_json(r)));
    }
    if let Some(fx) = methods.iter().position(|&m| m == Method::Flux) {
        fields.push((
            "speedup",
            Json::from(base_step / runs[fx].step_ns),
        ));
        if let Some(te) =
            methods.iter().position(|&m| m == Method::Medium)
        {
            fields.push((
                "speedup_vs_te",
                Json::from(runs[te].step_ns / runs[fx].step_ns),
            ));
        }
    }
    Ok(obj(fields))
}

/// The training document for one scenario, cells executed by `runner`
/// at (topology, method) grain so even a single-topology run spreads
/// its method set (and ideal floor) across workers.
pub fn train_doc_scenario(sc: &Scenario, runner: &Runner) -> Result<Json> {
    ensure!(sc.mode == Mode::Train, "not a train scenario");
    let methods = sc.method_set();
    let cells = sc.train_cells()?;
    let runs: Vec<Vec<TrainRun>> = runner.run_product(
        &cells,
        &methods,
        |tc, &m| run_train(tc, m),
    )?;
    let ideals: Vec<f64> = runner.run_matrix(&cells, ideal_step_ns)?;
    let mut topologies = Vec::new();
    for ((tc, cell_runs), ideal) in
        cells.iter().zip(&runs).zip(&ideals)
    {
        topologies.push(train_entry(tc, &methods, cell_runs, *ideal)?);
    }
    let mut top = vec![
        ("schema", Json::from(TRAIN_SCHEMA)),
        ("quick", Json::from(sc.quick)),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("topologies", Json::Arr(topologies)),
    ];
    if let Some(names) = sc.topo_filter_names()? {
        // Same contract as the scale doc: a filtered report must be
        // distinguishable from a full sweep when diffing trajectories.
        top.push(("topo_filter", super::topo_filter_json(&names)));
    }
    if !sc.name.is_empty() {
        top.push(("scenario", Json::from(sc.name.as_str())));
    }
    Ok(obj(top))
}

/// The training document: every topology in `ALL_TRAIN_TOPOLOGIES`
/// under the Megatron-LM (non-overlap), TransformerEngine and Flux
/// executions of the 1F1B step. Deterministic for a given `quick`.
pub fn train_doc(quick: bool) -> Result<Json> {
    train_doc_for(quick, None)
}

/// Like [`train_doc`], restricted to one topology when `only` is set
/// (`flux simulate --train --topo <name>`).
pub fn train_doc_for(
    quick: bool,
    only: Option<&'static TrainTopology>,
) -> Result<Json> {
    train_doc_scenario(&Scenario::train(only, quick), &Runner::new())
}

/// Human-readable rendering of the training document.
pub fn print_train(doc: &Json) -> Result<()> {
    fn ms(j: &Json, k: &str) -> Result<String> {
        Ok(format!("{:.1}", j.get(k)?.as_f64()? / 1e6))
    }
    let mut rows = Vec::new();
    for e in doc.get("topologies")?.as_arr()? {
        let fx = e.get("flux")?;
        rows.push(vec![
            e.get("topology")?.as_str()?.to_string(),
            format!(
                "{}x{}x{}",
                e.get("dp")?.as_usize()?,
                e.get("pp")?.as_usize()?,
                e.get("tp")?.as_usize()?
            ),
            ms(e.get("megatron")?, "step_ns")?,
            ms(e.get("te")?, "step_ns")?,
            ms(fx, "step_ns")?,
            format!(
                "{:.1}%",
                fx.get("bubble_fraction_pct")?.as_f64()?
            ),
            format!("{:.1}%", fx.get("overlap_eff_pct")?.as_f64()?),
            ms(fx, "dp_exposed_ns")?,
            format!("{:.2}x", e.get("speedup")?.as_f64()?),
            format!("{:.2}x", e.get("speedup_vs_te")?.as_f64()?),
        ]);
    }
    crate::util::bench::table(
        "training at scale (event-driven 1F1B, flux vs Megatron-LM/TE)",
        &[
            "topology",
            "dp x pp x tp",
            "megatron ms",
            "TE ms",
            "flux ms",
            "bubble",
            "flux eff",
            "dp tail ms",
            "vs megatron",
            "vs TE",
        ],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::ALL_TRAIN_TOPOLOGIES;

    #[test]
    fn train_doc_is_byte_stable_and_well_formed() {
        let a = train_doc(true).unwrap().to_string();
        let b = train_doc(true).unwrap().to_string();
        assert_eq!(a, b, "train doc must be deterministic");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            TRAIN_SCHEMA
        );
        let topos = doc.get("topologies").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), ALL_TRAIN_TOPOLOGIES.len());
        for t in topos {
            for k in [
                "topology", "cluster", "dp", "pp", "tp", "gpus",
                "microbatches", "megatron", "te", "flux", "speedup",
                "speedup_vs_te", "ideal_step_ns",
            ] {
                assert!(t.opt(k).is_some(), "missing key {k}");
            }
            let fx = t.get("flux").unwrap();
            let step = fx.get("step_ns").unwrap().as_f64().unwrap();
            let pipe = fx.get("pipe_ns").unwrap().as_f64().unwrap();
            assert!(step > pipe && pipe > 0.0);
            let bubble = fx
                .get("bubble_fraction_pct")
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(bubble > 0.0 && bubble < 100.0);
            assert!(
                t.get("speedup").unwrap().as_f64().unwrap() > 1.0,
                "flux must beat megatron on {}",
                t.get("topology").unwrap().as_str().unwrap()
            );
        }
    }

    #[test]
    fn train_doc_topo_filter_marks_the_document() {
        use crate::cost::arch::TRAIN_NVLINK_128;
        let doc = train_doc_for(true, Some(&TRAIN_NVLINK_128)).unwrap();
        assert_eq!(
            doc.get("topo_filter").unwrap().as_str().unwrap(),
            TRAIN_NVLINK_128.name
        );
        assert_eq!(
            doc.get("topologies").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn print_train_renders_without_error() {
        print_train(&train_doc(true).unwrap()).unwrap();
    }
}
