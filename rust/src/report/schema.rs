//! Self-describing schema dumps: `flux schema <name>` prints a typed
//! field catalog for any registered report schema (à la
//! cargo-dist-schema's typed JSON reports — the remaining half of
//! ROADMAP item 5's tooling).
//!
//! The catalogs are hand-authored against the emitters; the registry
//! test pins that every [`super::SCHEMAS`] entry has one, and the CLI
//! smoke test exercises the command surface. Field paths use `[]` for
//! array elements (`topologies[].speedup`).

use anyhow::{bail, Result};

use crate::util::json::{obj, Json};

/// One documented field of a report schema.
struct Field {
    /// Dotted path from the document root; `[]` marks array elements.
    path: &'static str,
    /// JSON type: `string`, `number`, `bool`, `object`, `array[...]`.
    ty: &'static str,
    doc: &'static str,
}

const fn f(
    path: &'static str,
    ty: &'static str,
    doc: &'static str,
) -> Field {
    Field { path, ty, doc }
}

const COMMON: [Field; 2] = [
    f("schema", "string", "schema name + version of this document"),
    f("quick", "bool", "true when run with the trimmed quick sweep"),
];

const BENCH_FIELDS: [Field; 9] = [
    f("model", "string", "transformer config the op shapes come from"),
    f("suite", "array[object]", "one cell per (cluster, op, m) point"),
    f("suite[].cluster", "string", "GPU cluster the cell is costed on"),
    f("suite[].op", "string", "fused op under test (ag_gemm/gemm_rs)"),
    f(
        "suite[].flux.overlap_eff_pct",
        "number",
        "Eq. 2 overlap efficiency of the tuned flux kernel, percent",
    ),
    f(
        "events_per_sec",
        "object",
        "DES engine hold-workload throughput section (deterministic \
         counters; wall-clock only under --wall)",
    ),
    f(
        "events_per_sec.cells[].checksum",
        "string",
        "order-sensitive event-stream checksum (determinism witness)",
    ),
    f(
        "fleet",
        "object",
        "fleet-scale section: hold cells on the parametric dpN pools \
         (dp64; + dp256 in full mode) plus a quick-scale serving cell \
         per pool; wall-clock throughput only under --wall",
    ),
    f(
        "fleet.cells[].slab_high_water",
        "number",
        "peak event-slab population of the cell's calendar queue",
    ),
];

const SCALE_FIELDS: [Field; 10] = [
    f("model", "string", "transformer config being served"),
    f("topologies", "array[object]", "one cell per serving topology"),
    f("topologies[].topology", "string", "topology registry name"),
    f("topologies[].workload", "object", "resolved workload spec"),
    f(
        "topologies[].speedup",
        "number",
        "decoupled/flux makespan ratio (throughput speedup)",
    ),
    f(
        "topologies[].<method>",
        "object",
        "per-method block (decoupled/medium/flux): completed, tokens, \
         makespan_ns, ttft_ns, per_token_ns, latency_ns, slo",
    ),
    f(
        "topologies[].<method>.ttft_ns",
        "object",
        "time-to-first-token percentiles p50/p95/p99, ns",
    ),
    f(
        "topologies[].<method>.ttft_ns_sketch",
        "object",
        "fixed-boundary sketch twin of ttft_ns (also per_token_ns/\
         latency_ns); present only under percentiles: \"sketch\"",
    ),
    f("topo_filter", "string|array", "present when --topo filtered"),
    f("scenario", "string", "present when run from a scenario file"),
];

const TRAIN_FIELDS: [Field; 7] = [
    f("model", "string", "transformer config being trained"),
    f("topologies", "array[object]", "one cell per train topology"),
    f("topologies[].gpus", "number", "dp * pp * tp GPUs in the cell"),
    f(
        "topologies[].<method>",
        "object",
        "per-method block (megatron/te/flux): step_ns, pipe_ns, \
         bubble_fraction, dp_exposed_ns, overlap_eff_pct, events",
    ),
    f("topologies[].<method>.step_ns", "number", "event-driven 1F1B step time, ns"),
    f("topologies[].speedup", "number", "megatron/flux step-time ratio"),
    f(
        "topologies[].ideal_step_ns",
        "number",
        "communication-free floor (Eq. 2 denominator)",
    ),
];

const SWEEP_FIELDS: [Field; 4] = [
    f("model", "string", "transformer config being served"),
    f("presets", "array[object]", "one block per workload preset"),
    f("presets[].workload", "object", "the preset's resolved spec"),
    f(
        "presets[].topologies[].speedup",
        "number",
        "decoupled/flux makespan ratio on that topology",
    ),
];

const CHURN_FIELDS: [Field; 6] = [
    f("faults", "object", "the expanded fault spec (seed included)"),
    f("topologies", "array[object]", "one cell per topology"),
    f(
        "topologies[].<method>.curve",
        "array[object]",
        "degradation curve: one point per fault intensity",
    ),
    f(
        "topologies[].<method>.curve[].intensity",
        "number",
        "fault-spec intensity knob (0 = fault-free replay)",
    ),
    f(
        "topologies[].<method>.curve[].goodput",
        "number",
        "SLO-attained goodput at that intensity (serve mode)",
    ),
    f(
        "topologies[].<method>.slowdown",
        "number",
        "step-time inflation at max intensity (train mode)",
    ),
];

const METRICS_FIELDS: [Field; 10] = [
    f("mode", "string", "serve or train"),
    f("scenario", "string", "present when run from a scenario file"),
    f(
        "cells",
        "array[object]",
        "one registry per (topology, method) observed run, in \
         scenario cell × method-registry order",
    ),
    f("cells[].method", "string", "overlap method key of the run"),
    f("cells[].topology", "string", "topology registry name"),
    f(
        "cells[].counters",
        "array[object]",
        "monotone counters {metric, labels, value}, sorted by \
         (metric, labels)",
    ),
    f(
        "cells[].gauges",
        "array[object]",
        "last-value gauges {metric, labels, value}",
    ),
    f(
        "cells[].histograms",
        "array[object]",
        "fixed-bucket histograms {metric, labels, bounds, counts, \
         sum, total}; counts has one overflow bucket past bounds",
    ),
    f(
        "cells[].markers",
        "array[object]",
        "instant fault markers {name, labels, t} in record order",
    ),
    f(
        "cells[].series",
        "array[object]",
        "sampled time series {metric, labels, points:[[t_ns, v]...]} \
         sorted by (metric, labels, t); seeded ~10 ms virtual cadence",
    ),
];

fn fields_for(name: &str) -> Option<&'static [Field]> {
    Some(match name {
        super::SCHEMA => &BENCH_FIELDS,
        super::SCALE_SCHEMA => &SCALE_FIELDS,
        super::TRAIN_SCHEMA => &TRAIN_FIELDS,
        super::SWEEP_SCHEMA => &SWEEP_FIELDS,
        super::CHURN_SCHEMA => &CHURN_FIELDS,
        super::METRICS_SCHEMA => &METRICS_FIELDS,
        _ => return None,
    })
}

/// The typed dump of one registered schema, as a byte-stable JSON
/// document: registry metadata plus the field catalog (common fields
/// first, then schema-specific ones, in catalog order).
pub fn schema_dump(name: &str) -> Result<Json> {
    let info = super::SCHEMAS.iter().find(|s| s.name == name);
    let (Some(info), Some(fields)) = (info, fields_for(name)) else {
        let known: Vec<&str> =
            super::SCHEMAS.iter().map(|s| s.name).collect();
        bail!("unknown schema {name:?}; known: {}", known.join(", "));
    };
    let field_docs: Vec<Json> = COMMON
        .iter()
        .chain(fields.iter())
        .map(|fd| {
            obj(vec![
                ("doc", Json::from(fd.doc)),
                ("path", Json::from(fd.path)),
                ("type", Json::from(fd.ty)),
            ])
        })
        .collect();
    Ok(obj(vec![
        ("command", Json::from(info.command)),
        ("fields", Json::Arr(field_docs)),
        ("name", Json::from(info.name)),
        ("summary", Json::from(info.summary)),
    ]))
}

/// Human-readable rendering of [`schema_dump`] for the plain CLI path.
pub fn print_schema(name: &str) -> Result<()> {
    let doc = schema_dump(name)?;
    println!(
        "{} — {}",
        doc.get("name")?.as_str()?,
        doc.get("summary")?.as_str()?
    );
    println!("emitted by: {}", doc.get("command")?.as_str()?);
    println!();
    for fd in doc.get("fields")?.as_arr()? {
        println!(
            "  {:<44} {:<14} {}",
            fd.get("path")?.as_str()?,
            fd.get("type")?.as_str()?,
            fd.get("doc")?.as_str()?
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_schema_has_a_dump() {
        for s in crate::report::SCHEMAS {
            let doc = schema_dump(s.name).unwrap();
            assert_eq!(doc.get("name").unwrap().as_str().unwrap(), s.name);
            let fields = doc.get("fields").unwrap().as_arr().unwrap();
            assert!(
                fields.len() > COMMON.len(),
                "{}: needs schema-specific fields",
                s.name
            );
            for fd in fields {
                for key in ["path", "type", "doc"] {
                    assert!(
                        !fd.get(key)
                            .unwrap()
                            .as_str()
                            .unwrap()
                            .is_empty(),
                        "{}: empty {key}",
                        s.name
                    );
                }
            }
        }
    }

    #[test]
    fn dumps_are_byte_stable_and_unknown_names_are_pointed() {
        let a = schema_dump("flux-metrics-v1").unwrap().to_string();
        assert_eq!(a, schema_dump("flux-metrics-v1").unwrap().to_string());
        assert!(a.contains("cells[].series"));
        let err =
            format!("{:#}", schema_dump("flux-imaginary-v9").unwrap_err());
        assert!(
            err.contains("flux-imaginary-v9")
                && err.contains("flux-bench-v1"),
            "pointed error with the known list: {err}"
        );
    }
}
