//! The workload-sweep document (`flux sweep-workloads --json`, schema
//! `flux-sweep-v1`): every built-in preset
//! ([`crate::workload::all_presets`]) on every
//! [`crate::cost::arch::ALL_SCALE_TOPOLOGIES`] entry, flux vs
//! decoupled — the matrix that shows where the speedup and goodput
//! gaps diverge (burst backlog widens them, closed-loop think pauses
//! compress them, the H800 narrow-store cliff turns decode-heavy cells
//! against Flux).
//!
//! The whole preset x topology x method matrix is flattened into one
//! [`crate::exp::Runner`] job list, so a slow preset never serializes
//! behind a fast one and `--quick` wall time drops roughly with core
//! count; the merge is in fixed preset-then-topology order, so the
//! document stays byte-identical at any worker count.

use anyhow::Result;

use crate::cost::arch::ALL_SCALE_TOPOLOGIES;
use crate::exp::Runner;
use crate::overlap::Method;
use crate::serving::scale::ScaleScenario;
use crate::util::json::{obj, Json};

use super::scale::scale_entries;
use super::SWEEP_SCHEMA;

/// Build the sweep document with the default runner. Deterministic
/// for a given `quick`, same byte-stability contract as
/// [`super::bench_doc`].
pub fn sweep_doc(quick: bool) -> Result<Json> {
    sweep_doc_with(quick, &Runner::new())
}

/// Like [`sweep_doc`], with the cell matrix executed by `runner`.
pub fn sweep_doc_with(quick: bool, runner: &Runner) -> Result<Json> {
    let presets = crate::workload::all_presets(quick);
    // Flatten the matrix: preset-major, topology-minor — the order the
    // document has always emitted.
    let mut cells: Vec<ScaleScenario> = Vec::new();
    for wl in &presets {
        for topo in ALL_SCALE_TOPOLOGIES {
            cells.push(ScaleScenario::with_workload(topo, wl.clone()));
        }
    }
    let entries =
        scale_entries(&cells, &Method::SERVE_SET, runner)?;
    let per_preset = ALL_SCALE_TOPOLOGIES.len();
    let preset_docs: Vec<Json> = presets
        .iter()
        .zip(entries.chunks(per_preset))
        .map(|(wl, topologies)| {
            obj(vec![
                ("name", Json::from(wl.name.as_str())),
                ("workload", wl.to_json()),
                ("topologies", Json::Arr(topologies.to_vec())),
            ])
        })
        .collect();
    Ok(obj(vec![
        ("schema", Json::from(SWEEP_SCHEMA)),
        ("quick", Json::from(quick)),
        ("model", Json::from(crate::model::configs::GPT3_175B.name)),
        ("presets", Json::Arr(preset_docs)),
    ]))
}

/// Human-readable rendering of the sweep document.
pub fn print_sweep(doc: &Json) -> Result<()> {
    let mut rows = Vec::new();
    for p in doc.get("presets")?.as_arr()? {
        let name = p.get("name")?.as_str()?;
        for e in p.get("topologies")?.as_arr()? {
            let fx = e.get("flux")?;
            let de = e.get("decoupled")?;
            let goodput = |m: &Json| -> String {
                match m.opt("slo") {
                    Some(s) => s
                        .get("goodput")
                        .and_then(|g| g.as_f64())
                        .map(|g| format!("{:.0}%", g * 100.0))
                        .unwrap_or_else(|_| "-".to_string()),
                    None => "-".to_string(),
                }
            };
            rows.push(vec![
                name.to_string(),
                e.get("topology")?.as_str()?.to_string(),
                format!(
                    "{:.1}",
                    fx.get("ttft_ns")?.get("p99_ns")?.as_f64()? / 1e6
                ),
                format!("{:.1}", fx.get("tokens_per_sec")?.as_f64()?),
                goodput(fx),
                goodput(de),
                format!("{:.2}x", e.get("speedup")?.as_f64()?),
                format!(
                    "{:.2}x",
                    e.get("latency_speedup")?.as_f64()?
                ),
            ]);
        }
    }
    crate::util::bench::table(
        "workload sweep (presets x topologies, flux vs decoupled)",
        &[
            "workload",
            "topology",
            "ttft p99 ms",
            "flux tok/s",
            "flux goodput",
            "dec goodput",
            "speedup",
            "lat speedup",
        ],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_doc_is_byte_stable_and_covers_the_matrix() {
        let a = sweep_doc(true).unwrap().to_string();
        let b = sweep_doc(true).unwrap().to_string();
        assert_eq!(a, b, "sweep doc must be deterministic");
        let doc = Json::parse(&a).unwrap();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            SWEEP_SCHEMA
        );
        let presets = doc.get("presets").unwrap().as_arr().unwrap();
        assert_eq!(presets.len(), crate::workload::PRESET_NAMES.len());
        for (p, name) in
            presets.iter().zip(crate::workload::PRESET_NAMES)
        {
            assert_eq!(p.get("name").unwrap().as_str().unwrap(), name);
            let topos = p.get("topologies").unwrap().as_arr().unwrap();
            assert_eq!(topos.len(), ALL_SCALE_TOPOLOGIES.len());
            for t in topos {
                let speedup =
                    t.get("speedup").unwrap().as_f64().unwrap();
                let nvlink = t
                    .get("cluster")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .contains("NVLink");
                // The acceptance bar: flux >= decoupled end to end on
                // every NVLink topology, for every preset.
                if nvlink {
                    assert!(
                        speedup >= 1.0,
                        "{name} on {}: speedup {speedup}",
                        t.get("topology").unwrap().as_str().unwrap()
                    );
                }
                // Goodput: flux meets at least as many SLOs as the
                // decoupled execution, everywhere.
                let goodput = |m: &Json| {
                    m.get("slo")
                        .unwrap()
                        .get("goodput")
                        .unwrap()
                        .as_f64()
                        .unwrap()
                };
                let gfx = goodput(t.get("flux").unwrap());
                let gde = goodput(t.get("decoupled").unwrap());
                assert!(
                    gfx >= gde,
                    "{name} on {}: flux goodput {gfx} < decoupled {gde}",
                    t.get("topology").unwrap().as_str().unwrap()
                );
            }
        }
        // The human rendering consumes the same document (checked here
        // rather than in its own test to avoid a third full sweep).
        print_sweep(&doc).unwrap();
    }
}
