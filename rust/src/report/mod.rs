//! Report emission: every `flux` JSON document behind one
//! schema-versioned, byte-stable writer.
//!
//! Each schema owns a (private) submodule — `bench`, `churn`, `scale`,
//! `sweep`, `train` — and this module holds what they share: the schema
//! registry, the `BENCH_<n>.json` trajectory path policy, the writer
//! with pointed path errors, and the [`Summary`] projections every
//! latency block uses.
//!
//! Two kinds of numbers, separated on purpose:
//!
//! * **Simulated** (default, always emitted): DES/op-suite runs with
//!   pinned `util::prng` seeds. Fully deterministic — two consecutive
//!   runs produce byte-identical files, *at any `--threads` count*
//!   (cells execute through [`crate::exp::Runner`] and merge in fixed
//!   scenario order) — so CI can diff them and regressions in the
//!   model are attributable to code changes, never to noise.
//! * **Wall-clock** (`flux bench --wall`, off by default): machine-
//!   dependent hotpath timings, excluded from the byte-stability
//!   contract and from CI diffing.
//!
//! Consumers must tolerate added keys; existing keys are stable.

mod bench;
mod churn;
mod scale;
mod schema;
mod sweep;
mod train;

pub use bench::{
    bench_doc, bench_doc_with, events_per_sec_doc, fleet_doc, print_bench,
    wall_doc, write_bench,
};
pub use churn::{churn_doc_scenario, print_churn, INTENSITIES};
pub use scale::{
    print_scale, scale_doc, scale_doc_for, scale_doc_scenario,
    scale_doc_with,
};
pub use schema::{print_schema, schema_dump};
pub use sweep::{print_sweep, sweep_doc, sweep_doc_with};
pub use train::{
    print_train, train_doc, train_doc_for, train_doc_scenario,
};

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Schema of the `flux bench --json` report.
pub const SCHEMA: &str = "flux-bench-v1";
/// Schema of the `flux simulate --scale --json` report. v2 folds in
/// the workload subsystem: a `workload` spec object per topology and
/// per-method `slo` goodput/abandonment accounting. Every v1 field is
/// preserved with identical values for the default Poisson workload
/// (the coordinator replays PR-2's PRNG draw sequence bit-for-bit;
/// `prompt`/`gen`/`arrival_mean_ns` remain emitted for fixed-mix
/// Poisson workloads).
pub const SCALE_SCHEMA: &str = "flux-scale-v2";
/// Schema of the `flux simulate --train --json` report.
pub const TRAIN_SCHEMA: &str = "flux-train-v1";
/// Schema of the `flux sweep-workloads --json` report: the workload
/// preset x topology matrix, flux vs decoupled.
pub const SWEEP_SCHEMA: &str = "flux-sweep-v1";
/// Schema of the `flux simulate --scale|--train --faults <spec>
/// --json` report: goodput / step-time degradation curves per method
/// x topology x fault intensity. Intensity 0 reproduces the
/// fault-free flux-scale-v2 / flux-train-v1 numbers bit-for-bit.
pub const CHURN_SCHEMA: &str = "flux-churn-v1";
/// Schema of the `flux simulate --scale|--train --metrics <path>` /
/// `flux scenario <file> --metrics <path>` telemetry document: per
/// (topology, method) cell, the deterministic counters / gauges /
/// histograms / fault markers / sampled time series recorded against
/// virtual DES time. Byte-stable at any `--threads`, like every other
/// schema.
pub const METRICS_SCHEMA: &str = "flux-metrics-v1";

/// One emitted schema, for `flux list` discoverability.
#[derive(Clone, Copy, Debug)]
pub struct SchemaInfo {
    pub name: &'static str,
    /// The invocation that emits it.
    pub command: &'static str,
    pub summary: &'static str,
}

/// Every document schema the CLI can emit, in trajectory order.
pub const SCHEMAS: [SchemaInfo; 6] = [
    SchemaInfo {
        name: SCHEMA,
        command: "flux bench --json",
        summary: "pinned-seed op suite (p50/p95, overlap eff, tiles/s)",
    },
    SchemaInfo {
        name: SCALE_SCHEMA,
        command: "flux simulate --scale --json",
        summary: "TP x DP serving sweep (TTFT/latency, goodput)",
    },
    SchemaInfo {
        name: TRAIN_SCHEMA,
        command: "flux simulate --train --json",
        summary: "event-driven 1F1B training sweep (step, bubble)",
    },
    SchemaInfo {
        name: SWEEP_SCHEMA,
        command: "flux sweep-workloads --json",
        summary: "workload preset x topology serving matrix",
    },
    SchemaInfo {
        name: CHURN_SCHEMA,
        command: "flux simulate --scale --faults <preset> --json",
        summary: "goodput/step-time degradation under seeded faults",
    },
    SchemaInfo {
        name: METRICS_SCHEMA,
        command: "flux simulate --scale|--train --metrics <path>",
        summary: "virtual-time telemetry: counters, gauges, series",
    },
];

/// p50/p95/p99 projection of a [`Summary`] — the latency blocks of the
/// scale and sweep documents. (One of three emitters that used to
/// hand-roll sorting/percentile math; all now sit on `util::stats`.)
pub(crate) fn latency_percentiles(s: &Summary) -> Json {
    obj(vec![
        ("p50_ns", Json::from(s.p50)),
        ("p95_ns", Json::from(s.p95)),
        ("p99_ns", Json::from(s.p99)),
    ])
}

/// The `topo_filter` compat shape shared by the scale and train
/// documents: a single name stays a string (the historical CLI form
/// the trajectory tooling reads), multiple names become an array.
pub(crate) fn topo_filter_json(names: &[&'static str]) -> Json {
    match names {
        [one] => Json::from(*one),
        many => {
            Json::Arr(many.iter().map(|&n| Json::from(n)).collect())
        }
    }
}

/// Full summary block (the wall-clock section).
pub(crate) fn summary_json(s: &Summary) -> Json {
    obj(vec![
        ("mean_ns", Json::from(s.mean)),
        ("p50_ns", Json::from(s.p50)),
        ("p95_ns", Json::from(s.p95)),
        ("p99_ns", Json::from(s.p99)),
        ("n", Json::from(s.n)),
    ])
}

/// Smallest-unused `BENCH_<n>.json` in `dir` — the perf trajectory is
/// an append-only sequence of these.
pub fn next_bench_path(dir: &Path) -> PathBuf {
    for n in 0..10_000usize {
        let p = dir.join(format!("BENCH_{n}.json"));
        if !p.exists() {
            return p;
        }
    }
    dir.join("BENCH_overflow.json")
}

/// Shared trajectory writer: resolve `out` (default: the next free
/// `BENCH_<n>.json`), create the parent dir, write the document. One
/// path policy for every report; failures name the offending path
/// (`util::fsio`).
pub fn write_doc(doc: &Json, out: Option<&Path>) -> Result<PathBuf> {
    let path = match out {
        Some(p) => p.to_path_buf(),
        None => next_bench_path(Path::new(".")),
    };
    crate::util::fsio::write_text(&path, &doc.to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_bench_path_skips_existing() {
        let dir = std::env::temp_dir().join("flux_bench_path_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(next_bench_path(&dir).ends_with("BENCH_0.json"));
        std::fs::write(dir.join("BENCH_0.json"), "{}").unwrap();
        assert!(next_bench_path(&dir).ends_with("BENCH_1.json"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_doc_errors_name_the_path() {
        // Regression (satellite): `--out` under a non-directory parent
        // must fail with the path, not a bare io error.
        let dir = std::env::temp_dir().join("flux_write_doc_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "x").unwrap();
        let bad = blocker.join("sub/report.json");
        let err = format!(
            "{:#}",
            write_doc(&Json::Null, Some(&bad)).unwrap_err()
        );
        assert!(err.contains("blocker"), "must name the path: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_registry_matches_the_constants() {
        let names: Vec<&str> = SCHEMAS.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            vec![
                SCHEMA,
                SCALE_SCHEMA,
                TRAIN_SCHEMA,
                SWEEP_SCHEMA,
                CHURN_SCHEMA,
                METRICS_SCHEMA
            ]
        );
        for s in SCHEMAS {
            assert!(!s.command.is_empty() && !s.summary.is_empty());
        }
    }
}
