//! Property-testing substrate (proptest is not in the vendored set).
//!
//! `forall` runs a property over `cases` seeded random cases and reports
//! the failing case's seed so it can be replayed deterministically:
//!
//! ```no_run
//! use flux::util::check::forall;
//! forall(64, 0xF00D, |rng| {
//!     let n = rng.range(1, 100);
//!     assert!(n < 100);
//! });
//! ```
//!
//! (`no_run`: doctest executables cannot locate libxla's bundled
//! libstdc++ without the workspace rpath; the property itself is
//! exercised by the unit tests below.)
//!
//! There is no shrinking; properties should draw *small* sizes so failing
//! cases are already readable. `FLUX_CHECK_CASES` scales case counts up
//! for soak runs.

use crate::util::prng::Rng;

/// Run `prop` over `cases` random cases derived from `seed`.
pub fn forall(cases: usize, seed: u64, prop: impl Fn(&mut Rng)) {
    let cases = std::env::var("FLUX_CHECK_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(cases);
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases} \
                 (replay seed: {case_seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(32, 1, |rng| {
            let a = rng.below(1000);
            let b = rng.below(1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn surfaces_failures() {
        forall(64, 2, |rng| {
            assert!(rng.below(10) != 3, "should eventually draw 3");
        });
    }
}
