//! Property-testing substrate (proptest is not in the vendored set).
//!
//! `forall` runs a property over `cases` seeded random cases and reports
//! the failing case's seed so it can be replayed deterministically:
//!
//! ```no_run
//! use flux::util::check::forall;
//! forall(64, 0xF00D, |rng| {
//!     let n = rng.range(1, 100);
//!     assert!(n < 100);
//! });
//! ```
//!
//! (`no_run`: doctest executables cannot locate libxla's bundled
//! libstdc++ without the workspace rpath; the property itself is
//! exercised by the unit tests below.)
//!
//! There is no shrinking; properties should draw *small* sizes so failing
//! cases are already readable. `FLUX_CHECK_CASES` scales case counts up
//! for soak runs.

use crate::util::prng::Rng;

/// The per-case replay seed: shared by [`forall`] and
/// `util::propcheck::forall_gen` so a printed seed reproduces the same
/// draw in either harness.
pub fn case_seed(seed: u64, case: usize) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(case as u64)
}

/// Case-count override for soak runs (`FLUX_CHECK_CASES=10000`).
pub fn case_count(default: usize) -> usize {
    std::env::var("FLUX_CHECK_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(default)
}

/// Run `prop` over `cases` random cases derived from `seed`.
pub fn forall(cases: usize, seed: u64, prop: impl Fn(&mut Rng)) {
    let cases = case_count(cases);
    for case in 0..cases {
        let case_seed = case_seed(seed, case);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases} \
                 (replay seed: {case_seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(32, 1, |rng| {
            let a = rng.below(1000);
            let b = rng.below(1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn surfaces_failures() {
        forall(64, 2, |rng| {
            assert!(rng.below(10) != 3, "should eventually draw 3");
        });
    }
}
