//! Minimal JSON substrate (parser + writer).
//!
//! No `serde` in the vendored crate set, so the artifact manifest,
//! cross-language goldens and report emission use this hand-rolled
//! implementation. Full JSON grammar (RFC 8259) minus `\u` surrogate
//! pairs outside the BMP, which our artifacts never contain.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
            }
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("not an integer: {x}");
        }
        Ok(x as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for report objects.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: re-decode from the raw slice.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow!("bad number {s:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_basic_values() {
        for s in [
            "null",
            "true",
            "false",
            "0",
            "-12.5",
            "\"hi\"",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "round trip {s}");
        }
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let t = r#"{"config": {"d_model": 256}, "weights":
                    {"l0.r0.w1": {"file": "weights/x.bin",
                                  "shape": [256, 256]}}}"#;
        let v = Json::parse(t).unwrap();
        assert_eq!(
            v.get("config").unwrap().get("d_model").unwrap()
                .as_usize().unwrap(),
            256
        );
        let shape = v
            .get("weights").unwrap()
            .get("l0.r0.w1").unwrap()
            .get("shape").unwrap()
            .usize_vec().unwrap();
        assert_eq!(shape, vec![256, 256]);
    }

    #[test]
    fn escapes_and_unescapes() {
        let v = Json::Str("a\"b\\c\nd\te".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
        // Raw multi-byte UTF-8 passes through.
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(
            Json::parse("1.5e3").unwrap().as_f64().unwrap(),
            1500.0
        );
    }
}
