//! Filesystem helpers with pointed error context.
//!
//! `--out` and `--trace` paths come straight from the command line, so
//! they routinely point at missing, read-only or non-directory parents.
//! A bare `io::Error` ("Not a directory (os error 20)") does not say
//! *which* path was bad; every writer in the crate goes through
//! [`write_text`] so the failure always names the offending path.

use std::path::Path;

use anyhow::{Context, Result};

/// Write `text` to `path`, creating missing parent directories. Both
/// failure modes (un-creatable parent, unwritable file) produce an
/// error naming the path.
pub fn write_text(path: &Path, text: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| {
                format!(
                    "creating parent directory {} for {}",
                    dir.display(),
                    path.display()
                )
            })?;
        }
    }
    std::fs::write(path, text)
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("flux_fsio_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn writes_and_creates_missing_parents() {
        let dir = tmp("ok");
        let path = dir.join("a/b/out.json");
        write_text(&path, "{}").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{}");
        // Existing parents are fine too (idempotent).
        write_text(&path, "[]").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "[]");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn error_names_the_offending_path() {
        // A parent that is a *file* cannot become a directory — the
        // error must name the path instead of surfacing a bare io code.
        let dir = tmp("err");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "x").unwrap();
        let bad = blocker.join("sub/out.json");
        let err = format!("{:#}", write_text(&bad, "{}").unwrap_err());
        assert!(
            err.contains("blocker") && err.contains("out.json"),
            "error must name the path: {err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
