//! Shared substrates: PRNG, JSON, statistics, bench harness, property
//! testing, CLI parsing. These exist in-tree because the offline build
//! has no rand/serde/criterion/proptest/clap.

pub mod bench;
pub mod check;
pub mod cli;
pub mod fsio;
pub mod json;
pub mod prng;
pub mod propcheck;
pub mod stats;
