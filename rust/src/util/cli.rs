//! Tiny CLI argument substrate (no `clap` offline).
//!
//! Supports `command --flag value --switch positional` shapes, which is
//! all the `flux` binary and the examples need.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `switch_names` lists flags that
    /// take no value; everything else starting with `--` consumes the
    /// next token as its value.
    pub fn parse(
        raw: impl IntoIterator<Item = String>,
        switch_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let val = it.next().ok_or_else(|| {
                        anyhow!("flag --{name} expects a value")
                    })?;
                    if val.starts_with("--") {
                        bail!("flag --{name} expects a value, got {val}");
                    }
                    out.flags.insert(name.to_string(), val);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env(switch_names: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), switch_names)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(
            v(&["serve", "--port", "8080", "--verbose", "extra"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("port", 0).unwrap(), 8080);
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(v(&["--port"]), &[]).is_err());
        assert!(Args::parse(v(&["--port", "--x", "1"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(v(&[]), &[]).unwrap();
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }
}
