//! Seeded generator combinators for property tests (no `proptest` in
//! the vendored set).
//!
//! [`crate::util::check::forall`] hands properties a bare [`Rng`];
//! this layer adds *generators* — plain `Fn(&mut Rng) -> T` closures —
//! so a property receives a structured, `Debug`-printable input, and
//! [`forall_gen`] can show **both** the reproducing seed and the exact
//! generated value on failure:
//!
//! ```no_run
//! use flux::util::propcheck::{forall_gen, usize_in, vec_of};
//! forall_gen(
//!     64,
//!     0xF00D,
//!     vec_of(usize_in(1, 10), usize_in(0, 100)),
//!     |xs| assert!(xs.iter().all(|&x| x < 100)),
//! );
//! ```
//!
//! (`no_run` for the same libxla-rpath reason as `util::check`.)
//!
//! Case seeds are shared with `check::forall` (`check::case_seed`), so
//! a printed seed replays the identical draw in either harness; there
//! is no shrinking — draw *small* sizes so failing cases read well.

use std::fmt::Debug;

use crate::util::check::{case_count, case_seed};
use crate::util::prng::Rng;

/// Uniform `usize` in `[lo, hi)`.
pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> usize {
    assert!(hi > lo, "empty range [{lo}, {hi})");
    move |rng| lo + rng.below((hi - lo) as u64) as usize
}

/// Uniform `f64` in `[lo, hi)`.
pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Rng) -> f64 {
    assert!(hi > lo && lo.is_finite() && hi.is_finite());
    move |rng| lo + (hi - lo) * rng.f64()
}

/// One of the given items, uniformly.
pub fn one_of<T: Clone>(items: Vec<T>) -> impl Fn(&mut Rng) -> T {
    assert!(!items.is_empty(), "one_of needs at least one item");
    move |rng| items[rng.below(items.len() as u64) as usize].clone()
}

/// A vector whose length and items are drawn from sub-generators.
pub fn vec_of<T>(
    len: impl Fn(&mut Rng) -> usize,
    item: impl Fn(&mut Rng) -> T,
) -> impl Fn(&mut Rng) -> Vec<T> {
    move |rng| {
        let n = len(rng);
        (0..n).map(|_| item(rng)).collect()
    }
}

/// Transform a generator's output.
pub fn map<A, B>(
    gen: impl Fn(&mut Rng) -> A,
    f: impl Fn(A) -> B,
) -> impl Fn(&mut Rng) -> B {
    move |rng| f(gen(rng))
}

/// Pair two generators (drawn left-to-right).
pub fn zip<A, B>(
    ga: impl Fn(&mut Rng) -> A,
    gb: impl Fn(&mut Rng) -> B,
) -> impl Fn(&mut Rng) -> (A, B) {
    move |rng| {
        let a = ga(rng);
        let b = gb(rng);
        (a, b)
    }
}

/// Run `prop` over `cases` inputs drawn from `gen`. On failure, prints
/// the replay seed *and* the generated input, then re-raises the
/// original panic. `FLUX_CHECK_CASES` scales case counts for soaks.
pub fn forall_gen<T: Debug>(
    cases: usize,
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T),
) {
    let cases = case_count(cases);
    for case in 0..cases {
        let case_seed = case_seed(seed, case);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&input)),
        );
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases} \
                 (replay seed: {case_seed:#x})\n  input: {input:?}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_stay_in_range_and_replay_by_seed() {
        let gen = zip(usize_in(3, 9), f64_in(-1.0, 1.0));
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..200 {
            let (n, x) = gen(&mut a);
            assert!((3..9).contains(&n));
            assert!((-1.0..1.0).contains(&x));
            assert_eq!((n, x), gen(&mut b), "same seed, same draw");
        }
    }

    #[test]
    fn vec_of_honours_the_length_generator() {
        let gen = vec_of(usize_in(2, 5), usize_in(0, 10));
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let v = gen(&mut rng);
            assert!((2..5).contains(&v.len()));
        }
    }

    #[test]
    fn map_and_one_of_compose() {
        let gen = map(one_of(vec![1usize, 2, 4]), |x| x * 8);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            assert!([8, 16, 32].contains(&gen(&mut rng)));
        }
    }

    #[test]
    fn forall_gen_passes_trivial_property() {
        forall_gen(
            32,
            1,
            vec_of(usize_in(0, 8), usize_in(0, 1000)),
            |xs| {
                let sum: usize = xs.iter().sum();
                assert!(sum <= 8 * 1000);
            },
        );
    }

    #[test]
    #[should_panic]
    fn forall_gen_surfaces_failures_with_input() {
        forall_gen(64, 2, usize_in(0, 10), |&x| {
            assert!(x != 3, "should eventually draw 3");
        });
    }

    #[test]
    fn shares_case_seeds_with_check_forall() {
        // A seed printed by either harness replays in the other: the
        // first draw of case 5 matches across entry points.
        let seed = case_seed(0xABCD, 5);
        let mut via_check = Rng::new(seed);
        let direct = usize_in(0, 1_000_000)(&mut Rng::new(seed));
        assert_eq!(direct, via_check.below(1_000_000) as usize);
    }
}
