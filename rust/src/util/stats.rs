//! Small statistics helpers shared by the bench harness, the simulator
//! reports and the serving metrics.

/// Summary statistics over a sample of f64 observations.
///
/// Convention: `std` is the **population** standard deviation (divide by
/// `n`, not `n - 1`). The samples summarized here — simulated latencies,
/// bench repetitions — are the *whole* population of a deterministic
/// run, not a draw from a larger one, so no Bessel correction is
/// applied. Callers reporting `std` next to the percentiles get the
/// same convention NumPy's default `np.std` uses.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population standard deviation (ddof = 0); see the struct docs.
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty, all-finite sample.
    ///
    /// Panics with a message naming the offending index/value if any
    /// sample is NaN or infinite: a non-finite observation is always an
    /// upstream accounting bug, and the old behavior (an opaque
    /// `partial_cmp().unwrap()` panic inside sort, or silently poisoned
    /// mean/std) hid where it came from. Callers with legitimately
    /// partial data (e.g. unfinished requests) must filter before
    /// summarizing.
    ///
    /// Implemented over [`Streaming`]; the accumulator is bit-identical
    /// to the old two-pass slice code by construction (see its docs), so
    /// every pinned report f64 survives the switch unchanged.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut acc = Streaming::with_capacity(xs.len());
        for &x in xs {
            acc.push(x);
        }
        acc.finalize()
    }
}

/// Streaming [`Summary`] accumulator: `push` observations one at a time,
/// `finalize` once at the end.
///
/// The running sum (→ mean) is accumulated online in push order —
/// float-identical to `xs.iter().sum::<f64>()` over a collected slice —
/// and min/max fall out of the final sort, so callers no longer build
/// their *own* sample `Vec` just to hand it to [`Summary::of`] (which
/// then cloned it again to sort): one buffer inside the accumulator
/// replaces two caller-side allocations per metric.
///
/// The buffer itself cannot be dropped: the schemas pin **exact**
/// linear-interpolated percentiles, and exact order statistics need the
/// whole sample. Constant space is available as the *opt-in* [`Sketch`]
/// (scenario `percentiles: "sketch"`), which surfaces as additive
/// `*_sketch` report fields precisely so the exact default — and every
/// pinned report byte — survives untouched. The variance pass runs over
/// the buffer in push order *before* sorting, exactly as the old code
/// read its input slice, so `std` is also bit-identical.
#[derive(Clone, Debug, Default)]
pub struct Streaming {
    sum: f64,
    buf: Vec<f64>,
}

impl Streaming {
    pub fn new() -> Streaming {
        Streaming { sum: 0.0, buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Streaming {
        Streaming { sum: 0.0, buf: Vec::with_capacity(n) }
    }

    /// Number of observations pushed so far.
    pub fn n(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Record one observation. Panics on NaN/infinite input, naming the
    /// value and its index — same contract as [`Summary::of`].
    pub fn push(&mut self, x: f64) {
        assert!(
            x.is_finite(),
            "non-finite sample {x} at index {}",
            self.buf.len()
        );
        self.sum += x;
        self.buf.push(x);
    }

    /// Consume the accumulator into a [`Summary`]. Panics if nothing was
    /// pushed.
    pub fn finalize(mut self) -> Summary {
        assert!(!self.buf.is_empty(), "Streaming::finalize on empty sample");
        let n = self.buf.len();
        let mean = self.sum / n as f64;
        let var = self
            .buf
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        self.buf.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: self.buf[0],
            p50: percentile(&self.buf, 0.50),
            p95: percentile(&self.buf, 0.95),
            p99: percentile(&self.buf, 0.99),
            max: self.buf[n - 1],
        }
    }
}

/// Percentile accounting mode of a serving cell: the scenario
/// `percentiles` key (`"exact"` | `"sketch"`). Exact buffers every
/// sample ([`Streaming`]); Sketch *additionally* folds each sample
/// into a constant-space [`Sketch`] whose bucketed percentiles ride
/// the report as additive `*_sketch` fields — the default stays exact
/// so every pinned report byte is untouched.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PercentileMode {
    #[default]
    Exact,
    Sketch,
}

impl PercentileMode {
    pub fn name(self) -> &'static str {
        match self {
            PercentileMode::Exact => "exact",
            PercentileMode::Sketch => "sketch",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<PercentileMode> {
        match name {
            "exact" => Ok(PercentileMode::Exact),
            "sketch" => Ok(PercentileMode::Sketch),
            _ => anyhow::bail!(
                "unknown percentile mode {name:?} (exact|sketch)"
            ),
        }
    }
}

/// Constant-space percentile sketch over fixed boundaries.
///
/// A deterministic fixed-boundary histogram (the caller supplies the
/// bucket upper bounds — in practice the power-of-4 ladder
/// `obs::LATENCY_BOUNDS_NS`): `observe` is O(log buckets) and the
/// memory is O(buckets) no matter how many samples stream through,
/// which is what makes million-request fleet runs summarizable without
/// buffering every latency. `n`/`mean`/`min`/`max` stay exact
/// (streamed scalars); only the percentile fields are bucketed, each
/// linearly interpolated inside the bucket holding its rank — so a
/// sketch percentile lands within the bucket that contains the exact
/// order statistic (one bucket width of the exact value when the
/// neighboring order statistics share a bucket; `tests/prop.rs` pins
/// the differential bound on seeded samples).
///
/// No randomness, no data-dependent resizing: two runs over the same
/// sample stream produce bit-identical estimates at any thread count,
/// same as every other number in the reports.
#[derive(Clone, Debug)]
pub struct Sketch {
    bounds: &'static [f64],
    /// `counts[i]` holds samples `<= bounds[i]`; the final slot is the
    /// overflow bucket (`> bounds[last]`).
    counts: Vec<u64>,
    n: usize,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

impl Sketch {
    /// Build over `bounds` (finite, strictly increasing upper bounds).
    pub fn new(bounds: &'static [f64]) -> Sketch {
        assert!(!bounds.is_empty(), "Sketch bounds must be non-empty");
        for w in bounds.windows(2) {
            assert!(
                w[0].is_finite() && w[1].is_finite() && w[0] < w[1],
                "Sketch bounds must be finite and strictly increasing"
            );
        }
        Sketch {
            bounds,
            counts: vec![0; bounds.len() + 1],
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Fold one observation in. Panics on NaN/infinite input — same
    /// contract as [`Streaming::push`].
    pub fn observe(&mut self, x: f64) {
        assert!(x.is_finite(), "non-finite sample {x} in Sketch");
        let i = self.bounds.partition_point(|&b| b < x);
        self.counts[i] += 1;
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// The `[lo, hi]` boundaries of the bucket `x` falls in, clamped
    /// to the observed `[min, max]` range at the edge buckets.
    pub fn bucket_of(&self, x: f64) -> (f64, f64) {
        let i = self.bounds.partition_point(|&b| b < x);
        let lo = if i == 0 { self.min } else { self.bounds[i - 1] };
        let hi = if i == self.bounds.len() {
            self.max
        } else {
            self.bounds[i]
        };
        (lo, hi)
    }

    /// Bucketed percentile estimate: locate the bucket containing the
    /// rank position `q * (n - 1)` (the exact [`percentile`]'s
    /// convention) and interpolate linearly inside its boundaries.
    pub fn percentile(&self, q: f64) -> f64 {
        assert!(self.n > 0, "Sketch::percentile on empty sample");
        assert!((0.0..=1.0).contains(&q));
        let pos = q * (self.n - 1) as f64;
        let mut before = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let after = before + c;
            if pos < after as f64 {
                let lo =
                    if i == 0 { self.min } else { self.bounds[i - 1] };
                let hi = if i == self.bounds.len() {
                    self.max
                } else {
                    self.bounds[i]
                };
                let t = (pos - before as f64) / c as f64;
                let est = lo + (hi - lo) * t;
                return est.clamp(self.min, self.max);
            }
            before = after;
        }
        self.max
    }

    /// Project into a [`Summary`]: exact `n`/`mean`/`min`/`max`,
    /// bucketed `p50`/`p95`/`p99`, sum-of-squares `std`. Panics if
    /// nothing was observed.
    pub fn summary(&self) -> Summary {
        assert!(self.n > 0, "Sketch::summary on empty sample");
        let mean = self.sum / self.n as f64;
        let var = (self.sumsq / self.n as f64 - mean * mean).max(0.0);
        Summary {
            n: self.n,
            mean,
            std: var.sqrt(),
            min: self.min,
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
            max: self.max,
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean — used for "average speedup over shapes" rows, matching
/// how the paper aggregates.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Pretty time: ns → human unit. All simulator times are ns (u64).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
    }

    #[test]
    fn std_is_population_not_sample() {
        // [2, 4]: population std = 1.0; the sample (ddof=1) convention
        // would give sqrt(2) ≈ 1.414. Pin the documented choice.
        let s = Summary::of(&[2.0, 4.0]);
        assert_eq!(s.std, 1.0);
    }

    #[test]
    #[should_panic(expected = "non-finite sample NaN at index 1")]
    fn rejects_nan_sample() {
        // The message must name the offending value and index.
        Summary::of(&[1.0, f64::NAN, 3.0]);
    }

    #[test]
    #[should_panic(expected = "non-finite sample")]
    fn rejects_infinite_sample() {
        Summary::of(&[1.0, f64::INFINITY]);
    }

    #[test]
    fn streaming_matches_collected_bit_for_bit() {
        // Same observations, push-at-a-time vs slice: every field equal
        // by `==` (not tolerance) — the accumulator must be a pure
        // refactor of the two-pass code.
        let xs: Vec<f64> = (0..257)
            .map(|i| ((i * 2654435761_u64 as usize) % 1000) as f64 * 0.37)
            .collect();
        let mut acc = Streaming::new();
        for &x in &xs {
            acc.push(x);
        }
        assert_eq!(acc.n(), xs.len());
        assert_eq!(acc.finalize(), Summary::of(&xs));
    }

    #[test]
    #[should_panic(expected = "non-finite sample inf at index 2")]
    fn streaming_rejects_non_finite_with_index() {
        let mut acc = Streaming::new();
        acc.push(1.0);
        acc.push(2.0);
        acc.push(f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn streaming_finalize_rejects_empty() {
        Streaming::new().finalize();
    }

    const POW4: [f64; 13] = [
        1.0e3, 4.0e3, 1.6e4, 6.4e4, 2.56e5, 1.024e6, 4.096e6, 1.6384e7,
        6.5536e7, 2.62144e8, 1.048576e9, 4.194304e9, 1.6777216e10,
    ];

    #[test]
    fn sketch_exact_scalars_and_bracketed_percentiles() {
        let mut sk = Sketch::new(&POW4);
        assert!(sk.is_empty());
        let xs: Vec<f64> =
            (1..=1000).map(|i| i as f64 * 1.7e4).collect();
        for &x in &xs {
            sk.observe(x);
        }
        assert_eq!(sk.n(), 1000);
        let s = sk.summary();
        let exact = Summary::of(&xs);
        // n/mean/min/max are exact; std within float noise of exact.
        assert_eq!(s.n, exact.n);
        assert_eq!(s.min, exact.min);
        assert_eq!(s.max, exact.max);
        assert!((s.mean - exact.mean).abs() < 1e-6 * exact.mean);
        assert!((s.std - exact.std).abs() < 1e-6 * exact.std);
        // Each sketch percentile lands inside the bucket containing
        // the exact order statistic.
        for (sp, ep) in [
            (s.p50, exact.p50),
            (s.p95, exact.p95),
            (s.p99, exact.p99),
        ] {
            let (lo, hi) = sk.bucket_of(ep);
            assert!(
                sp >= lo && sp <= hi,
                "sketch {sp} outside bucket [{lo}, {hi}] of exact {ep}"
            );
        }
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!(s.min <= s.p50 && s.p99 <= s.max);
    }

    #[test]
    fn sketch_is_deterministic_across_reruns() {
        let run = || {
            let mut sk = Sketch::new(&POW4);
            for i in 0..257u64 {
                sk.observe(((i * 2654435761) % 100_000) as f64 * 37.0);
            }
            let s = sk.summary();
            [s.mean, s.std, s.p50, s.p95, s.p99]
                .map(f64::to_bits)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn sketch_handles_out_of_range_samples() {
        // Below the first bound and above the last: edge buckets clamp
        // to the observed min/max.
        let mut sk = Sketch::new(&POW4);
        sk.observe(5.0);
        sk.observe(1.0e12);
        let s = sk.summary();
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 1.0e12);
        assert!(s.p50 >= 5.0 && s.p99 <= 1.0e12);
    }

    #[test]
    #[should_panic(expected = "non-finite sample")]
    fn sketch_rejects_nan() {
        Sketch::new(&POW4).observe(f64::NAN);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn sketch_summary_rejects_empty() {
        Sketch::new(&POW4).summary();
    }

    #[test]
    fn percentile_mode_round_trips() {
        for m in [PercentileMode::Exact, PercentileMode::Sketch] {
            assert_eq!(PercentileMode::from_name(m.name()).unwrap(), m);
        }
        assert_eq!(PercentileMode::default(), PercentileMode::Exact);
        assert!(PercentileMode::from_name("tdigest").is_err());
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.2e9).ends_with('s'));
    }
}
