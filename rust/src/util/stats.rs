//! Small statistics helpers shared by the bench harness, the simulator
//! reports and the serving metrics.

/// Summary statistics over a sample of f64 observations.
///
/// Convention: `std` is the **population** standard deviation (divide by
/// `n`, not `n - 1`). The samples summarized here — simulated latencies,
/// bench repetitions — are the *whole* population of a deterministic
/// run, not a draw from a larger one, so no Bessel correction is
/// applied. Callers reporting `std` next to the percentiles get the
/// same convention NumPy's default `np.std` uses.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population standard deviation (ddof = 0); see the struct docs.
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty, all-finite sample.
    ///
    /// Panics with a message naming the offending index/value if any
    /// sample is NaN or infinite: a non-finite observation is always an
    /// upstream accounting bug, and the old behavior (an opaque
    /// `partial_cmp().unwrap()` panic inside sort, or silently poisoned
    /// mean/std) hid where it came from. Callers with legitimately
    /// partial data (e.g. unfinished requests) must filter before
    /// summarizing.
    ///
    /// Implemented over [`Streaming`]; the accumulator is bit-identical
    /// to the old two-pass slice code by construction (see its docs), so
    /// every pinned report f64 survives the switch unchanged.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut acc = Streaming::with_capacity(xs.len());
        for &x in xs {
            acc.push(x);
        }
        acc.finalize()
    }
}

/// Streaming [`Summary`] accumulator: `push` observations one at a time,
/// `finalize` once at the end.
///
/// The running sum (→ mean) is accumulated online in push order —
/// float-identical to `xs.iter().sum::<f64>()` over a collected slice —
/// and min/max fall out of the final sort, so callers no longer build
/// their *own* sample `Vec` just to hand it to [`Summary::of`] (which
/// then cloned it again to sort): one buffer inside the accumulator
/// replaces two caller-side allocations per metric.
///
/// The buffer itself cannot be dropped: the schemas pin **exact**
/// linear-interpolated percentiles, and exact order statistics need the
/// whole sample (constant space would force an approximate sketch like
/// P²/t-digest, which would change pinned report bytes). The variance
/// pass runs over the buffer in push order *before* sorting, exactly as
/// the old code read its input slice, so `std` is also bit-identical.
#[derive(Clone, Debug, Default)]
pub struct Streaming {
    sum: f64,
    buf: Vec<f64>,
}

impl Streaming {
    pub fn new() -> Streaming {
        Streaming { sum: 0.0, buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Streaming {
        Streaming { sum: 0.0, buf: Vec::with_capacity(n) }
    }

    /// Number of observations pushed so far.
    pub fn n(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Record one observation. Panics on NaN/infinite input, naming the
    /// value and its index — same contract as [`Summary::of`].
    pub fn push(&mut self, x: f64) {
        assert!(
            x.is_finite(),
            "non-finite sample {x} at index {}",
            self.buf.len()
        );
        self.sum += x;
        self.buf.push(x);
    }

    /// Consume the accumulator into a [`Summary`]. Panics if nothing was
    /// pushed.
    pub fn finalize(mut self) -> Summary {
        assert!(!self.buf.is_empty(), "Streaming::finalize on empty sample");
        let n = self.buf.len();
        let mean = self.sum / n as f64;
        let var = self
            .buf
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        self.buf.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: self.buf[0],
            p50: percentile(&self.buf, 0.50),
            p95: percentile(&self.buf, 0.95),
            p99: percentile(&self.buf, 0.99),
            max: self.buf[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean — used for "average speedup over shapes" rows, matching
/// how the paper aggregates.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Pretty time: ns → human unit. All simulator times are ns (u64).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
    }

    #[test]
    fn std_is_population_not_sample() {
        // [2, 4]: population std = 1.0; the sample (ddof=1) convention
        // would give sqrt(2) ≈ 1.414. Pin the documented choice.
        let s = Summary::of(&[2.0, 4.0]);
        assert_eq!(s.std, 1.0);
    }

    #[test]
    #[should_panic(expected = "non-finite sample NaN at index 1")]
    fn rejects_nan_sample() {
        // The message must name the offending value and index.
        Summary::of(&[1.0, f64::NAN, 3.0]);
    }

    #[test]
    #[should_panic(expected = "non-finite sample")]
    fn rejects_infinite_sample() {
        Summary::of(&[1.0, f64::INFINITY]);
    }

    #[test]
    fn streaming_matches_collected_bit_for_bit() {
        // Same observations, push-at-a-time vs slice: every field equal
        // by `==` (not tolerance) — the accumulator must be a pure
        // refactor of the two-pass code.
        let xs: Vec<f64> = (0..257)
            .map(|i| ((i * 2654435761_u64 as usize) % 1000) as f64 * 0.37)
            .collect();
        let mut acc = Streaming::new();
        for &x in &xs {
            acc.push(x);
        }
        assert_eq!(acc.n(), xs.len());
        assert_eq!(acc.finalize(), Summary::of(&xs));
    }

    #[test]
    #[should_panic(expected = "non-finite sample inf at index 2")]
    fn streaming_rejects_non_finite_with_index() {
        let mut acc = Streaming::new();
        acc.push(1.0);
        acc.push(2.0);
        acc.push(f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn streaming_finalize_rejects_empty() {
        Streaming::new().finalize();
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.2e9).ends_with('s'));
    }
}
