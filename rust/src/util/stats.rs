//! Small statistics helpers shared by the bench harness, the simulator
//! reports and the serving metrics.

/// Summary statistics over a sample of f64 observations.
///
/// Convention: `std` is the **population** standard deviation (divide by
/// `n`, not `n - 1`). The samples summarized here — simulated latencies,
/// bench repetitions — are the *whole* population of a deterministic
/// run, not a draw from a larger one, so no Bessel correction is
/// applied. Callers reporting `std` next to the percentiles get the
/// same convention NumPy's default `np.std` uses.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population standard deviation (ddof = 0); see the struct docs.
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty, all-finite sample.
    ///
    /// Panics with a message naming the offending index/value if any
    /// sample is NaN or infinite: a non-finite observation is always an
    /// upstream accounting bug, and the old behavior (an opaque
    /// `partial_cmp().unwrap()` panic inside sort, or silently poisoned
    /// mean/std) hid where it came from. Callers with legitimately
    /// partial data (e.g. unfinished requests) must filter before
    /// summarizing.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        if let Some((i, x)) =
            xs.iter().enumerate().find(|(_, x)| !x.is_finite())
        {
            panic!("Summary::of: non-finite sample {x} at index {i}");
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            p99: percentile(&s, 0.99),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean — used for "average speedup over shapes" rows, matching
/// how the paper aggregates.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Pretty time: ns → human unit. All simulator times are ns (u64).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
    }

    #[test]
    fn std_is_population_not_sample() {
        // [2, 4]: population std = 1.0; the sample (ddof=1) convention
        // would give sqrt(2) ≈ 1.414. Pin the documented choice.
        let s = Summary::of(&[2.0, 4.0]);
        assert_eq!(s.std, 1.0);
    }

    #[test]
    #[should_panic(expected = "non-finite sample NaN at index 1")]
    fn rejects_nan_sample() {
        // The message must name the offending value and index.
        Summary::of(&[1.0, f64::NAN, 3.0]);
    }

    #[test]
    #[should_panic(expected = "non-finite sample")]
    fn rejects_infinite_sample() {
        Summary::of(&[1.0, f64::INFINITY]);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.2e9).ends_with('s'));
    }
}
