//! Criterion-style micro-bench harness substrate.
//!
//! The vendored crate set has no `criterion`; `cargo bench` targets use
//! this instead (they are `harness = false` binaries). It does warmup,
//! adaptive iteration-count selection, and prints a stable one-line
//! summary per benchmark plus any figure tables the bench emits.
//!
//! This module is also the repo's only sanctioned wall-clock source:
//! flux-lint rule D003 bans `Instant`/`SystemTime` everywhere else in
//! `rust/src`, so code that genuinely needs wall time (`--wall` report
//! sections, PJRT compile accounting, the serve loop) routes through
//! [`Stopwatch`]. Wall-clock numbers are machine-local and stay outside
//! the byte-stability contract.

// The clippy mirror of D003 (clippy.toml disallowed-methods) is
// file-allowed here for the same reason flux-lint allowlists this file.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use crate::util::stats::{fmt_ns, Summary};

/// Wall-clock stopwatch — the one `Instant` entry point outside this
/// module's bench harness (flux-lint rule D003). Keeping every caller
/// on this type makes the wall-clock surface greppable: a `Stopwatch`
/// reading may feed `--wall` report sections, throughput prints and
/// diagnostics, never a deterministic report field.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed wall time in f64 nanoseconds — the unit the `wall`
    /// report sections carry.
    pub fn elapsed_ns(&self) -> f64 {
        self.0.elapsed().as_nanos() as f64
    }
}

pub struct Bench {
    /// Target measurement time per benchmark.
    pub budget: Duration,
    /// Samples to collect within the budget.
    pub samples: usize,
    results: Vec<(String, Summary)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Honor the conventional quick-run env toggle.
        let quick = std::env::var("FLUX_BENCH_QUICK").is_ok();
        Bench {
            budget: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            samples: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, which should return something the optimizer cannot
    /// delete (use `std::hint::black_box` inside when in doubt).
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) {
        // Warmup + calibration: how many iters fit in budget/samples?
        let t0 = Instant::now();
        let mut iters_per_sample = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            let dt = t.elapsed();
            if dt >= self.budget / (self.samples as u32)
                || t0.elapsed() > self.budget
            {
                break;
            }
            iters_per_sample =
                iters_per_sample.saturating_mul(2).min(1 << 24);
        }

        let mut obs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            obs.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let s = Summary::of(&obs);
        println!(
            "bench {name:<44} {:>10}/iter  (p50 {:>10}, p99 {:>10}, n={} x{})",
            fmt_ns(s.mean),
            fmt_ns(s.p50),
            fmt_ns(s.p99),
            self.samples,
            iters_per_sample,
        );
        self.results.push((name.to_string(), s));
    }

    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }
}

/// Render a paper-style table: a header plus aligned rows. Used by every
/// fig* bench to print the series the paper reports.
pub fn table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> =
        header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(
            &header.iter().map(|s| s.to_string()).collect::<Vec<_>>()
        )
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("FLUX_BENCH_QUICK", "1");
        let mut b = Bench::new();
        b.budget = Duration::from_millis(20);
        b.samples = 5;
        b.run("noop-ish", || 1u64 + std::hint::black_box(2));
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].1.mean >= 0.0);
    }
}
