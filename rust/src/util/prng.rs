//! Deterministic PRNG substrate (splitmix64 + xoshiro256**).
//!
//! The vendored crate set has no `rand`; the simulator, the workload
//! generators and the property-test harness all need seeded, reproducible
//! randomness, so we carry our own. xoshiro256** is the reference
//! generator of Blackman & Vigna; splitmix64 seeds it (also per the
//! reference implementation).

/// splitmix64 step — used for seeding and as a cheap stateless hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire's method would be overkill here;
    /// modulo bias is negligible for our n ≪ 2^64).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with mean `mean` (Poisson inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-300);
        -mean * u.ln()
    }

    /// Log-normal jitter factor centered on 1.0 with sigma `s` — the shape
    /// of kernel-launch timing noise on a busy GPU node.
    pub fn jitter(&mut self, s: f64) -> f64 {
        (self.normal() * s).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill with standard-normal f32s (test-data generator).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b), "all residues should appear");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn jitter_positive_and_centered() {
        let mut r = Rng::new(17);
        let n = 10_000;
        let mean: f64 =
            (0..n).map(|_| r.jitter(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }
}
