//! SLO specification and attained-goodput evaluation.
//!
//! Serving papers compare TP communication strategies by *goodput* —
//! the fraction of requests that meet their latency deadlines — not
//! raw throughput, because under load a faster execution converts
//! queueing delay into met SLOs nonlinearly. Two deadlines per
//! request, the standard pair:
//!
//! * **TTFT** (`ttft_ns`): arrival to first token (prefill exposure);
//! * **per-token** (`per_token_ns`): mean inter-token decode latency.
//!
//! A request meets the SLO when it meets *both*. Requests whose TTFT
//! exceeds `abandon_ttft_ns` are counted as **abandoned** — the user
//! walked away, so every token they were served is wasted work. The
//! simulator still runs them to completion (abandonment accounting
//! must not perturb the execution being compared), it just books the
//! waste.

use anyhow::{bail, Result};

use crate::util::json::{obj, Json};

/// Per-request latency deadlines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token deadline, ns.
    pub ttft_ns: f64,
    /// Mean inter-token decode latency deadline, ns.
    pub per_token_ns: f64,
    /// TTFT beyond which the request counts as abandoned, ns.
    pub abandon_ttft_ns: f64,
}

impl SloSpec {
    pub fn validate(&self) -> Result<()> {
        for (name, x) in [
            ("ttft_ns", self.ttft_ns),
            ("per_token_ns", self.per_token_ns),
            ("abandon_ttft_ns", self.abandon_ttft_ns),
        ] {
            if !x.is_finite() || x <= 0.0 {
                bail!("slo.{name} must be finite and > 0, got {x}");
            }
        }
        if self.abandon_ttft_ns < self.ttft_ns {
            bail!(
                "slo.abandon_ttft_ns ({}) must be >= slo.ttft_ns ({}): \
                 a request cannot be abandoned before it misses its \
                 deadline",
                self.abandon_ttft_ns,
                self.ttft_ns
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("ttft_ns", Json::from(self.ttft_ns)),
            ("per_token_ns", Json::from(self.per_token_ns)),
            ("abandon_ttft_ns", Json::from(self.abandon_ttft_ns)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SloSpec> {
        let spec = SloSpec {
            ttft_ns: j.get("ttft_ns")?.as_f64()?,
            per_token_ns: j.get("per_token_ns")?.as_f64()?,
            abandon_ttft_ns: j.get("abandon_ttft_ns")?.as_f64()?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Attained-goodput accounting over one run's finished requests.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloReport {
    /// Requests evaluated.
    pub requests: usize,
    /// Requests meeting the TTFT deadline.
    pub met_ttft: usize,
    /// Requests meeting the per-token deadline.
    pub met_per_token: usize,
    /// Requests meeting both (the goodput numerator).
    pub met_both: usize,
    /// Requests whose TTFT exceeded the abandonment threshold.
    pub abandoned: usize,
    /// Tokens generated for abandoned requests (wasted work).
    pub wasted_tokens: usize,
}

impl SloReport {
    /// Fold one finished request into the accounting.
    pub fn observe(
        &mut self,
        slo: &SloSpec,
        ttft_ns: f64,
        per_token_ns: f64,
        generated_tokens: usize,
    ) {
        self.requests += 1;
        let a = ttft_ns <= slo.ttft_ns;
        let b = per_token_ns <= slo.per_token_ns;
        self.met_ttft += a as usize;
        self.met_per_token += b as usize;
        self.met_both += (a && b) as usize;
        if ttft_ns > slo.abandon_ttft_ns {
            self.abandoned += 1;
            self.wasted_tokens += generated_tokens;
        }
    }

    /// Attained goodput: the fraction of requests meeting both SLOs.
    pub fn goodput(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.met_both as f64 / self.requests as f64
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("goodput", Json::from(self.goodput())),
            ("met_ttft", Json::from(self.met_ttft)),
            ("met_per_token", Json::from(self.met_per_token)),
            ("met_both", Json::from(self.met_both)),
            ("abandoned", Json::from(self.abandoned)),
            ("wasted_tokens", Json::from(self.wasted_tokens)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SLO: SloSpec = SloSpec {
        ttft_ns: 100.0,
        per_token_ns: 10.0,
        abandon_ttft_ns: 300.0,
    };

    #[test]
    fn goodput_requires_both_deadlines() {
        let mut r = SloReport::default();
        r.observe(&SLO, 50.0, 5.0, 8); // meets both
        r.observe(&SLO, 50.0, 50.0, 8); // ttft only
        r.observe(&SLO, 200.0, 5.0, 8); // per-token only
        r.observe(&SLO, 400.0, 50.0, 8); // neither, abandoned
        assert_eq!(r.requests, 4);
        assert_eq!(r.met_ttft, 2);
        assert_eq!(r.met_per_token, 2);
        assert_eq!(r.met_both, 1);
        assert_eq!(r.goodput(), 0.25);
        assert_eq!(r.abandoned, 1);
        assert_eq!(r.wasted_tokens, 8);
    }

    #[test]
    fn deadline_boundaries_are_inclusive() {
        let mut r = SloReport::default();
        r.observe(&SLO, 100.0, 10.0, 1);
        assert_eq!(r.met_both, 1);
        // Exactly at the abandonment threshold is still served.
        r.observe(&SLO, 300.0, 10.0, 1);
        assert_eq!(r.abandoned, 0);
    }

    #[test]
    fn empty_report_has_zero_goodput() {
        assert_eq!(SloReport::default().goodput(), 0.0);
    }

    #[test]
    fn validation_rejects_nonfinite_and_inverted_deadlines() {
        for bad in [
            SloSpec { ttft_ns: f64::NAN, ..SLO },
            SloSpec { per_token_ns: 0.0, ..SLO },
            SloSpec { abandon_ttft_ns: -1.0, ..SLO },
            SloSpec { abandon_ttft_ns: 50.0, ..SLO }, // < ttft
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
        assert!(SLO.validate().is_ok());
    }

    #[test]
    fn json_round_trips() {
        let j = Json::parse(&SLO.to_json().to_string()).unwrap();
        assert_eq!(SloSpec::from_json(&j).unwrap(), SLO);
        let mut r = SloReport::default();
        r.observe(&SLO, 50.0, 5.0, 8);
        let rj = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(rj.get("goodput").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(
            rj.get("met_both").unwrap().as_usize().unwrap(),
            1
        );
    }
}
