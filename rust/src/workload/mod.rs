//! Workload generation: arrival processes, request mixes and SLOs as
//! one declarative, replayable [`WorkloadSpec`].
//!
//! The serving-at-scale coordinator ([`crate::serving::scale`]) used
//! to know exactly one traffic shape — a seeded Poisson process with
//! one prompt/generation length. This subsystem turns the request
//! source into data: a spec names an arrival process ([`arrival`]), a
//! length mix ([`mix`]), a routing policy, per-request SLOs ([`slo`])
//! and a request count, and [`WorkloadSpec::generate`] expands it into
//! the per-request schedule the DES consumes. Everything draws from
//! one `Rng::new(seed)` under a fixed order (arrivals/think gaps
//! first, then lengths), so a checked-in scenario file replays
//! byte-stably — and the default preset reproduces the PR-2 Poisson
//! coordinator draw-for-draw.
//!
//! Specs express *per-replica* load (`requests_per_replica`, gap means
//! per replica): one file drives every
//! [`crate::cost::arch::ScaleTopology`] at the same intensity, which
//! is what makes the `flux sweep-workloads` preset-x-topology matrix
//! comparable.

pub mod arrival;
pub mod mix;
pub mod slo;

use anyhow::{bail, ensure, Context, Result};

pub use arrival::ArrivalSpec;
pub use mix::{LenClass, MixSpec};
pub use slo::{SloReport, SloSpec};

use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// Upper bound on every count-like spec field (requests, burst sizes,
/// concurrency, token lengths, token budgets). `Json::as_usize`
/// accepts any integral f64 and the float→int cast saturates, so an
/// absurd value in a scenario file would otherwise surface as an
/// arithmetic overflow or an OOM allocation mid-simulation instead of
/// a parse-time rejection. 2^20 tokens/requests is far beyond any
/// scenario the simulator is calibrated for.
pub const MAX_COUNT: usize = 1 << 20;

/// How the cluster-level router assigns arrivals to DP replicas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Routing {
    /// Strict rotation: method-independent assignment (the PR-2
    /// policy, kept as the default so flux-vs-decoupled comparisons
    /// never measure routing luck).
    #[default]
    RoundRobin,
    /// Fewest queued + running requests wins (ties to the lowest
    /// replica index). Sees queue imbalance, so it beats round-robin
    /// on tail TTFT when bursty arrivals meet a skewed length mix.
    LeastOutstanding,
}

impl Routing {
    pub fn name(&self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::LeastOutstanding => "least-outstanding",
        }
    }

    pub fn from_name(name: &str) -> Result<Routing> {
        match name {
            "round-robin" => Ok(Routing::RoundRobin),
            "least-outstanding" => Ok(Routing::LeastOutstanding),
            _ => bail!(
                "unknown routing {name:?} \
                 (round-robin|least-outstanding)"
            ),
        }
    }
}

/// One declarative serving workload.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    pub arrival: ArrivalSpec,
    pub mix: MixSpec,
    /// Requests per DP replica (total = this x dp).
    pub requests_per_replica: usize,
    pub routing: Routing,
    /// Optional per-request deadlines; when set, the report gains
    /// goodput/abandonment accounting.
    pub slo: Option<SloSpec>,
    /// Optional prefill token budget per batch (vLLM's
    /// max_num_batched_tokens); defaults to max_prompt x prefill
    /// batch, which never binds for a fixed mix.
    pub max_prefill_tokens: Option<usize>,
}

/// The expanded per-request schedule the coordinator consumes.
#[derive(Clone, Debug)]
pub struct GeneratedWorkload {
    /// Per-request lengths, index == request id.
    pub lengths: Vec<LenClass>,
    /// Open-loop absolute arrival times (empty for closed loop).
    pub arrivals: Vec<f64>,
    /// Closed-loop think gaps by issue index (empty for open loop).
    pub think_gaps: Vec<f64>,
    /// Closed-loop user count per replica (0 for open loop).
    pub concurrency: usize,
}

impl GeneratedWorkload {
    pub fn n_requests(&self) -> usize {
        self.lengths.len()
    }

    pub fn is_closed_loop(&self) -> bool {
        self.concurrency > 0
    }

    pub fn max_prompt(&self) -> usize {
        self.lengths.iter().map(|c| c.prompt).max().unwrap_or(0)
    }

    pub fn max_total(&self) -> usize {
        self.lengths
            .iter()
            .map(|c| c.prompt + c.gen)
            .max()
            .unwrap_or(0)
    }
}

impl WorkloadSpec {
    /// Expand the spec for a `dp`-replica cluster. One `Rng::new(seed)`
    /// drives everything: arrival times (or think gaps) first, then
    /// lengths — the order the byte-stability tests pin.
    pub fn generate(&self, seed: u64, dp: usize) -> GeneratedWorkload {
        let n = self.requests_per_replica * dp;
        let mut rng = Rng::new(seed);
        let (arrivals, think_gaps, concurrency) =
            match self.arrival.arrival_times(n, dp, &mut rng) {
                Some(at) => (at, Vec::new(), 0),
                None => {
                    let think = self.arrival.think_gaps(n, &mut rng);
                    let ArrivalSpec::ClosedLoop { concurrency, .. } =
                        self.arrival
                    else {
                        unreachable!("only the closed loop defers")
                    };
                    (Vec::new(), think, concurrency)
                }
            };
        GeneratedWorkload {
            lengths: self.mix.lengths(n, &mut rng),
            arrivals,
            think_gaps,
            concurrency,
        }
    }

    pub fn validate(&self) -> Result<()> {
        let ctx = || format!("workload {:?}", self.name);
        ensure!(!self.name.is_empty(), "workload name must be non-empty");
        self.arrival.validate().with_context(ctx)?;
        self.mix.validate().with_context(ctx)?;
        if let Some(slo) = &self.slo {
            slo.validate().with_context(ctx)?;
        }
        ensure!(
            (1..=MAX_COUNT).contains(&self.requests_per_replica),
            "{}: requests_per_replica must be in [1, {MAX_COUNT}], \
             got {}",
            ctx(),
            self.requests_per_replica
        );
        if let Some(cap) = self.max_prefill_tokens {
            ensure!(
                cap >= self.mix.max_prompt(),
                "{}: max_prefill_tokens ({cap}) below the mix's \
                 longest prompt ({}) — no prefill batch could ever \
                 form",
                ctx(),
                self.mix.max_prompt()
            );
            ensure!(
                cap <= MAX_COUNT,
                "{}: max_prefill_tokens ({cap}) exceeds the \
                 {MAX_COUNT}-token sanity cap",
                ctx()
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::from(self.name.as_str())),
            ("arrival", self.arrival.to_json()),
            ("mix", self.mix.to_json()),
            (
                "requests_per_replica",
                Json::from(self.requests_per_replica),
            ),
            ("routing", Json::from(self.routing.name())),
        ];
        if let Some(slo) = &self.slo {
            fields.push(("slo", slo.to_json()));
        }
        if let Some(cap) = self.max_prefill_tokens {
            fields.push(("max_prefill_tokens", Json::from(cap)));
        }
        obj(fields)
    }

    /// Parse (and validate) a workload document. Bad rates, durations
    /// and probabilities are rejected here with pointed errors instead
    /// of panicking mid-simulation (the same boundary hardening PR-2
    /// gave the event queue).
    pub fn from_json(j: &Json) -> Result<WorkloadSpec> {
        let name = j.get("name")?.as_str()?.to_string();
        let ctx = || format!("workload {name:?}");
        let spec = WorkloadSpec {
            arrival: ArrivalSpec::from_json(j.get("arrival")?)
                .with_context(ctx)?,
            mix: MixSpec::from_json(j.get("mix")?).with_context(ctx)?,
            requests_per_replica: j
                .get("requests_per_replica")?
                .as_usize()
                .with_context(ctx)?,
            routing: match j.opt("routing") {
                Some(r) => Routing::from_name(r.as_str()?)
                    .with_context(ctx)?,
                None => Routing::RoundRobin,
            },
            slo: match j.opt("slo") {
                Some(s) => {
                    Some(SloSpec::from_json(s).with_context(ctx)?)
                }
                None => None,
            },
            max_prefill_tokens: match j.opt("max_prefill_tokens") {
                Some(c) => Some(c.as_usize().with_context(ctx)?),
                None => None,
            },
            name,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a workload scenario file from disk.
    pub fn load(path: &std::path::Path) -> Result<WorkloadSpec> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!("reading workload file {}", path.display())
        })?;
        let j = Json::parse(&text).with_context(|| {
            format!("parsing workload file {}", path.display())
        })?;
        WorkloadSpec::from_json(&j).with_context(|| {
            format!("validating workload file {}", path.display())
        })
    }

    /// Resolve `--workload <preset|file.json>`: a preset name first,
    /// else a path.
    pub fn resolve(arg: &str, quick: bool) -> Result<WorkloadSpec> {
        if let Some(wl) = preset(arg, quick) {
            return Ok(wl);
        }
        if arg.ends_with(".json") || std::path::Path::new(arg).exists()
        {
            return WorkloadSpec::load(std::path::Path::new(arg));
        }
        bail!(
            "unknown workload {arg:?}; one of the presets ({}) or a \
             scenario .json file",
            PRESET_NAMES.join(" | ")
        )
    }
}

/// The preset names `flux sweep-workloads` iterates, in report order.
pub const PRESET_NAMES: [&str; 7] = [
    "poisson-balanced",
    "steady-decode",
    "bursty-decode",
    "open-prefill",
    "closed-prefill",
    "diurnal-chat",
    "long-context",
];

/// Built-in presets. `quick` trims request counts to CI size (and, for
/// the default preset, keeps the PR-2 quick/full generation lengths).
///
/// The matrix is designed in pairs so the sweep isolates one traffic
/// axis at a time: `steady-decode` vs `bursty-decode` share a mix and
/// differ only in arrivals (burst backlog widens the Flux gap —
/// measured on H800, speedup 1.03 -> 1.11 quick); `open-prefill` vs
/// `closed-prefill` share a mix and differ only in loop closure (think
/// pauses compress it, 1.58 -> 1.31 on H800).
pub fn preset(name: &str, quick: bool) -> Option<WorkloadSpec> {
    let k = if quick { 1 } else { 3 };
    let decode_mix = MixSpec::TwoPoint {
        p_long: 0.25,
        short: LenClass { prompt: 512, gen: 16 },
        long: LenClass { prompt: 768, gen: 32 },
    };
    let prefill_mix =
        MixSpec::Fixed(LenClass { prompt: 2048, gen: 4 });
    let slo = |ttft: f64, tok: f64, abandon: f64| {
        Some(SloSpec {
            ttft_ns: ttft,
            per_token_ns: tok,
            abandon_ttft_ns: abandon,
        })
    };
    let spec = match name {
        // The PR-2 scenario, verbatim: Poisson at 20ms/replica, fixed
        // 512-token prompts, 8/16-token generations.
        "poisson-balanced" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::Poisson { mean_ns: 20.0e6 },
            mix: MixSpec::Fixed(LenClass {
                prompt: 512,
                gen: if quick { 8 } else { 16 },
            }),
            requests_per_replica: if quick { 8 } else { 24 },
            routing: Routing::RoundRobin,
            slo: slo(1.2e9, 120.0e6, 2.5e9),
            max_prefill_tokens: None,
        },
        "steady-decode" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::Poisson { mean_ns: 60.0e6 },
            mix: decode_mix,
            requests_per_replica: 8 * k,
            routing: Routing::RoundRobin,
            slo: slo(0.6e9, 120.0e6, 2.0e9),
            max_prefill_tokens: None,
        },
        "bursty-decode" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::Mmpp {
                on_mean_ns: 1.0e6,
                idle_mean_ns: 90.0e6,
                avg_burst: 8,
            },
            mix: decode_mix,
            requests_per_replica: 8 * k,
            routing: Routing::RoundRobin,
            slo: slo(0.6e9, 120.0e6, 2.0e9),
            max_prefill_tokens: None,
        },
        "open-prefill" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::Poisson { mean_ns: 30.0e6 },
            mix: prefill_mix,
            requests_per_replica: 6 * k,
            routing: Routing::RoundRobin,
            slo: slo(2.0e9, 150.0e6, 4.0e9),
            max_prefill_tokens: None,
        },
        "closed-prefill" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::ClosedLoop {
                concurrency: 2,
                think_ns: 150.0e6,
            },
            mix: prefill_mix,
            requests_per_replica: 6 * k,
            routing: Routing::RoundRobin,
            slo: slo(2.0e9, 150.0e6, 4.0e9),
            max_prefill_tokens: None,
        },
        "diurnal-chat" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::Diurnal {
                base_mean_ns: 15.0e6,
                amplitude: 0.8,
                period_ns: 200.0e6,
            },
            mix: MixSpec::TwoPoint {
                p_long: 0.3,
                short: LenClass { prompt: 256, gen: 16 },
                long: LenClass { prompt: 1024, gen: 32 },
            },
            requests_per_replica: 8 * k,
            routing: Routing::RoundRobin,
            slo: slo(1.0e9, 120.0e6, 2.0e9),
            max_prefill_tokens: None,
        },
        "long-context" => WorkloadSpec {
            name: name.to_string(),
            arrival: ArrivalSpec::Poisson { mean_ns: 40.0e6 },
            mix: MixSpec::TwoPoint {
                p_long: 0.5,
                short: LenClass { prompt: 512, gen: 8 },
                long: LenClass { prompt: 6144, gen: 16 },
            },
            requests_per_replica: 6 * k,
            routing: Routing::RoundRobin,
            slo: slo(3.0e9, 150.0e6, 6.0e9),
            max_prefill_tokens: Some(8192),
        },
        _ => return None,
    };
    debug_assert!(spec.validate().is_ok());
    Some(spec)
}

/// All presets in report order.
pub fn all_presets(quick: bool) -> Vec<WorkloadSpec> {
    PRESET_NAMES
        .iter()
        .map(|n| preset(n, quick).expect("preset table is closed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_preset_replays_the_pr2_draw_sequence() {
        // generate() must consume exactly one exponential per request
        // and nothing else, in request order — the PR-2 coordinator's
        // sequence.
        let wl = preset("poisson-balanced", true).unwrap();
        let dp = 2;
        let gw = wl.generate(17, dp);
        assert_eq!(gw.n_requests(), 16);
        assert!(!gw.is_closed_loop());
        let mut rng = Rng::new(17);
        let mut t = 0.0;
        for (i, &at) in gw.arrivals.iter().enumerate() {
            t += rng.exponential(20.0e6 / dp as f64);
            assert_eq!(at, t, "arrival {i}");
        }
        assert!(gw
            .lengths
            .iter()
            .all(|c| *c == LenClass { prompt: 512, gen: 8 }));
    }

    #[test]
    fn every_preset_generates_and_validates() {
        for quick in [true, false] {
            for wl in all_presets(quick) {
                wl.validate().unwrap();
                let gw = wl.generate(17, 4);
                assert_eq!(
                    gw.n_requests(),
                    wl.requests_per_replica * 4
                );
                assert!(gw.max_prompt() >= 1);
                assert!(gw.max_total() > gw.max_prompt());
                if gw.is_closed_loop() {
                    assert_eq!(gw.think_gaps.len(), gw.n_requests());
                    assert!(gw.arrivals.is_empty());
                } else {
                    assert_eq!(gw.arrivals.len(), gw.n_requests());
                    assert!(gw.think_gaps.is_empty());
                }
                // Identical seeds, identical schedules.
                let gw2 = wl.generate(17, 4);
                assert_eq!(gw.arrivals, gw2.arrivals);
                assert_eq!(gw.think_gaps, gw2.think_gaps);
                assert_eq!(gw.lengths, gw2.lengths);
            }
        }
    }

    #[test]
    fn spec_json_round_trips_byte_stably() {
        for wl in all_presets(true) {
            let text = wl.to_json().to_string();
            let parsed =
                WorkloadSpec::from_json(&Json::parse(&text).unwrap())
                    .unwrap();
            assert_eq!(parsed, wl);
            // Serialize -> parse -> serialize is byte-identical: the
            // contract that lets scenario files be checked in.
            assert_eq!(parsed.to_json().to_string(), text);
        }
    }

    #[test]
    fn from_json_rejects_bad_specs_with_pointed_errors() {
        let base = preset("poisson-balanced", true).unwrap();
        // Non-positive rate.
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert(
                "arrival".into(),
                Json::parse(r#"{"kind":"poisson","mean_ns":0}"#)
                    .unwrap(),
            );
        }
        let err = WorkloadSpec::from_json(&j).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("poisson-balanced")
                && msg.contains("mean_ns"),
            "must name the workload and the field: {msg}"
        );
        // Zero requests.
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("requests_per_replica".into(), Json::from(0usize));
        }
        assert!(WorkloadSpec::from_json(&j).is_err());
        // Token cap below the longest prompt.
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("max_prefill_tokens".into(), Json::from(64usize));
        }
        let msg =
            format!("{:#}", WorkloadSpec::from_json(&j).unwrap_err());
        assert!(msg.contains("max_prefill_tokens"), "{msg}");
    }

    #[test]
    fn resolve_finds_presets_and_rejects_unknown_names() {
        assert_eq!(
            WorkloadSpec::resolve("bursty-decode", true).unwrap().name,
            "bursty-decode"
        );
        let err = WorkloadSpec::resolve("mystery-traffic", true)
            .unwrap_err()
            .to_string();
        assert!(err.contains("poisson-balanced"), "{err}");
    }

    #[test]
    fn routing_names_round_trip() {
        for r in [Routing::RoundRobin, Routing::LeastOutstanding] {
            assert_eq!(Routing::from_name(r.name()).unwrap(), r);
        }
        assert!(Routing::from_name("random").is_err());
    }
}
