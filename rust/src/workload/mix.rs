//! Request-mix models: how long prompts and generations are.
//!
//! The paper's inference evaluation is length-shaped — Fig. 16 prefill
//! is 8 x 2048-token prompts, Fig. 17 decoding is long token-by-token
//! generations — and which phase dominates decides how much TP
//! communication Flux can hide. Two samplers cover the space:
//!
//! * [`MixSpec::Fixed`] — every request identical (the PR-2 default;
//!   draws nothing from the PRNG, preserving the arrival stream
//!   byte-for-byte).
//! * [`MixSpec::TwoPoint`] — a ShareGPT-like two-point mixture: with
//!   probability `p_long` the request is the long class, otherwise the
//!   short class (one uniform draw per request). Real trace length
//!   histograms are famously bimodal — short chat turns plus a heavy
//!   tail of long documents — and a two-point mixture is the smallest
//!   model that reproduces the scheduling pathologies that bimodality
//!   causes (head-of-line blocking, padded-batch waste).

use anyhow::{bail, ensure, Result};

use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// One request class: prompt tokens in, generated tokens out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LenClass {
    pub prompt: usize,
    pub gen: usize,
}

impl LenClass {
    fn to_json(self) -> Json {
        obj(vec![
            ("prompt", Json::from(self.prompt)),
            ("gen", Json::from(self.gen)),
        ])
    }

    /// Both lengths in `[1, MAX_COUNT]` — an absurd length would
    /// otherwise become an OOM-sized prompt allocation mid-simulation.
    fn check(self, what: &str) -> Result<()> {
        let max = super::MAX_COUNT;
        ensure!(
            (1..=max).contains(&self.prompt)
                && (1..=max).contains(&self.gen),
            "{what} lengths must be in [1, {max}], got prompt {} \
             gen {}",
            self.prompt,
            self.gen
        );
        Ok(())
    }

    fn from_json(j: &Json) -> Result<LenClass> {
        let c = LenClass {
            prompt: j.get("prompt")?.as_usize()?,
            gen: j.get("gen")?.as_usize()?,
        };
        c.check("mix length class")?;
        Ok(c)
    }
}

/// A seeded request-length sampler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MixSpec {
    /// Every request `prompt` x `gen` (no PRNG draws).
    Fixed(LenClass),
    /// Two-point mixture: `long` with probability `p_long`, else
    /// `short` (one uniform draw per request).
    TwoPoint { p_long: f64, short: LenClass, long: LenClass },
}

impl MixSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            MixSpec::Fixed(_) => "fixed",
            MixSpec::TwoPoint { .. } => "two-point",
        }
    }

    /// Draw `n` request lengths (index == request id). Fixed draws
    /// nothing; two-point consumes exactly one `f64` per request.
    pub fn lengths(&self, n: usize, rng: &mut Rng) -> Vec<LenClass> {
        match *self {
            MixSpec::Fixed(c) => vec![c; n],
            MixSpec::TwoPoint { p_long, short, long } => (0..n)
                .map(|_| if rng.f64() < p_long { long } else { short })
                .collect(),
        }
    }

    /// The longest prompt this mix can emit (padded-batch sizing).
    pub fn max_prompt(&self) -> usize {
        match *self {
            MixSpec::Fixed(c) => c.prompt,
            MixSpec::TwoPoint { short, long, .. } => {
                short.prompt.max(long.prompt)
            }
        }
    }

    /// The longest total sequence (prompt + gen) this mix can emit
    /// (KV-pool sizing).
    pub fn max_total(&self) -> usize {
        match *self {
            MixSpec::Fixed(c) => c.prompt + c.gen,
            MixSpec::TwoPoint { short, long, .. } => {
                (short.prompt + short.gen).max(long.prompt + long.gen)
            }
        }
    }

    /// The fixed lengths, when the mix is degenerate (the v1-report
    /// compat fields only exist for fixed mixes).
    pub fn fixed(&self) -> Option<LenClass> {
        match *self {
            MixSpec::Fixed(c) => Some(c),
            MixSpec::TwoPoint { .. } => None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        match *self {
            MixSpec::Fixed(c) => c.check("mix")?,
            MixSpec::TwoPoint { p_long, short, long } => {
                if !p_long.is_finite() || !(0.0..=1.0).contains(&p_long)
                {
                    bail!(
                        "mix.p_long must be a probability in [0, 1], \
                         got {p_long}"
                    );
                }
                short.check("mix.short")?;
                long.check("mix.long")?;
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        match *self {
            MixSpec::Fixed(c) => obj(vec![
                ("kind", Json::from("fixed")),
                ("prompt", Json::from(c.prompt)),
                ("gen", Json::from(c.gen)),
            ]),
            MixSpec::TwoPoint { p_long, short, long } => obj(vec![
                ("kind", Json::from("two-point")),
                ("p_long", Json::from(p_long)),
                ("short", short.to_json()),
                ("long", long.to_json()),
            ]),
        }
    }

    /// Parse (and validate) from the `"mix"` object of a workload file.
    pub fn from_json(j: &Json) -> Result<MixSpec> {
        let spec = match j.get("kind")?.as_str()? {
            "fixed" => MixSpec::Fixed(LenClass {
                prompt: j.get("prompt")?.as_usize()?,
                gen: j.get("gen")?.as_usize()?,
            }),
            "two-point" => MixSpec::TwoPoint {
                p_long: j.get("p_long")?.as_f64()?,
                short: LenClass::from_json(j.get("short")?)?,
                long: LenClass::from_json(j.get("long")?)?,
            },
            k => bail!("unknown mix kind {k:?} (fixed|two-point)"),
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHORT: LenClass = LenClass { prompt: 256, gen: 16 };
    const LONG: LenClass = LenClass { prompt: 1024, gen: 32 };

    #[test]
    fn fixed_draws_nothing_from_the_rng() {
        // The bit-compat anchor: a fixed mix must leave the PRNG
        // untouched so the PR-2 arrival stream replays exactly.
        let mix = MixSpec::Fixed(SHORT);
        let mut rng = Rng::new(17);
        let before = rng.clone().next_u64();
        let lens = mix.lengths(100, &mut rng);
        assert_eq!(rng.next_u64(), before, "rng state must be untouched");
        assert!(lens.iter().all(|c| *c == SHORT));
    }

    #[test]
    fn two_point_stays_in_class_and_hits_both() {
        let mix =
            MixSpec::TwoPoint { p_long: 0.3, short: SHORT, long: LONG };
        let lens = mix.lengths(400, &mut Rng::new(7));
        let n_long = lens.iter().filter(|c| **c == LONG).count();
        assert!(lens.iter().all(|c| *c == SHORT || *c == LONG));
        // ~30% +- a wide tolerance at n=400.
        assert!((60..=180).contains(&n_long), "n_long {n_long}");
        // Replays by seed.
        assert_eq!(lens, mix.lengths(400, &mut Rng::new(7)));
    }

    #[test]
    fn bounds_cover_both_classes() {
        let mix =
            MixSpec::TwoPoint { p_long: 0.5, short: SHORT, long: LONG };
        assert_eq!(mix.max_prompt(), 1024);
        assert_eq!(mix.max_total(), 1056);
        assert_eq!(mix.fixed(), None);
        let fixed = MixSpec::Fixed(LONG);
        assert_eq!(fixed.max_prompt(), 1024);
        assert_eq!(fixed.max_total(), 1056);
        assert_eq!(fixed.fixed(), Some(LONG));
    }

    #[test]
    fn validation_rejects_degenerate_mixes() {
        for bad in [
            MixSpec::Fixed(LenClass { prompt: 0, gen: 1 }),
            MixSpec::Fixed(LenClass { prompt: 1, gen: 0 }),
            MixSpec::TwoPoint {
                p_long: f64::NAN,
                short: SHORT,
                long: LONG,
            },
            MixSpec::TwoPoint { p_long: 1.5, short: SHORT, long: LONG },
            MixSpec::TwoPoint {
                p_long: 0.5,
                short: LenClass { prompt: 0, gen: 1 },
                long: LONG,
            },
            // OOM-sized lengths from a scenario file are a parse-time
            // rejection, not a mid-simulation allocation failure.
            MixSpec::Fixed(LenClass {
                prompt: crate::workload::MAX_COUNT + 1,
                gen: 1,
            }),
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn json_round_trips_both_kinds() {
        for mix in [
            MixSpec::Fixed(SHORT),
            MixSpec::TwoPoint { p_long: 0.25, short: SHORT, long: LONG },
        ] {
            let j = Json::parse(&mix.to_json().to_string()).unwrap();
            assert_eq!(MixSpec::from_json(&j).unwrap(), mix);
        }
    }
}
