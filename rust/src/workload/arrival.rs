//! Arrival processes: how requests hit the cluster over time.
//!
//! Four shapes behind one seeded interface, chosen to span the traffic
//! regimes the paper's inference figures (13–15) are sensitive to:
//!
//! * [`ArrivalSpec::Poisson`] — memoryless open-loop arrivals, the
//!   PR-2 default (bit-preserved: one exponential draw per request).
//! * [`ArrivalSpec::Mmpp`] — on/off bursty arrivals (a two-state
//!   Markov-modulated Poisson process): bursts of closely spaced
//!   requests separated by exponential silences. Burst *backlog* is
//!   what amplifies the Flux-vs-decoupled gap.
//! * [`ArrivalSpec::Diurnal`] — rate-curve Poisson: the instantaneous
//!   rate swings sinusoidally around the base rate, the day/night
//!   load shape of a public serving endpoint.
//! * [`ArrivalSpec::ClosedLoop`] — fixed concurrency: a pool of users
//!   who each wait for their previous request to finish, think for an
//!   exponential pause, then issue the next one. Arrival times depend
//!   on completions, so they are generated *inside* the coordinator,
//!   not up front — the think gaps are still pre-drawn per request
//!   index so every execution method sees the same user behavior.
//!
//! Cluster-level scaling: specs express *per-replica* load, and open
//! -loop gap means are divided by the DP degree (rates add across
//! replicas); closed-loop concurrency multiplies by it. One spec file
//! therefore drives every [`crate::cost::arch::ScaleTopology`] at the
//! same per-replica intensity.
//!
//! Draw-order contract (the byte-stability anchor, shared with the
//! length samplers in [`super::mix`]): `generate` draws all open-loop
//! arrival gaps (or all closed-loop think gaps) first, then all
//! request lengths, from one `Rng::new(seed)`. The default Poisson +
//! fixed-mix path consumes exactly one exponential per request and
//! nothing else — the identical sequence PR-2's coordinator drew.

use anyhow::{bail, Result};

use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// A seeded arrival process. Open-loop processes pre-draw the full
/// absolute-time schedule; the closed loop exposes its parameters for
/// the coordinator's completion-driven issue loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalSpec {
    /// Open-loop Poisson with per-replica mean inter-arrival `mean_ns`.
    Poisson { mean_ns: f64 },
    /// On/off bursty arrivals: bursts of exponential(`on_mean_ns`)
    /// gaps, sizes uniform in `[1, 2*avg_burst)`, separated by
    /// exponential(`idle_mean_ns`) silences (per-replica means).
    Mmpp { on_mean_ns: f64, idle_mean_ns: f64, avg_burst: usize },
    /// Rate-curve Poisson: instantaneous rate scaled by
    /// `1 + amplitude * sin(2*pi*t / period_ns)` around the base.
    Diurnal { base_mean_ns: f64, amplitude: f64, period_ns: f64 },
    /// Fixed concurrency per replica with exponential think time.
    ClosedLoop { concurrency: usize, think_ns: f64 },
}

impl ArrivalSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalSpec::Poisson { .. } => "poisson",
            ArrivalSpec::Mmpp { .. } => "mmpp",
            ArrivalSpec::Diurnal { .. } => "diurnal",
            ArrivalSpec::ClosedLoop { .. } => "closed-loop",
        }
    }

    /// Pre-draw the open-loop absolute arrival times for `n` requests
    /// over `dp` replicas (gap means divided by `dp`: rates add).
    /// Returns `None` for the closed loop, whose arrivals depend on
    /// completions.
    pub fn arrival_times(
        &self,
        n: usize,
        dp: usize,
        rng: &mut Rng,
    ) -> Option<Vec<f64>> {
        let dp = dp as f64;
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0;
        match *self {
            ArrivalSpec::Poisson { mean_ns } => {
                for _ in 0..n {
                    t += rng.exponential(mean_ns / dp);
                    out.push(t);
                }
            }
            ArrivalSpec::Mmpp { on_mean_ns, idle_mean_ns, avg_burst } => {
                let mut burst_left = 0usize;
                // `validate()` rejects avg_burst == 0 at parse time;
                // the saturating bound keeps a hand-built spec from
                // underflowing to below(u64::MAX) (same value and
                // draw count for every legal avg_burst >= 1).
                let bound =
                    (2 * avg_burst as u64).saturating_sub(1).max(1);
                for _ in 0..n {
                    if burst_left == 0 {
                        t += rng.exponential(idle_mean_ns / dp);
                        burst_left = 1 + rng.below(bound) as usize;
                    } else {
                        t += rng.exponential(on_mean_ns / dp);
                    }
                    burst_left -= 1;
                    out.push(t);
                }
            }
            ArrivalSpec::Diurnal { base_mean_ns, amplitude, period_ns } => {
                for _ in 0..n {
                    let rate = 1.0
                        + amplitude
                            * (2.0 * std::f64::consts::PI * t / period_ns)
                                .sin();
                    t += rng.exponential(base_mean_ns / dp / rate);
                    out.push(t);
                }
            }
            ArrivalSpec::ClosedLoop { .. } => return None,
        }
        Some(out)
    }

    /// Pre-draw the closed loop's per-request think gaps (issue order
    /// indexes them, so every method replays the same user pauses).
    /// Empty for open-loop processes.
    pub fn think_gaps(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match *self {
            ArrivalSpec::ClosedLoop { think_ns, .. } => {
                (0..n).map(|_| rng.exponential(think_ns)).collect()
            }
            _ => Vec::new(),
        }
    }

    /// Reject non-finite / non-positive / degenerate parameters with a
    /// pointed error (a NaN rate would otherwise surface as a
    /// "non-finite event time" panic mid-simulation, and an absurd
    /// count — `as_usize` saturates huge floats — as an arithmetic
    /// overflow inside `generate`).
    pub fn validate(&self) -> Result<()> {
        let pos = |name: &str, x: f64| -> Result<()> {
            if !x.is_finite() || x <= 0.0 {
                bail!(
                    "arrival.{name} must be finite and > 0, got {x}"
                );
            }
            Ok(())
        };
        let count = |name: &str, x: usize| -> Result<()> {
            if !(1..=super::MAX_COUNT).contains(&x) {
                bail!(
                    "arrival.{name} must be in [1, {}], got {x}",
                    super::MAX_COUNT
                );
            }
            Ok(())
        };
        match *self {
            ArrivalSpec::Poisson { mean_ns } => pos("mean_ns", mean_ns),
            ArrivalSpec::Mmpp { on_mean_ns, idle_mean_ns, avg_burst } => {
                pos("on_mean_ns", on_mean_ns)?;
                pos("idle_mean_ns", idle_mean_ns)?;
                count("avg_burst", avg_burst)
            }
            ArrivalSpec::Diurnal { base_mean_ns, amplitude, period_ns } => {
                pos("base_mean_ns", base_mean_ns)?;
                pos("period_ns", period_ns)?;
                if !amplitude.is_finite()
                    || !(0.0..1.0).contains(&amplitude)
                {
                    bail!(
                        "arrival.amplitude must be in [0, 1) so the \
                         rate stays positive, got {amplitude}"
                    );
                }
                Ok(())
            }
            ArrivalSpec::ClosedLoop { concurrency, think_ns } => {
                pos("think_ns", think_ns)?;
                count("concurrency", concurrency)
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            ArrivalSpec::Poisson { mean_ns } => obj(vec![
                ("kind", Json::from("poisson")),
                ("mean_ns", Json::from(mean_ns)),
            ]),
            ArrivalSpec::Mmpp { on_mean_ns, idle_mean_ns, avg_burst } => {
                obj(vec![
                    ("kind", Json::from("mmpp")),
                    ("on_mean_ns", Json::from(on_mean_ns)),
                    ("idle_mean_ns", Json::from(idle_mean_ns)),
                    ("avg_burst", Json::from(avg_burst)),
                ])
            }
            ArrivalSpec::Diurnal { base_mean_ns, amplitude, period_ns } => {
                obj(vec![
                    ("kind", Json::from("diurnal")),
                    ("base_mean_ns", Json::from(base_mean_ns)),
                    ("amplitude", Json::from(amplitude)),
                    ("period_ns", Json::from(period_ns)),
                ])
            }
            ArrivalSpec::ClosedLoop { concurrency, think_ns } => {
                obj(vec![
                    ("kind", Json::from("closed-loop")),
                    ("concurrency", Json::from(concurrency)),
                    ("think_ns", Json::from(think_ns)),
                ])
            }
        }
    }

    /// Parse (and validate) from the `"arrival"` object of a workload
    /// file.
    pub fn from_json(j: &Json) -> Result<ArrivalSpec> {
        let spec = match j.get("kind")?.as_str()? {
            "poisson" => ArrivalSpec::Poisson {
                mean_ns: j.get("mean_ns")?.as_f64()?,
            },
            "mmpp" => ArrivalSpec::Mmpp {
                on_mean_ns: j.get("on_mean_ns")?.as_f64()?,
                idle_mean_ns: j.get("idle_mean_ns")?.as_f64()?,
                avg_burst: j.get("avg_burst")?.as_usize()?,
            },
            "diurnal" => ArrivalSpec::Diurnal {
                base_mean_ns: j.get("base_mean_ns")?.as_f64()?,
                amplitude: j.get("amplitude")?.as_f64()?,
                period_ns: j.get("period_ns")?.as_f64()?,
            },
            "closed-loop" => ArrivalSpec::ClosedLoop {
                concurrency: j.get("concurrency")?.as_usize()?,
                think_ns: j.get("think_ns")?.as_f64()?,
            },
            k => bail!(
                "unknown arrival kind {k:?} \
                 (poisson|mmpp|diurnal|closed-loop)"
            ),
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_matches_the_pr2_draw_sequence() {
        // The default path's contract: arrival_times with the cluster
        // mean is exactly the `t += rng.exponential(mean)` loop the
        // PR-2 coordinator ran.
        let spec = ArrivalSpec::Poisson { mean_ns: 20.0e6 };
        let times =
            spec.arrival_times(8, 2, &mut Rng::new(17)).unwrap();
        let mut rng = Rng::new(17);
        let mut t = 0.0;
        for &at in &times {
            t += rng.exponential(20.0e6 / 2.0);
            assert_eq!(at, t);
        }
    }

    #[test]
    fn all_processes_are_finite_increasing_and_seeded() {
        let specs = [
            ArrivalSpec::Poisson { mean_ns: 1e6 },
            ArrivalSpec::Mmpp {
                on_mean_ns: 1e5,
                idle_mean_ns: 1e7,
                avg_burst: 4,
            },
            ArrivalSpec::Diurnal {
                base_mean_ns: 1e6,
                amplitude: 0.9,
                period_ns: 1e8,
            },
        ];
        for spec in &specs {
            let a = spec.arrival_times(64, 2, &mut Rng::new(3)).unwrap();
            let b = spec.arrival_times(64, 2, &mut Rng::new(3)).unwrap();
            assert_eq!(a, b, "{:?} must replay by seed", spec.kind());
            let mut prev = 0.0;
            for &t in &a {
                assert!(t.is_finite() && t >= prev, "{t} after {prev}");
                prev = t;
            }
        }
    }

    #[test]
    fn closed_loop_pre_draws_think_gaps_only() {
        let spec =
            ArrivalSpec::ClosedLoop { concurrency: 2, think_ns: 1e6 };
        assert!(spec.arrival_times(8, 1, &mut Rng::new(1)).is_none());
        let gaps = spec.think_gaps(8, &mut Rng::new(1));
        assert_eq!(gaps.len(), 8);
        assert!(gaps.iter().all(|g| g.is_finite() && *g >= 0.0));
        // Open-loop processes have no think gaps.
        let open = ArrivalSpec::Poisson { mean_ns: 1e6 };
        assert!(open.think_gaps(8, &mut Rng::new(1)).is_empty());
    }

    #[test]
    fn mmpp_bursts_are_tighter_than_idles() {
        // Structural sanity: with a 100x on/idle separation, the p90
        // gap (burst-internal) is far below the max gap (idle).
        let spec = ArrivalSpec::Mmpp {
            on_mean_ns: 1e5,
            idle_mean_ns: 1e7,
            avg_burst: 8,
        };
        let times =
            spec.arrival_times(256, 1, &mut Rng::new(5)).unwrap();
        let mut gaps: Vec<f64> =
            times.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_by(|a, b| a.total_cmp(b));
        let p50 = gaps[gaps.len() / 2];
        let max = *gaps.last().unwrap();
        assert!(
            max > 20.0 * p50,
            "idle gaps ({max}) should dwarf burst gaps ({p50})"
        );
    }

    #[test]
    fn mmpp_boundary_burst_sizes_never_underflow() {
        // Regression: the old bound `2 * avg_burst - 1` underflowed
        // for avg_burst == 0. validate() rejects 0 at parse time, and
        // the draw site saturates so even a hand-built spec cannot
        // panic; avg_burst == 1 (the boundary) draws below(1) == 0 —
        // every burst is exactly one request.
        let one = ArrivalSpec::Mmpp {
            on_mean_ns: 1e5,
            idle_mean_ns: 1e7,
            avg_burst: 1,
        };
        let times = one.arrival_times(64, 1, &mut Rng::new(9)).unwrap();
        assert_eq!(times.len(), 64);
        // Burst size 1 means every gap is an idle draw: strictly
        // increasing times.
        assert!(times.windows(2).all(|w| w[1] > w[0]));
        let zero = ArrivalSpec::Mmpp {
            on_mean_ns: 1e5,
            idle_mean_ns: 1e7,
            avg_burst: 0,
        };
        assert!(zero.validate().is_err(), "0 still rejected at parse");
        let t0 = zero.arrival_times(16, 1, &mut Rng::new(9)).unwrap();
        assert_eq!(t0.len(), 16, "hand-built spec must not underflow");
        assert!(t0.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn validation_rejects_bad_rates() {
        for bad in [
            ArrivalSpec::Poisson { mean_ns: 0.0 },
            ArrivalSpec::Poisson { mean_ns: -1.0 },
            ArrivalSpec::Poisson { mean_ns: f64::NAN },
            ArrivalSpec::Poisson { mean_ns: f64::INFINITY },
            ArrivalSpec::Mmpp {
                on_mean_ns: 1.0,
                idle_mean_ns: f64::NAN,
                avg_burst: 2,
            },
            ArrivalSpec::Mmpp {
                on_mean_ns: 1.0,
                idle_mean_ns: 1.0,
                avg_burst: 0,
            },
            ArrivalSpec::Diurnal {
                base_mean_ns: 1.0,
                amplitude: 1.0,
                period_ns: 1.0,
            },
            ArrivalSpec::Diurnal {
                base_mean_ns: 1.0,
                amplitude: -0.1,
                period_ns: 1.0,
            },
            ArrivalSpec::ClosedLoop { concurrency: 0, think_ns: 1.0 },
            // Saturated `as_usize` casts from absurd file values must
            // be rejected here, not overflow inside generate().
            ArrivalSpec::Mmpp {
                on_mean_ns: 1.0,
                idle_mean_ns: 1.0,
                avg_burst: usize::MAX,
            },
            ArrivalSpec::ClosedLoop {
                concurrency: crate::workload::MAX_COUNT + 1,
                think_ns: 1.0,
            },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn json_round_trips_every_kind() {
        for spec in [
            ArrivalSpec::Poisson { mean_ns: 2.5e7 },
            ArrivalSpec::Mmpp {
                on_mean_ns: 1e6,
                idle_mean_ns: 9e7,
                avg_burst: 8,
            },
            ArrivalSpec::Diurnal {
                base_mean_ns: 1.5e7,
                amplitude: 0.8,
                period_ns: 2e8,
            },
            ArrivalSpec::ClosedLoop { concurrency: 2, think_ns: 1.5e8 },
        ] {
            let j = Json::parse(&spec.to_json().to_string()).unwrap();
            assert_eq!(ArrivalSpec::from_json(&j).unwrap(), spec);
        }
    }

    #[test]
    fn from_json_rejects_nonfinite_rates_with_pointed_error() {
        let j = Json::parse(
            r#"{"kind": "poisson", "mean_ns": -2e6}"#,
        )
        .unwrap();
        let err = ArrivalSpec::from_json(&j).unwrap_err().to_string();
        assert!(
            err.contains("mean_ns") && err.contains("-2000000"),
            "error must name the field and value: {err}"
        );
    }
}
