//! The three communication-overlap strategies, as timed schedules on the
//! cluster simulator:
//!
//! * [`baseline`] — non-overlapping PyTorch-style: fastest monolithic
//!   GEMM + NCCL ring collective, strictly serialized.
//! * [`medium`] — the prior medium-grained decomposition
//!   (TransformerEngine UserBuffer): N_TP chunk GEMM kernels on streams
//!   with chunked P2P, §2.2.
//! * [`flux`] — the paper's fine-grained fused kernel: tile-level
//!   decomposition, signals, swizzling, pull/push, tunable comm tiles.
//!
//! Plus [`numeric`], the correctness twin that executes the same
//! decompositions over real host buffers (and PJRT artifacts at the
//! op level) and checks them against each other.

pub mod baseline;
pub mod flux;
pub mod medium;
pub mod numeric;
pub mod signals;
pub mod tiles;

use crate::cost::arch::ClusterSpec;
use crate::cost::gemm::{gemm_time_ns, GemmShape};

pub const BF16: f64 = 2.0;

/// Which fused pattern (paper Fig. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// AllGather(x) then GEMM with column-sharded weight.
    AgGemm,
    /// GEMM with row-sharded weight then ReduceScatter.
    GemmRs,
}

impl Op {
    pub fn name(self) -> &'static str {
        match self {
            Op::AgGemm => "AllGather+GEMM",
            Op::GemmRs => "GEMM+ReduceScatter",
        }
    }
}

/// A tensor-parallel GEMM problem in *global* (pre-partition) shape,
/// matching the paper's notation (§2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Problem {
    pub op: Op,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub n_tp: usize,
}

impl Problem {
    pub fn ag(m: usize, n: usize, k: usize, n_tp: usize) -> Problem {
        Problem { op: Op::AgGemm, m, n, k, n_tp }
    }

    pub fn rs(m: usize, n: usize, k: usize, n_tp: usize) -> Problem {
        Problem { op: Op::GemmRs, m, n, k, n_tp }
    }

    /// The local (per-rank) GEMM each strategy must compute.
    pub fn local_gemm(&self) -> GemmShape {
        match self.op {
            Op::AgGemm => GemmShape::new(self.m, self.n / self.n_tp, self.k),
            Op::GemmRs => GemmShape::new(self.m, self.n, self.k / self.n_tp),
        }
    }

    /// Bytes moved by the collective (bf16).
    pub fn comm_bytes(&self) -> f64 {
        match self.op {
            // AllGather of x: [m, k] gathered.
            Op::AgGemm => self.m as f64 * self.k as f64 * BF16,
            // ReduceScatter of the [m, n] partial outputs.
            Op::GemmRs => self.m as f64 * self.n as f64 * BF16,
        }
    }

    /// Eq. 1's `GEMM_non-split`: the fastest monolithic local GEMM.
    pub fn gemm_nonsplit_ns(&self, cluster: &ClusterSpec) -> f64 {
        gemm_time_ns(&cluster.arch, &self.local_gemm())
    }
}

/// Result of simulating one strategy on one problem.
#[derive(Clone, Copy, Debug)]
pub struct OpTiming {
    /// End-to-end time for the slowest rank, ns.
    pub overall_ns: f64,
    /// Eq. 1 baseline GEMM time, ns (identical across strategies).
    pub gemm_nonsplit_ns: f64,
}

impl OpTiming {
    /// Eq. 1: Effective Communication Time.
    pub fn ect_ns(&self) -> f64 {
        self.overall_ns - self.gemm_nonsplit_ns
    }

    /// Eq. 2: overlap efficiency against a non-overlapping baseline.
    pub fn overlap_efficiency(&self, baseline: &OpTiming) -> f64 {
        1.0 - self.ect_ns() / baseline.ect_ns()
    }

    pub fn speedup_over(&self, other: &OpTiming) -> f64 {
        other.overall_ns / self.overall_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::A100_NVLINK;

    #[test]
    fn local_shapes_follow_fig2() {
        let ag = Problem::ag(4096, 49152, 12288, 8);
        assert_eq!(ag.local_gemm(), GemmShape::new(4096, 6144, 12288));
        let rs = Problem::rs(4096, 12288, 49152, 8);
        assert_eq!(rs.local_gemm(), GemmShape::new(4096, 12288, 6144));
    }

    #[test]
    fn comm_bytes() {
        let ag = Problem::ag(1024, 49152, 12288, 8);
        assert_eq!(ag.comm_bytes(), 1024.0 * 12288.0 * 2.0);
        let rs = Problem::rs(1024, 12288, 49152, 8);
        assert_eq!(rs.comm_bytes(), 1024.0 * 12288.0 * 2.0);
    }

    #[test]
    fn metrics_identities() {
        let base = OpTiming { overall_ns: 150.0, gemm_nonsplit_ns: 100.0 };
        let perfect = OpTiming { overall_ns: 100.0, gemm_nonsplit_ns: 100.0 };
        assert_eq!(base.ect_ns(), 50.0);
        // Perfect overlap: zero ECT, 100% efficiency (§2.3).
        assert_eq!(perfect.ect_ns(), 0.0);
        assert_eq!(perfect.overlap_efficiency(&base), 1.0);
        // Non-overlap baseline has efficiency 0 against itself.
        assert_eq!(base.overlap_efficiency(&base), 0.0);
        // Slower than baseline → negative efficiency.
        let bad = OpTiming { overall_ns: 220.0, gemm_nonsplit_ns: 100.0 };
        assert!(bad.overlap_efficiency(&base) < 0.0);
    }

    #[test]
    fn gemm_nonsplit_uses_local_shape() {
        let p = Problem::ag(1024, 49152, 12288, 8);
        let t = p.gemm_nonsplit_ns(&A100_NVLINK);
        assert!(t > 0.0);
    }
}
