//! The three communication-overlap strategies, as timed schedules on the
//! cluster simulator:
//!
//! * [`baseline`] — non-overlapping PyTorch-style: fastest monolithic
//!   GEMM + NCCL ring collective, strictly serialized.
//! * [`medium`] — the prior medium-grained decomposition
//!   (TransformerEngine UserBuffer): N_TP chunk GEMM kernels on streams
//!   with chunked P2P, §2.2.
//! * [`flux`] — the paper's fine-grained fused kernel: tile-level
//!   decomposition, signals, swizzling, pull/push, tunable comm tiles.
//!
//! Plus [`numeric`], the correctness twin that executes the same
//! decompositions over real host buffers (and PJRT artifacts at the
//! op level) and checks them against each other.
//!
//! [`Method`] is the registry over those strategies: serving, training
//! and sweep experiments iterate a method *set* (`SERVE_SET` /
//! `TRAIN_SET` or a scenario file's explicit list) instead of wiring a
//! fixed pair, and `flux list` / scenario JSON address entries by
//! [`Method::key`].

pub mod baseline;
pub mod flux;
pub mod medium;
pub mod numeric;
pub mod signals;
pub mod tiles;

use crate::cost::arch::ClusterSpec;
use crate::cost::gemm::{gemm_time_ns, GemmShape};

pub const BF16: f64 = 2.0;

/// Which overlap system executes the TP ops — the method registry the
/// serving, training and sweep paths iterate uniformly (historically
/// each hard-coded its own flux-vs-decoupled pair). Scenario files and
/// `flux list` address methods by [`Method::key`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Megatron-LM / vLLM: fastest GEMM + NCCL, no overlap.
    NonOverlap,
    /// TransformerEngine UserBuffer: medium-grained chunk overlap.
    Medium,
    /// FLUX fused fine-grained overlap (auto-tuned per shape).
    Flux,
}

impl Method {
    pub const ALL: [Method; 3] =
        [Method::NonOverlap, Method::Medium, Method::Flux];

    /// The pair every serving comparison runs (decoupled vs fused).
    pub const SERVE_SET: [Method; 2] = [Method::NonOverlap, Method::Flux];

    /// The three-way Fig. 16 training comparison.
    pub const TRAIN_SET: [Method; 3] =
        [Method::NonOverlap, Method::Medium, Method::Flux];

    pub fn name(self) -> &'static str {
        match self {
            Method::NonOverlap => "non-overlap",
            Method::Medium => "TE-medium",
            Method::Flux => "Flux",
        }
    }

    /// Stable registry key, the spelling scenario files and `flux list`
    /// use.
    pub fn key(self) -> &'static str {
        match self {
            Method::NonOverlap => "baseline",
            Method::Medium => "medium",
            Method::Flux => "flux",
        }
    }

    /// Look a method up by its registry [`Method::key`].
    pub fn by_key(key: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.key() == key)
    }

    /// Every registry key, in `ALL` order (error messages, `flux list`).
    pub fn keys() -> Vec<&'static str> {
        Method::ALL.iter().map(|m| m.key()).collect()
    }

    /// Key of this method's block in serving documents (the decoupled
    /// GEMM-then-NCCL execution keeps its historical report name).
    pub fn serve_label(self) -> &'static str {
        match self {
            Method::NonOverlap => "decoupled",
            Method::Medium => "medium",
            Method::Flux => "flux",
        }
    }

    /// Key of this method's block in training documents (the system
    /// names Fig. 16 compares).
    pub fn train_label(self) -> &'static str {
        match self {
            Method::NonOverlap => "megatron",
            Method::Medium => "te",
            Method::Flux => "flux",
        }
    }

    /// One-line description for `flux list`.
    pub fn summary(self) -> &'static str {
        match self {
            Method::NonOverlap => {
                "decoupled GEMM then NCCL collective, strictly serialized"
            }
            Method::Medium => {
                "TransformerEngine-style chunked GEMM/P2P stream overlap"
            }
            Method::Flux => {
                "fused tile-level overlap with signals and swizzling"
            }
        }
    }

    /// Simulated time of one TP op under this method.
    pub fn op_ns(self, cluster: &ClusterSpec, p: &Problem, seed: u64) -> f64 {
        match self {
            Method::NonOverlap => baseline::simulate(cluster, p).overall_ns,
            Method::Medium => medium::simulate(cluster, p, seed).overall_ns,
            Method::Flux => {
                // The tuned direction per interconnect; full tuning is
                // tuner::tune (used by the benches); the training loop
                // uses the converged config for speed.
                let cfg = flux::FluxConfig::for_cluster(cluster);
                flux::simulate(cluster, p, &cfg, seed).overall_ns
            }
        }
    }
}

/// Which fused pattern (paper Fig. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// AllGather(x) then GEMM with column-sharded weight.
    AgGemm,
    /// GEMM with row-sharded weight then ReduceScatter.
    GemmRs,
}

impl Op {
    pub fn name(self) -> &'static str {
        match self {
            Op::AgGemm => "AllGather+GEMM",
            Op::GemmRs => "GEMM+ReduceScatter",
        }
    }
}

/// A tensor-parallel GEMM problem in *global* (pre-partition) shape,
/// matching the paper's notation (§2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Problem {
    pub op: Op,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub n_tp: usize,
}

impl Problem {
    pub fn ag(m: usize, n: usize, k: usize, n_tp: usize) -> Problem {
        Problem { op: Op::AgGemm, m, n, k, n_tp }
    }

    pub fn rs(m: usize, n: usize, k: usize, n_tp: usize) -> Problem {
        Problem { op: Op::GemmRs, m, n, k, n_tp }
    }

    /// The local (per-rank) GEMM each strategy must compute.
    pub fn local_gemm(&self) -> GemmShape {
        match self.op {
            Op::AgGemm => GemmShape::new(self.m, self.n / self.n_tp, self.k),
            Op::GemmRs => GemmShape::new(self.m, self.n, self.k / self.n_tp),
        }
    }

    /// Bytes moved by the collective (bf16).
    pub fn comm_bytes(&self) -> f64 {
        match self.op {
            // AllGather of x: [m, k] gathered.
            Op::AgGemm => self.m as f64 * self.k as f64 * BF16,
            // ReduceScatter of the [m, n] partial outputs.
            Op::GemmRs => self.m as f64 * self.n as f64 * BF16,
        }
    }

    /// Eq. 1's `GEMM_non-split`: the fastest monolithic local GEMM.
    pub fn gemm_nonsplit_ns(&self, cluster: &ClusterSpec) -> f64 {
        gemm_time_ns(&cluster.arch, &self.local_gemm())
    }
}

/// Result of simulating one strategy on one problem.
#[derive(Clone, Copy, Debug)]
pub struct OpTiming {
    /// End-to-end time for the slowest rank, ns.
    pub overall_ns: f64,
    /// Eq. 1 baseline GEMM time, ns (identical across strategies).
    pub gemm_nonsplit_ns: f64,
}

impl OpTiming {
    /// Eq. 1: Effective Communication Time.
    pub fn ect_ns(&self) -> f64 {
        self.overall_ns - self.gemm_nonsplit_ns
    }

    /// Eq. 2: overlap efficiency against a non-overlapping baseline.
    pub fn overlap_efficiency(&self, baseline: &OpTiming) -> f64 {
        1.0 - self.ect_ns() / baseline.ect_ns()
    }

    pub fn speedup_over(&self, other: &OpTiming) -> f64 {
        other.overall_ns / self.overall_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::A100_NVLINK;

    #[test]
    fn local_shapes_follow_fig2() {
        let ag = Problem::ag(4096, 49152, 12288, 8);
        assert_eq!(ag.local_gemm(), GemmShape::new(4096, 6144, 12288));
        let rs = Problem::rs(4096, 12288, 49152, 8);
        assert_eq!(rs.local_gemm(), GemmShape::new(4096, 12288, 6144));
    }

    #[test]
    fn comm_bytes() {
        let ag = Problem::ag(1024, 49152, 12288, 8);
        assert_eq!(ag.comm_bytes(), 1024.0 * 12288.0 * 2.0);
        let rs = Problem::rs(1024, 12288, 49152, 8);
        assert_eq!(rs.comm_bytes(), 1024.0 * 12288.0 * 2.0);
    }

    #[test]
    fn metrics_identities() {
        let base = OpTiming { overall_ns: 150.0, gemm_nonsplit_ns: 100.0 };
        let perfect = OpTiming { overall_ns: 100.0, gemm_nonsplit_ns: 100.0 };
        assert_eq!(base.ect_ns(), 50.0);
        // Perfect overlap: zero ECT, 100% efficiency (§2.3).
        assert_eq!(perfect.ect_ns(), 0.0);
        assert_eq!(perfect.overlap_efficiency(&base), 1.0);
        // Non-overlap baseline has efficiency 0 against itself.
        assert_eq!(base.overlap_efficiency(&base), 0.0);
        // Slower than baseline → negative efficiency.
        let bad = OpTiming { overall_ns: 220.0, gemm_nonsplit_ns: 100.0 };
        assert!(bad.overlap_efficiency(&base) < 0.0);
    }

    #[test]
    fn gemm_nonsplit_uses_local_shape() {
        let p = Problem::ag(1024, 49152, 12288, 8);
        let t = p.gemm_nonsplit_ns(&A100_NVLINK);
        assert!(t > 0.0);
    }

    #[test]
    fn method_keys_round_trip_and_are_unique() {
        for m in Method::ALL {
            assert_eq!(Method::by_key(m.key()), Some(m));
        }
        assert_eq!(Method::by_key("warp-speed"), None);
        let keys = Method::keys();
        assert_eq!(keys, vec!["baseline", "medium", "flux"]);
        for (i, k) in keys.iter().enumerate() {
            assert!(!keys[..i].contains(k), "duplicate key {k}");
        }
    }

    #[test]
    fn method_sets_and_labels_match_the_report_schemas() {
        // The report keys the compat tests pin: serving documents carry
        // decoupled/flux blocks, training documents megatron/te/flux.
        let serve: Vec<&str> =
            Method::SERVE_SET.iter().map(|m| m.serve_label()).collect();
        assert_eq!(serve, vec!["decoupled", "flux"]);
        let train: Vec<&str> =
            Method::TRAIN_SET.iter().map(|m| m.train_label()).collect();
        assert_eq!(train, vec!["megatron", "te", "flux"]);
        for m in Method::ALL {
            assert!(!m.summary().is_empty());
            assert!(!m.name().is_empty());
        }
    }

    #[test]
    fn method_op_ns_orders_like_the_strategies() {
        // Flux (tuned default config) beats the serialized baseline on
        // a comm-heavy shape; every method prices positive time.
        let p = Problem::rs(4096, 12288, 49152, 8);
        let base = Method::NonOverlap.op_ns(&A100_NVLINK, &p, 7);
        let fx = Method::Flux.op_ns(&A100_NVLINK, &p, 7);
        assert!(base > 0.0 && fx > 0.0);
        assert!(fx < base, "flux {fx} vs baseline {base}");
    }
}
