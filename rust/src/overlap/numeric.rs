//! Numeric twin of the fused kernels: executes the FLUX tile
//! decomposition over host buffers, with the real signal protocol, in
//! *arbitrary* interleavings — and must produce results identical to the
//! monolithic computation. This is the correctness core of the Rust
//! coordinator: if routing/swizzling/scheduling had an index bug, it
//! would show up here (and in the cross-check against the Pallas
//! kernels' PJRT artifacts in rust/tests/).

use anyhow::{ensure, Result};

use crate::collectives::host::{all_to_all, local_reduce, matmul, Mat};
use crate::overlap::signals::SignalSet;
use crate::overlap::tiles::{comm_schedule, swizzle_order, tile_dest};

/// Tile-decomposed GEMM+ReduceScatter for one rank (Alg. 1 numeric twin).
///
/// a: [M, K_local], b: [K_local, N]. Returns the scattered output
/// [N_TP][M/N_TP, N]: slot d holds the tiles destined for rank d — what
/// the fused CUDA epilogue would have P2P-stored into rank d's memory.
/// `bm` is the row-tile height; traversal follows the §4.1 swizzle.
pub fn gemm_rs_scattered(
    a: &Mat,
    b: &Mat,
    rank: usize,
    n_tp: usize,
    bm: usize,
    swizzle: bool,
) -> Result<Vec<Mat>> {
    let m = a.rows;
    ensure!(m % (n_tp * bm) == 0, "M={m} must tile into n_tp x bm");
    let tiles_m = m / bm;
    let per = tiles_m / n_tp;
    let order: Vec<usize> = if swizzle {
        swizzle_order(tiles_m, rank, n_tp)
    } else {
        (0..tiles_m).collect()
    };
    let mut out: Vec<Mat> =
        (0..n_tp).map(|_| Mat::zeros(m / n_tp, b.cols)).collect();
    for &ti in &order {
        // One thread-block row-tile: compute rows [ti*bm, (ti+1)*bm).
        let a_tile = a.row_slice(ti * bm, (ti + 1) * bm);
        let c_tile = matmul(&a_tile, b);
        // Epilogue: route to the destination rank (TileCoord+GetOutput).
        let dest = tile_dest(ti, tiles_m, n_tp);
        let local_i = ti % per;
        for i in 0..bm {
            for j in 0..b.cols {
                *out[dest].at_mut(local_i * bm + i, j) = c_tile.at(i, j);
            }
        }
    }
    Ok(out)
}

/// Full GEMM+ReduceScatter across ranks: per-rank fused kernels, then the
/// AlltoAll transport + local reduction (§3.1 decoupling).
pub fn gemm_rs_fused(
    a_shards: &[Mat],
    b_shards: &[Mat],
    bm: usize,
    swizzle: bool,
) -> Result<Vec<Mat>> {
    let n = a_shards.len();
    ensure!(n == b_shards.len());
    let scattered: Vec<Vec<Mat>> = a_shards
        .iter()
        .zip(b_shards)
        .enumerate()
        .map(|(r, (a, b))| gemm_rs_scattered(a, b, r, n, bm, swizzle))
        .collect::<Result<_>>()?;
    let received = all_to_all(&scattered)?;
    Ok(received.iter().map(|rx| local_reduce(rx)).collect())
}

/// Reference: monolithic GEMMs + direct ReduceScatter.
pub fn gemm_rs_reference(
    a_shards: &[Mat],
    b_shards: &[Mat],
) -> Result<Vec<Mat>> {
    let partials: Vec<Mat> = a_shards
        .iter()
        .zip(b_shards)
        .map(|(a, b)| matmul(a, b))
        .collect();
    crate::collectives::host::reduce_scatter(&partials)
}

/// The AllGather+GEMM numeric twin for one rank (Alg. 2+3): the host
/// loop transfers communication tiles in `transfer_order` (a permutation
/// of the schedule — tests randomize it to prove order-independence of
/// the *values*), sets signals; the kernel waits each row-tile's signal
/// before computing it.
///
/// x_shards: all ranks' [M/N, K] shards (rank r may only read its own
/// rows except through the scheduled transfers — enforced by building
/// a_agg strictly from transfers). w: [K, N_local].
pub fn ag_gemm_rank(
    x_shards: &[Mat],
    w: &Mat,
    rank: usize,
    comm_rows: usize,
    bm: usize,
    transfer_order: &[usize],
) -> Result<Mat> {
    let n_tp = x_shards.len();
    let shard_rows = x_shards[0].rows;
    let m = shard_rows * n_tp;
    let k = x_shards[0].cols;
    ensure!(m % bm == 0, "m {m} % bm {bm}");
    let sched = comm_schedule(m, rank, n_tp, comm_rows, true);
    // A shorter order = dropped transfers (failure injection): the kernel
    // must then deadlock on an unset signal rather than compute garbage.
    ensure!(transfer_order.len() <= sched.len(), "order too long");

    let tiles_per_rank = shard_rows / comm_rows;
    let mut signals = SignalSet::new(n_tp * tiles_per_rank);
    // Local tiles' signals preset (§3.2).
    for t in 0..tiles_per_rank {
        signals.preset(rank * tiles_per_rank + t);
    }

    // Aggregated buffer, filled only by transfers (+ local copy).
    let mut a_agg = Mat::zeros(m, k);
    for i in 0..shard_rows {
        for j in 0..k {
            *a_agg.at_mut(rank * shard_rows + i, j) =
                x_shards[rank].at(i, j);
        }
    }
    // Host loop in the given order: DataTransfer then SetSignal.
    for &oi in transfer_order {
        let t = sched[oi];
        let src_local0 = t.row0 - t.src * shard_rows;
        for i in 0..t.rows {
            for j in 0..k {
                *a_agg.at_mut(t.row0 + i, j) =
                    x_shards[t.src].at(src_local0 + i, j);
            }
        }
        signals.set(t.signal)?;
    }

    // Fused kernel: per row-tile, WaitSignal on every comm tile covering
    // its rows, then the plain tiled matmul.
    let mut out = Mat::zeros(m, w.cols);
    for ti in 0..m / bm {
        let row0 = ti * bm;
        let row1 = row0 + bm;
        let mut row = row0;
        while row < row1 {
            let sig = row / comm_rows.min(shard_rows);
            // Signal index: peer-major over comm tiles.
            let peer = row / shard_rows;
            let within = (row - peer * shard_rows) / comm_rows;
            let _ = sig;
            signals.wait(peer * tiles_per_rank + within)?;
            row += comm_rows;
        }
        let a_tile = a_agg.row_slice(row0, row1);
        let c_tile = matmul(&a_tile, w);
        for i in 0..bm {
            for j in 0..w.cols {
                *out.at_mut(row0 + i, j) = c_tile.at(i, j);
            }
        }
    }
    signals.reset()?;
    Ok(out)
}

/// Reference: gather then monolithic GEMM.
pub fn ag_gemm_reference(x_shards: &[Mat], w: &Mat) -> Result<Mat> {
    let full = crate::collectives::host::all_gather(x_shards)?;
    Ok(matmul(&full[0], w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::prng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_vec(rows, cols, rng.normal_vec(rows * cols))
    }

    #[test]
    fn gemm_rs_matches_reference_swizzled_or_not() {
        forall(24, 0x6E, |rng| {
            let n = [2usize, 4][rng.below(2) as usize];
            let bm = 4;
            let m = n * bm * rng.range(1, 3) as usize;
            let kl = rng.range(1, 5) as usize * 2;
            let cols = rng.range(1, 5) as usize * 2;
            let a: Vec<Mat> = (0..n).map(|_| rand_mat(rng, m, kl)).collect();
            let b: Vec<Mat> =
                (0..n).map(|_| rand_mat(rng, kl, cols)).collect();
            let swizzle = rng.below(2) == 0;
            let fused = gemm_rs_fused(&a, &b, bm, swizzle).unwrap();
            let want = gemm_rs_reference(&a, &b).unwrap();
            for (f, w) in fused.iter().zip(&want) {
                assert!(f.max_abs_diff(w) < 1e-3, "mismatch");
            }
        });
    }

    #[test]
    fn scattered_layout_is_the_alltoall_preimage() {
        let mut rng = Rng::new(3);
        let (n, bm, m, kl, cols) = (4usize, 2usize, 16usize, 4usize, 6usize);
        let a = rand_mat(&mut rng, m, kl);
        let b = rand_mat(&mut rng, kl, cols);
        let scattered = gemm_rs_scattered(&a, &b, 1, n, bm, true).unwrap();
        let full = matmul(&a, &b);
        let per = m / n;
        for (d, s) in scattered.iter().enumerate() {
            let want = full.row_slice(d * per, (d + 1) * per);
            assert!(s.max_abs_diff(&want) < 1e-4, "dest {d}");
        }
    }

    #[test]
    fn ag_gemm_value_is_transfer_order_independent() {
        // The paper's schedule optimizations (§4.1/4.3) reorder
        // communication freely; values must be invariant. Randomized
        // interleavings all agree with the reference.
        forall(24, 0xA6, |rng| {
            let n = [2usize, 4][rng.below(2) as usize];
            let comm_rows = 2usize;
            let shard_rows = comm_rows * rng.range(1, 4) as usize;
            let m = shard_rows * n;
            let bm = if m % 4 == 0 { 4 } else { 2 };
            let k = rng.range(1, 5) as usize * 2;
            let cols = rng.range(1, 4) as usize * 2;
            let x: Vec<Mat> =
                (0..n).map(|_| rand_mat(rng, shard_rows, k)).collect();
            let rank = rng.below(n as u64) as usize;
            let w = rand_mat(rng, k, cols);
            let sched_len =
                comm_schedule(m, rank, n, comm_rows, true).len();
            let mut order: Vec<usize> = (0..sched_len).collect();
            rng.shuffle(&mut order);
            let got =
                ag_gemm_rank(&x, &w, rank, comm_rows, bm, &order).unwrap();
            let want = ag_gemm_reference(&x, &w).unwrap();
            assert!(got.max_abs_diff(&want) < 1e-3);
        });
    }

    #[test]
    fn ag_gemm_detects_missing_transfer_as_deadlock() {
        // Failure injection: drop one transfer — the kernel must deadlock
        // (wait on unset signal), not silently compute garbage.
        let mut rng = Rng::new(9);
        let n = 2;
        let x: Vec<Mat> = (0..n).map(|_| rand_mat(&mut rng, 4, 4)).collect();
        let w = rand_mat(&mut rng, 4, 2);
        let sched_len = comm_schedule(8, 0, n, 2, true).len();
        let order: Vec<usize> = (0..sched_len - 1).collect(); // drop last
        let err = ag_gemm_rank(&x, &w, 0, 2, 2, &order);
        assert!(err.is_err(), "must fail: {err:?}");
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("deadlock") || msg.contains("never set"),
                "got: {msg}");
    }

    #[test]
    fn all_ranks_agree_on_ag_gemm_rows() {
        // Every rank computes x_full @ w_r; the gathered input must be
        // identical across ranks regardless of their different ring
        // orders.
        let mut rng = Rng::new(11);
        let n = 4;
        let x: Vec<Mat> = (0..n).map(|_| rand_mat(&mut rng, 4, 6)).collect();
        let w = rand_mat(&mut rng, 6, 4);
        let sched_len = comm_schedule(16, 0, n, 2, true).len();
        let order: Vec<usize> = (0..sched_len).collect();
        let outs: Vec<Mat> = (0..n)
            .map(|r| ag_gemm_rank(&x, &w, r, 2, 4, &order).unwrap())
            .collect();
        for o in &outs[1..] {
            assert!(o.max_abs_diff(&outs[0]) < 1e-5);
        }
    }
}
