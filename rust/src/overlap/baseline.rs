//! Non-overlapping baseline: fastest monolithic GEMM + NCCL ring
//! collective, strictly serialized — the "PyTorch" bars of Fig. 4/11-14.

use crate::cost::arch::ClusterSpec;
use crate::cost::comm::{ring_all_gather_ns, ring_reduce_scatter_ns};
use crate::overlap::{Op, OpTiming, Problem};

/// Simulate the non-overlapping execution. All ranks are symmetric, so
/// the slowest-rank time equals the single-rank time.
pub fn simulate(cluster: &ClusterSpec, p: &Problem) -> OpTiming {
    let gemm = p.gemm_nonsplit_ns(cluster);
    let comm = match p.op {
        // AllGather happens BEFORE the GEMM (Fig. 2 first GEMM).
        Op::AgGemm => ring_all_gather_ns(cluster, p.n_tp, p.comm_bytes()),
        // ReduceScatter happens AFTER the GEMM (Fig. 2 second GEMM).
        Op::GemmRs => {
            ring_reduce_scatter_ns(cluster, p.n_tp, p.comm_bytes())
        }
    };
    OpTiming { overall_ns: gemm + comm, gemm_nonsplit_ns: gemm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};

    #[test]
    fn baseline_ect_equals_collective_time() {
        // §2.3: for the non-overlapping method, ECT == pure NCCL time.
        let p = Problem::ag(4096, 49152, 12288, 8);
        let t = simulate(&A100_NVLINK, &p);
        let comm = ring_all_gather_ns(&A100_NVLINK, 8, p.comm_bytes());
        assert!((t.ect_ns() - comm).abs() < 1e-6);
    }

    #[test]
    fn pcie_has_much_higher_comm_fraction() {
        let p = Problem::rs(8192, 12288, 49152, 8);
        let pcie = simulate(&A100_PCIE, &p);
        let nvl = simulate(&A100_NVLINK, &p);
        let frac = |t: &OpTiming| t.ect_ns() / t.overall_ns;
        assert!(frac(&pcie) > 3.0 * frac(&nvl),
                "pcie {} nvl {}", frac(&pcie), frac(&nvl));
    }

    #[test]
    fn h800_comm_fraction_exceeds_a100_nvlink() {
        // Fast compute + slower links => §6's "high communication
        // proportion for different reasons".
        let p = Problem::ag(8192, 49152, 12288, 8);
        let h = simulate(&H800_NVLINK, &p);
        let a = simulate(&A100_NVLINK, &p);
        let frac = |t: &OpTiming| t.ect_ns() / t.overall_ns;
        assert!(frac(&h) > frac(&a));
    }
}
